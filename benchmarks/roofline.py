"""Aggregate the dry-run sweep JSONs into the §Roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.sweep) and emits a
markdown table + CSV with the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS, and a one-line "what would move the dominant term" note
per (arch x shape x mesh) cell.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

NOTES = {
    ("compute",): "raise per-chip work (bigger microbatch) or cut remat recompute",
    ("memory",): "fuse attention (Pallas flash kernel keeps scores in VMEM), "
                 "cut fp32 score materialization and layout copies",
    ("collective",): "reshard to cut all-gathers (FSDP->TP boundary), overlap "
                     "grad all-reduce with backward, int8-compress cross-pod",
}


def load(out_dir: str = "experiments/dryrun") -> List[Dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rows.append(json.load(open(fn)))
    return rows


def fmt_row(r: Dict) -> str:
    if r["status"] != "ok":
        reason = r.get("reason", r.get("error", ""))[:60]
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
                f"| — | — | — | — | — | {reason} |")
    roof = r["roofline"]
    dom = roof["dominant"]
    note = NOTES.get((dom,), "")
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {roof['compute_s']:.3f} | {roof['memory_s']:.3f} "
            f"| {roof['collective_s']:.3f} | **{dom}** "
            f"| {r['useful_flops_ratio']:.2f} | {note} |")


def run(out_dir: str = "experiments/dryrun", csv: bool = True):
    rows = load(out_dir)
    ok = [r for r in rows if r["status"] == "ok"]
    if csv:
        for r in ok:
            roof = r["roofline"]
            print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
                  f"compute_s={roof['compute_s']:.4f},"
                  f"memory_s={roof['memory_s']:.4f},"
                  f"collective_s={roof['collective_s']:.4f},"
                  f"dominant={roof['dominant']},"
                  f"useful_ratio={r['useful_flops_ratio']:.3f}")
    return rows


def markdown(out_dir: str = "experiments/dryrun") -> str:
    rows = load(out_dir)
    hdr = ("| arch | shape | mesh | status | compute s | memory s | "
           "collective s | dominant | useful/HLO | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(fmt_row(r) for r in rows)


if __name__ == "__main__":
    run()
    print()
    print(markdown())
