"""Paper Fig. 6: train/test reconstruction error vs iteration (unsupervised).

DBN pre-training (Algorithm 1) + autoencoder unroll + MapReduce BP fine-tuning
on synthetic MNIST; reports the per-image squared reconstruction error curve.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import DBNConfig, autoencoder, train_dbn
from repro.data import train_test


def run(n_train=2048, n_test=512, epochs=8, stack=(784, 256, 64, 30),
        batch=128, seed=0, csv=True):
    Xtr, _, Xte, _ = train_test(n_train=n_train, n_test=n_test, seed=seed)
    key = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    dbn_cfg = DBNConfig(stack=stack, max_epoch=3, batch_size=batch)
    rbm_stack = train_dbn(Xtr, dbn_cfg, key)
    params = autoencoder.unroll(rbm_stack)
    step = autoencoder.make_finetune_step(None, lr=0.02)
    vel = jax.tree.map(jnp.zeros_like, params)
    rows = []
    for epoch in range(epochs):
        for b in range(0, n_train - batch + 1, batch):
            params, vel, loss, aux = step(
                params, vel, {"x": jnp.asarray(Xtr[b:b + batch])})
        tr = autoencoder.reconstruction_error(params, Xtr[:n_test])
        te = autoencoder.reconstruction_error(params, Xte)
        rows.append((epoch, tr, te))
        if csv:
            print(f"fig6_unsup_error,epoch={epoch},train_err={tr:.4f},"
                  f"test_err={te:.4f}")
    dt = time.perf_counter() - t0
    if csv:
        improved = rows[0][1] / max(rows[-1][1], 1e-9)
        print(f"fig6_unsup_error,total_s={dt:.1f},improvement_x={improved:.2f}")
    return rows


if __name__ == "__main__":
    run()
