"""Static-batch vs continuous-batch serving throughput (BENCH_serve.json).

Offered load: N concurrent requests with mixed prompt lengths (8-48) and a
head-of-line-blocking budget mix — every ``C``-request arrival group is
short chat-style turns plus one long-form generation — served at a fixed
concurrency cap C (the decode batch width both schedulers get).  The
static baseline processes arrival-order batches of C, padding each batch's
prompts together and decoding until its slowest member finishes, so every
short request's slot idles for the straggler's full budget; the continuous
engine retires slots at EOS/budget and backfills from the queue, so a slot
only spends steps on tokens someone asked for.  Both paths are fully
warmed (every jit shape compiled) before timing, and the static path's
greedy tokens are checked to match the engine's.

Emits BENCH_serve.json with requests/s, tokens/s, p50/p95 latency for both
engines and the continuous/static tokens/s speedup.

  PYTHONPATH=src python -m benchmarks.serve_throughput [--requests 16]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np


def run(arch: str = "qwen2-0.5b", requests: int = 16, slots: int = 4,
        prompt_lo: int = 8, prompt_hi: int = 48, gen_short: int = 4,
        gen_long: int = 128, seed: int = 0, out: str = "BENCH_serve.json"):
    import jax
    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine, generate_static

    cfg = dataclasses.replace(reduced(get_arch(arch)), remat="none")
    ps = 16
    max_len = ((prompt_hi + gen_long + ps - 1) // ps) * ps
    scfg = ServeConfig(page_size=ps, max_slots=slots, max_len=max_len)

    rng = np.random.RandomState(seed)
    prompts = [rng.randint(1, cfg.vocab, size=int(rng.randint(
        prompt_lo, prompt_hi + 1))).tolist() for _ in range(requests)]
    # one long-form generation per arrival group of `slots`: each static
    # batch stalls on its straggler while continuous retires + backfills
    budgets = [gen_long if i % slots == slots - 1 else gen_short
               for i in range(requests)]

    eng = Engine(cfg, scfg, seed=seed)
    params = eng.params

    # warm-up: replay the whole workload with a 2-token budget so every
    # prefill bucket, scatter shape, and decode step both paths will use is
    # compiled before the timed runs (prefill shapes depend only on lengths)
    eng.run_offline(prompts, 2)
    eng.collect()
    generate_static(cfg, params, prompts, 2, scfg, batch_size=slots)

    # timed: static
    static_tokens, static_m = generate_static(
        cfg, params, prompts, budgets, scfg, batch_size=slots)

    # timed: continuous (fresh engine state, same params/pool geometry)
    eng2 = Engine(cfg, scfg, params)
    eng2._prefill, eng2._decode, eng2._scatter = \
        eng._prefill, eng._decode, eng._scatter   # reuse compiled steps
    results, cont_m = eng2.run_offline(prompts, budgets)

    match = [r.tokens for r in results] == static_tokens
    speedup = cont_m["tokens_per_s"] / max(static_m["tokens_per_s"], 1e-9)
    payload = {
        "arch": cfg.name,
        "requests": requests,
        "concurrency": slots,
        "prompt_lens": [len(p) for p in prompts],
        "token_budgets": budgets,
        "tokens_match_static": match,
        "static": static_m,
        "continuous": cont_m,
        "speedup_tokens_per_s": speedup,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), out) if not os.path.isabs(out) else out
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"serve_throughput,arch={cfg.name},requests={requests},"
          f"concurrency={slots},"
          f"static_tok_s={static_m['tokens_per_s']:.1f},"
          f"cont_tok_s={cont_m['tokens_per_s']:.1f},"
          f"speedup={speedup:.2f},match={match}")
    print(f"serve_throughput,wrote={path}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    run(arch=args.arch, requests=args.requests, slots=args.slots,
        seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
