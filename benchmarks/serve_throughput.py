"""Serving throughput: static vs continuous vs continuous + prefix cache.

Offered load: N concurrent requests drawn from ``families`` distinct prompt
*families* — every request is a shared family prefix plus a unique suffix
(mixed lengths), with a head-of-line-blocking budget mix: every ``C``-request
arrival group is short chat-style turns plus one long-form generation.  The
shared prefixes are the redundancy the source paper complains about
("redundant data aggravates the system workload"): without a prefix cache
every request prefills its family prefix from scratch.

Three serving paths are timed at the same concurrency cap C:

* ``static``   — arrival-order batches of C, padded together, each batch
  gated by its slowest member (the pre-paging baseline);
* ``continuous`` — paged KV pool + continuous batching, prefix cache off;
* ``continuous_prefix_cache`` — same engine with the radix prefix cache:
  matched prefix pages are shared/refcounted and only uncached tails are
  prefilled.

All paths are fully warmed (every jit shape compiled) before timing and all
greedy tokens are checked to match; the cache row additionally reports
cached/prefilled prompt tokens, hit rate, and TTFT — the win to look for is
``prefill_tokens`` dropping by roughly the duplicated-prefix mass and TTFT
p50 shrinking with it.

A second section (``cache_families``) serves one reduced arch per cache
family — paged KV, MLA latent pages, sliding-window page ring, SSM and
RG-LRU state slots, enc-dec pinned cross cache — through the same
continuous-vs-static comparison, reporting per-family tokens/s and TTFT
(exact-match checked against the single-request baseline).

A third section (``chunked_prefill``) runs the head-of-line adversarial mix
— one 2048-token prompt arriving behind live short decodes plus a queue of
shorts — with and without ``prefill_chunk_tokens``, reporting short-request
``ttft_p50/p95``, per-engine ``decode_stall_ms`` percentiles, and prefill
padding waste (``prefill_padded_tokens`` vs ``prefill_actual_tokens``).

A fourth section (``poisson_openloop``) offers the workload *open-loop*
through the async streaming front-end (``ServingLoop`` driving the
overlapped ``Engine.pump()``): Poisson arrivals at a machine-calibrated
rate, per-request TTFT/TPOT deadlines, reporting goodput (tokens from
SLO-meeting requests only), SLO attainment, and TTFT/TPOT percentiles —
streamed tokens exact-checked against the static baseline.

A fifth section (``quantization``) serves the same mixed workload with
``kv_dtype=int8`` (int8 KV pages + per-page bf16 absmax scales, dequant
in-kernel) vs ``bf16``, reporting KV bytes/token, tokens/s, max concurrent
residency at a fixed pool byte budget, and the dual-gate parity stats
(bounded max-abs logit error + exact greedy match at high-margin tokens,
see ``serving.quant_verify``).

A sixth section (``speculation``) serves a greedy-repetitive workload
(periodic prompts whose continuation the n-gram prompt-lookup proposer
nails) and an adversarial-random one (i.i.d. tokens, accept rate ~0)
with and without ``speculate_tokens``, reporting decode tokens/s both
ways, draft accept rate, and exact token match vs the non-speculative
engine — the win to look for is the repetitive speedup with the
adversarial overhead bounded.

Emits BENCH_serve.json and appends one summary line per (kv_dtype,
spec_tokens) to BENCH_history.jsonl (the perf trajectory across runs;
``kv_dtype`` and ``spec_tokens`` keep the bf16 / int8 / speculative
series in separate regression-gate groups).

  PYTHONPATH=src python -m benchmarks.serve_throughput [--requests 16]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np


def make_workload(vocab: int, requests: int, families: int, prefix_len: int,
                  suffix_lo: int, suffix_hi: int, slots: int, gen_short: int,
                  gen_long: int, seed: int):
    rng = np.random.RandomState(seed)
    fams = [rng.randint(1, vocab, size=prefix_len).tolist()
            for _ in range(families)]
    prompts = [fams[i % families] + rng.randint(1, vocab, size=int(
        rng.randint(suffix_lo, suffix_hi + 1))).tolist()
        for i in range(requests)]
    # one long-form generation per arrival group of `slots`: each static
    # batch stalls on its straggler while continuous retires + backfills
    budgets = [gen_long if i % slots == slots - 1 else gen_short
               for i in range(requests)]
    return prompts, budgets


def adversarial_mix(arch: str = "qwen2-0.5b", slots: int = 4,
                    long_len: int = 2048, n_short: int = 15, gen: int = 4,
                    chunk: int = 256, seed: int = 0,
                    attn_backend: str = "auto"):
    """Head-of-line adversarial mix: one ``long_len``-token prompt arriving
    behind the first admission wave of short prompts, plus more shorts
    queued behind it.  The unchunked engine stalls every decoding short for
    the long prompt's whole monolithic prefill and makes the queued shorts
    wait it out; chunked prefill (``prefill_chunk_tokens``) bounds each
    stall at one chunk.  Reports short-request ttft percentiles and
    decode-stall times for both engine configs (exact-token checked against
    each other and the static single-request baseline) — the chunking win
    the ISSUE acceptance bar reads off this section is
    ``ttft_short_p50_ratio >= 2``."""
    import dataclasses as _dc

    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine, generate_static

    cfg = _dc.replace(reduced(get_arch(arch)), remat="none")
    rng = np.random.RandomState(seed)
    ps = 16
    max_len = ((long_len + gen + ps - 1) // ps) * ps
    prompts = [rng.randint(1, cfg.vocab,
                           size=int(rng.randint(8, 25))).tolist()
               for _ in range(n_short)]
    long_prompt = rng.randint(1, cfg.vocab, size=long_len).tolist()
    # long prompt arrives after the first admission wave fills the slots, so
    # its prefill competes with live decodes (the stall being measured)
    prompts.insert(slots - 1, long_prompt)
    budgets = [gen] * len(prompts)
    short_rids = [i for i, p in enumerate(prompts) if len(p) < long_len]

    base = ServeConfig(page_size=ps, max_slots=slots, max_len=max_len,
                       attn_backend=attn_backend)
    chunked = dataclasses.replace(base, prefill_chunk_tokens=chunk)
    eng = Engine(cfg, base, seed=seed)
    params = eng.params
    # warm every jit shape both configs use before the timed runs
    eng.run_offline(prompts, budgets)
    Engine(cfg, chunked, params).run_offline(prompts, budgets)

    res_mono, m_mono = Engine(cfg, base, params).run_offline(prompts, budgets)
    res_chnk, m_chnk = Engine(cfg, chunked, params).run_offline(prompts,
                                                               budgets)
    ref, _ = generate_static(cfg, params, prompts, budgets, base,
                             batch_size=1)
    match = ([r.tokens for r in res_mono] == ref
             and [r.tokens for r in res_chnk] == ref)

    def short_ttft(results, q):
        return float(np.percentile(
            [r.ttft for r in results if r.rid in short_rids], q))

    out = {
        "arch": cfg.name,
        "long_len": long_len,
        "n_short": n_short,
        "prefill_chunk_tokens": chunk,
        "tokens_match_static": match,
        "monolithic": {
            "ttft_short_p50_s": short_ttft(res_mono, 50),
            "ttft_short_p95_s": short_ttft(res_mono, 95),
            "decode_stall_ms_p50": m_mono["decode_stall_ms_p50"],
            "decode_stall_ms_p95": m_mono["decode_stall_ms_p95"],
            "decode_stall_ms_max": m_mono["decode_stall_ms_max"],
            "prefill_padded_tokens": m_mono["prefill_padded_tokens"],
            "prefill_actual_tokens": m_mono["prefill_actual_tokens"],
            "prefill_padding_waste": m_mono["prefill_padding_waste"],
            "tokens_per_s": m_mono["tokens_per_s"],
        },
        "chunked": {
            "ttft_short_p50_s": short_ttft(res_chnk, 50),
            "ttft_short_p95_s": short_ttft(res_chnk, 95),
            "decode_stall_ms_p50": m_chnk["decode_stall_ms_p50"],
            "decode_stall_ms_p95": m_chnk["decode_stall_ms_p95"],
            "decode_stall_ms_max": m_chnk["decode_stall_ms_max"],
            "chunked_prefill_steps": m_chnk["chunked_prefill_steps"],
            "prefill_padded_tokens": m_chnk["prefill_padded_tokens"],
            "prefill_actual_tokens": m_chnk["prefill_actual_tokens"],
            "prefill_padding_waste": m_chnk["prefill_padding_waste"],
            "tokens_per_s": m_chnk["tokens_per_s"],
        },
    }
    out["ttft_short_p50_ratio"] = (
        out["monolithic"]["ttft_short_p50_s"]
        / max(out["chunked"]["ttft_short_p50_s"], 1e-9))
    out["decode_stall_max_ratio"] = (
        out["monolithic"]["decode_stall_ms_max"]
        / max(out["chunked"]["decode_stall_ms_max"], 1e-9))
    print(f"serve_throughput,adversarial,long={long_len},chunk={chunk},"
          f"ttft_short_p50_ms="
          f"{out['monolithic']['ttft_short_p50_s']*1e3:.1f}"
          f"->{out['chunked']['ttft_short_p50_s']*1e3:.1f}"
          f" (x{out['ttft_short_p50_ratio']:.1f}),"
          f"stall_max_ms={out['monolithic']['decode_stall_ms_max']:.1f}"
          f"->{out['chunked']['decode_stall_ms_max']:.1f},match={match}")
    return out


def poisson_openloop(arch: str = "qwen2-0.5b", requests: int = 16,
                     slots: int = 4, gen: int = 8, prompt_lo: int = 4,
                     prompt_hi: int = 24, rate_scale: float = 0.7,
                     slo_scale: float = 2.0, seed: int = 0,
                     attn_backend: str = "auto"):
    """Open-loop Poisson arrivals through the async streaming front-end.

    Unlike the closed-loop sections (all requests offered at t=0), arrivals
    here follow an exponential inter-arrival clock that does NOT wait for
    the server — the serving regime of the paper's "millions of users"
    deployment.  Each request carries TTFT and TPOT deadlines calibrated on
    this machine (``slo_scale`` x the warm closed-loop p50s — absolute
    deadlines would be meaningless on an arbitrary CI box); the offered
    rate is ``rate_scale`` x the warm closed-loop request throughput, i.e.
    below saturation so attainment is expected high.  Reports **goodput**
    (tokens from SLO-meeting requests per second — tokens that merely
    arrive late count for nothing), SLO attainment, and TTFT/TPOT
    percentiles, with every streamed token checked exact against the
    static single-request baseline."""
    import asyncio
    import dataclasses as _dc

    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine, ServingLoop, generate_static

    cfg = _dc.replace(reduced(get_arch(arch)), remat="none")
    rng = np.random.RandomState(seed)
    ps = 16
    max_len = ((prompt_hi + gen + ps - 1) // ps) * ps
    scfg = ServeConfig(page_size=ps, max_slots=slots, max_len=max_len,
                       attn_backend=attn_backend)
    prompts = [rng.randint(1, cfg.vocab, size=int(
        rng.randint(prompt_lo, prompt_hi + 1))).tolist()
        for _ in range(requests)]
    budgets = [gen] * requests

    # warm every jit shape AND calibrate the machine: the closed-loop run's
    # ttft/decode-step p50s set the deadlines, its request rate the load
    warm_eng = Engine(cfg, scfg, seed=seed)
    params = warm_eng.params
    _, warm = warm_eng.run_offline(prompts, budgets)
    ttft_slo_s = slo_scale * max(warm["ttft_p50_s"], 1e-3)
    tpot_slo_s = slo_scale * max(warm["decode_step_ms_p50"] / 1e3, 1e-4)
    offered_rate = rate_scale * max(warm["requests_per_s"], 1e-9)
    arrivals = np.cumsum(rng.exponential(1.0 / offered_rate, size=requests))

    eng = Engine(cfg, scfg, params)
    serving = ServingLoop(eng, overlap=True)

    async def client(i: int, t0: float):
        delay = t0 + arrivals[i] - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        t_submit = time.perf_counter()
        rid, q = serving.submit(prompts[i], budgets[i])
        toks, t_first = [], None
        while True:
            ev = await q.get()
            if ev["type"] == "token":
                if t_first is None:
                    t_first = time.perf_counter()
                toks.append(ev["token"])
            elif ev["type"] in ("done", "error"):
                serving.forget(rid)
                t_done = time.perf_counter()
                t_first = t_first if t_first is not None else t_done
                return {
                    "i": i, "tokens": toks,
                    "ok": ev["type"] == "done",
                    "ttft_s": t_first - t_submit,
                    "tpot_s": ((t_done - t_first)
                               / max(len(toks) - 1, 1)),
                    "latency_s": t_done - t_submit}

    async def drive():
        await serving.start()
        t0 = time.perf_counter()
        rows = await asyncio.gather(*[client(i, t0)
                                      for i in range(requests)])
        wall = time.perf_counter() - t0
        await serving.stop()
        return rows, wall

    rows, wall = asyncio.run(drive())
    rows.sort(key=lambda r: r["i"])
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1, seed=seed)
    match = all(r["ok"] for r in rows) \
        and [r["tokens"] for r in rows] == ref
    met = [r for r in rows
           if r["ttft_s"] <= ttft_slo_s and r["tpot_s"] <= tpot_slo_s]
    good_tokens = sum(len(r["tokens"]) for r in met)
    ttfts = [r["ttft_s"] for r in rows]
    tpots = [r["tpot_s"] for r in rows]
    out = {
        "arch": cfg.name,
        "requests": requests,
        "offered_rate_req_s": float(offered_rate),
        "ttft_slo_s": float(ttft_slo_s),
        "tpot_slo_s": float(tpot_slo_s),
        "wall_s": wall,
        "tokens_match_static": match,
        "tokens_per_s": sum(len(r["tokens"]) for r in rows)
        / max(wall, 1e-9),
        "goodput_tokens_per_s": good_tokens / max(wall, 1e-9),
        "slo_attainment": len(met) / max(requests, 1),
        "ttft_attainment": (sum(r["ttft_s"] <= ttft_slo_s for r in rows)
                            / max(requests, 1)),
        "tpot_attainment": (sum(r["tpot_s"] <= tpot_slo_s for r in rows)
                            / max(requests, 1)),
        "ttft_p50_s": float(np.percentile(ttfts, 50)),
        "ttft_p95_s": float(np.percentile(ttfts, 95)),
        "tpot_p50_s": float(np.percentile(tpots, 50)),
        "tpot_p95_s": float(np.percentile(tpots, 95)),
        "overlap_staged": eng.metrics.value("engine.overlap_staged"),
        "overlap_used": eng.metrics.value("engine.overlap_used"),
        "overlap_dropped": eng.metrics.value("engine.overlap_dropped"),
    }
    print(f"serve_throughput,poisson,rate={offered_rate:.2f}req/s,"
          f"goodput_tok_s={out['goodput_tokens_per_s']:.1f},"
          f"slo_attainment={out['slo_attainment']:.2f},"
          f"ttft_p95_ms={out['ttft_p95_s']*1e3:.1f},"
          f"overlap_used={out['overlap_used']}/{out['overlap_staged']},"
          f"match={match}")
    return out


def overload(arch: str = "qwen2-0.5b", requests: int = 16,
             slots: int = 4, gen: int = 8, prompt_lo: int = 4,
             prompt_hi: int = 24, rate_scale: float = 1.5,
             deadline_scale: float = 3.0, seed: int = 0,
             attn_backend: str = "auto"):
    """Overload section: deadline goodput at 1.5x the calibrated rate,
    with vs without admission control.

    The open-loop Poisson workload is offered at ``rate_scale`` x the warm
    closed-loop request rate — past saturation, so a queue *must* build —
    with per-request total deadlines at ``deadline_scale`` x the warm p50
    latency.  Served twice with identical arrivals:

    * **admission off**: every request is accepted; late ones burn slots
      and pages producing tokens that count for nothing;
    * **admission on** (``ServeConfig.admission_control``): requests whose
      calibrated queue-wait estimate blows the deadline are shed at the
      door with a ``retry_after_s`` backoff hint, and expired requests are
      evicted mid-flight.

    Reports **goodput** (tokens from deadline-meeting requests per second),
    shed rate, deadline attainment, and the terminal accounting the
    fault-tolerance contract requires: every submission ends in
    ``finished`` / ``shed`` / ``deadline_exceeded`` (``unaccounted`` must
    be 0).  ``overload_goodput_tokens_per_s`` (admission on) lands in the
    history; `check_regression` gates a >20% drop."""
    import asyncio
    import dataclasses as _dc

    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine, ServingLoop

    cfg = _dc.replace(reduced(get_arch(arch)), remat="none")
    rng = np.random.RandomState(seed)
    ps = 16
    max_len = ((prompt_hi + gen + ps - 1) // ps) * ps
    base = ServeConfig(page_size=ps, max_slots=slots, max_len=max_len,
                       attn_backend=attn_backend)
    prompts = [rng.randint(1, cfg.vocab, size=int(
        rng.randint(prompt_lo, prompt_hi + 1))).tolist()
        for _ in range(requests)]
    budgets = [gen] * requests

    # warm the jit shapes and calibrate: deadlines and the offered rate are
    # machine-relative, absolute numbers would be meaningless on CI
    warm_eng = Engine(cfg, base, seed=seed)
    params = warm_eng.params
    _, warm = warm_eng.run_offline(prompts, budgets)
    deadline_s = deadline_scale * max(warm["latency_p50_s"], 1e-3)
    offered_rate = rate_scale * max(warm["requests_per_s"], 1e-9)
    arrivals = np.cumsum(rng.exponential(1.0 / offered_rate, size=requests))

    def serve_once(admission: bool):
        scfg = dataclasses.replace(base, admission_control=admission)
        eng = Engine(cfg, scfg, params)
        serving = ServingLoop(eng, overlap=True)

        async def client(i: int, t0: float):
            delay = t0 + arrivals[i] - time.perf_counter()
            if delay > 0:
                await asyncio.sleep(delay)
            t_submit = time.perf_counter()
            rid, q = serving.submit(prompts[i], budgets[i],
                                    deadline_s=deadline_s)
            toks = []
            while True:
                ev = await q.get()
                if ev["type"] == "token":
                    toks.append(ev["token"])
                    continue
                serving.forget(rid)
                err = ev.get("error", "") if ev["type"] == "error" else ""
                if ev["type"] == "done":
                    terminal = "finished"
                elif "shed" in err:
                    terminal = "shed"
                elif "deadline_exceeded" in err:
                    terminal = "deadline_exceeded"
                else:
                    terminal = f"other:{err}"
                return {"i": i, "tokens": toks, "terminal": terminal,
                        "retry_after_s": float(ev.get("retry_after_s", 0.0)),
                        "latency_s": time.perf_counter() - t_submit}

        async def drive():
            await serving.start()
            t0 = time.perf_counter()
            rows = await asyncio.gather(*[client(i, t0)
                                          for i in range(requests)])
            wall = time.perf_counter() - t0
            await serving.stop()
            return rows, wall

        rows, wall = asyncio.run(drive())
        met = [r for r in rows
               if r["terminal"] == "finished" and r["latency_s"] <= deadline_s]
        sheds = [r for r in rows if r["terminal"] == "shed"]
        evicted = [r for r in rows if r["terminal"] == "deadline_exceeded"]
        finished = [r for r in rows if r["terminal"] == "finished"]
        unaccounted = requests - len(finished) - len(sheds) - len(evicted)
        return {
            "wall_s": wall,
            "tokens_per_s": sum(len(r["tokens"]) for r in rows)
            / max(wall, 1e-9),
            "goodput_tokens_per_s": sum(len(r["tokens"]) for r in met)
            / max(wall, 1e-9),
            "deadline_attainment": len(met) / max(requests, 1),
            "shed_rate": len(sheds) / max(requests, 1),
            "evicted_rate": len(evicted) / max(requests, 1),
            "unaccounted": unaccounted,
            "sheds_with_backoff_hint": sum(
                r["retry_after_s"] > 0 for r in sheds),
            "deadline_evictions": eng.metrics.value(
                "engine.deadline_evictions"),
            "shed_total": len(sheds),
        }

    out = {
        "arch": cfg.name,
        "requests": requests,
        "offered_rate_req_s": float(offered_rate),
        "deadline_s": float(deadline_s),
        "without_admission": serve_once(False),
        "with_admission": serve_once(True),
    }
    w, wo = out["with_admission"], out["without_admission"]
    out["goodput_ratio"] = (w["goodput_tokens_per_s"]
                            / max(wo["goodput_tokens_per_s"], 1e-9))
    out["terminal_accounting_ok"] = (
        w["unaccounted"] == 0 and wo["unaccounted"] == 0
        and w["sheds_with_backoff_hint"] == w["shed_total"])
    print(f"serve_throughput,overload,rate={offered_rate:.2f}req/s,"
          f"deadline_ms={deadline_s*1e3:.0f},"
          f"goodput_tok_s={wo['goodput_tokens_per_s']:.1f}"
          f"->{w['goodput_tokens_per_s']:.1f}"
          f" (x{out['goodput_ratio']:.2f}),"
          f"shed_rate={w['shed_rate']:.2f},"
          f"attainment={wo['deadline_attainment']:.2f}"
          f"->{w['deadline_attainment']:.2f},"
          f"accounting_ok={out['terminal_accounting_ok']}")
    return out


def quantization(arch: str = "qwen2-0.5b", requests: int = 8,
                 slots: int = 4, gen: int = 8, prompt_lo: int = 8,
                 prompt_hi: int = 24, pool_budget_mib: float = 64.0,
                 seed: int = 0, attn_backend: str = "auto"):
    """Quantized-KV section: int8 paged pool vs bf16 on the same workload.

    Serves one mixed-length closed-loop workload twice — ``kv_dtype=bf16``
    and ``kv_dtype=int8`` (same params, same backend, both warmed) — and
    reports the three numbers the int8 mode is judged on:

    * ``kv_bytes_per_token`` both ways (int8 pages + bf16 per-page scales
      vs bf16 pages; the acceptance bar is a ratio <= 0.55x);
    * decode throughput both ways (tokens/s and decode-step p50 — the HBM
      gather moves half the bytes, so int8 must not be slower);
    * max concurrent residency at a *fixed pool byte budget*: how many
      max-length requests fit if the whole pool is capped at
      ``pool_budget_mib`` — the capacity win quantization buys (bar:
      >= 1.8x).

    The int8 run's tokens then go through the dual-gate verifier
    (``serving.quant_verify``): bounded max-abs logit error vs a bf16
    replay plus exact greedy match at high-margin positions.  The error
    stats land in the payload so the quantization noise level is tracked
    run-over-run alongside throughput."""
    import dataclasses as _dc

    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine, dual_gate_verify

    cfg = _dc.replace(reduced(get_arch(arch)), remat="none")
    rng = np.random.RandomState(seed)
    ps = 16
    max_len = ((prompt_hi + gen + ps - 1) // ps) * ps
    base = ServeConfig(page_size=ps, max_slots=slots, max_len=max_len,
                       attn_backend=attn_backend)
    int8 = dataclasses.replace(base, kv_dtype="int8")
    prompts = [rng.randint(1, cfg.vocab, size=int(
        rng.randint(prompt_lo, prompt_hi + 1))).tolist()
        for _ in range(requests)]
    budgets = [gen] * requests

    eng_b = Engine(cfg, base, seed=seed)
    params = eng_b.params
    if not eng_b.pool.spec.paged:
        return {"arch": cfg.name, "skipped":
                "kv_dtype only applies to paged attention families"}
    # warm every jit shape for both dtypes before the timed runs
    eng_b.run_offline(prompts, budgets)
    Engine(cfg, int8, params).run_offline(prompts, budgets)

    _, m_b = Engine(cfg, base, params).run_offline(prompts, budgets)
    eng_i = Engine(cfg, int8, params)
    res_i, m_i = eng_i.run_offline(prompts, budgets)

    # capacity at a fixed byte budget: page_nbytes counts payload AND scale
    # leaves for int8 (a page id owns its slice of both), so the residency
    # ratio is the honest capacity win, not payload-only accounting
    pages_req = eng_b.pool.pages_for(prompt_hi + gen)
    budget = int(pool_budget_mib * 2 ** 20)
    resident_b = budget // (eng_b.pool.page_nbytes * pages_req)
    resident_i = budget // (eng_i.pool.page_nbytes * pages_req)

    report = dual_gate_verify(cfg, int8, params, prompts,
                              [r.tokens for r in res_i],
                              attn_backend=m_i["attn_backend"])
    verify = {k: v for k, v in report.items() if k != "per_request"}
    verify["per_request_max_err"] = [r["max_err"]
                                    for r in report["per_request"]]

    out = {
        "arch": cfg.name,
        "attn_backend": m_i["attn_backend"],
        "requests": requests,
        "bf16": {
            "kv_bytes_per_token": eng_b.pool.kv_bytes_per_token,
            "page_nbytes": eng_b.pool.page_nbytes,
            "tokens_per_s": m_b["tokens_per_s"],
            "decode_step_ms_p50": m_b["decode_step_ms_p50"],
        },
        "int8": {
            "kv_bytes_per_token": eng_i.pool.kv_bytes_per_token,
            "page_nbytes": eng_i.pool.page_nbytes,
            "tokens_per_s": m_i["tokens_per_s"],
            "decode_step_ms_p50": m_i["decode_step_ms_p50"],
        },
        "kv_bytes_ratio": (eng_i.pool.kv_bytes_per_token
                           / max(eng_b.pool.kv_bytes_per_token, 1e-9)),
        "tokens_per_s_ratio": (m_i["tokens_per_s"]
                               / max(m_b["tokens_per_s"], 1e-9)),
        "pool_budget_mib": pool_budget_mib,
        "pages_per_request": pages_req,
        "max_resident_bf16": int(resident_b),
        "max_resident_int8": int(resident_i),
        "residency_ratio": resident_i / max(resident_b, 1),
        "quant_verify": verify,
        "dual_gate_ok": report["ok"],
    }
    print(f"serve_throughput,quantization,arch={cfg.name},"
          f"kv_bytes_per_token={out['bf16']['kv_bytes_per_token']:.0f}"
          f"->{out['int8']['kv_bytes_per_token']:.0f}"
          f" (x{out['kv_bytes_ratio']:.3f}),"
          f"tok_s={out['bf16']['tokens_per_s']:.1f}"
          f"->{out['int8']['tokens_per_s']:.1f},"
          f"residency={out['max_resident_bf16']}"
          f"->{out['max_resident_int8']}"
          f" (x{out['residency_ratio']:.2f})")
    print(f"serve_throughput,quantization,max_logit_err="
          f"{verify['max_logit_err']:.4f} (tol {verify['tol']:.2f}),"
          f"high_margin_mismatches={verify['high_margin_mismatches']}/"
          f"{verify['high_margin_tokens']},"
          f"dual_gate_ok={report['ok']}")
    return out


def speculation(arch: str = "qwen2-0.5b", requests: int = 1, slots: int = 1,
                gen: int = 64, spec_tokens: int = 4, seed: int = 0,
                attn_backend: str = "auto"):
    """Speculative-decoding section: n-gram drafts + small-q verify.

    Two workloads bracket the proposer's range, both decoded with
    ``speculate_tokens`` on and off (same params, same backend, warmed):

    * ``repetitive`` — periodic prompts (a short token motif repeated), the
      greedy continuation keeps the period, so prompt lookup drafts the
      right tokens nearly every step: the best case the ISSUE acceptance
      bar reads (``decode speedup >= 1.5``);
    * ``adversarial`` — i.i.d. uniform-random prompts: trailing n-grams of
      the *prompt* almost never recur, so early drafts are empty/rejected
      and the section bounds speculation overhead (``speedup >= 0.95``).

    The section pins the regime speculation actually targets: the
    latency-bound single stream (``requests = slots = 1``).  Speculation
    trades extra verify FLOPs for fewer sequential steps, so it wins where
    a decode step's cost is dominated by per-step fixed work (dispatch,
    gather, host scheduling) rather than per-row math; at batch >= 4 on a
    compute-bound host each verify row costs as much as a decode row and
    the win collapses toward 1x — batched throughput serving is already
    covered by the other sections.  Speculation also only changes the
    *decode* loop, so the headline ``speedup`` is decode-phase-attributed:
    with one admission wave (``requests <= slots``) every request decodes
    from one batched prefill, and ``decode_tokens_per_s`` divides
    post-first-token tokens by the window from the earliest first token to
    the last finish (arrival-relative stamps share an epoch —
    ``run_offline`` queues all requests up front).  Whole-run
    ``tokens_per_s`` is reported alongside (``speedup_total``) but dilutes
    the win with prefill/admission time.

    Both runs are exact-token-checked against the non-speculative engine —
    greedy accept means speculation may only change launch count, never
    tokens."""
    import dataclasses as _dc

    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine

    cfg = _dc.replace(reduced(get_arch(arch)), remat="none")
    rng = np.random.RandomState(seed)
    ps = 16
    motif = rng.randint(1, cfg.vocab, size=6).tolist()
    workloads = {
        "repetitive": [motif * 4 + rng.randint(
            1, cfg.vocab, size=2).tolist() for _ in range(requests)],
        "adversarial": [rng.randint(1, cfg.vocab, size=26).tolist()
                        for _ in range(requests)],
    }
    max_len = ((26 + gen + ps - 1) // ps) * ps
    base = ServeConfig(page_size=ps, max_slots=slots, max_len=max_len,
                       attn_backend=attn_backend)
    spec = dataclasses.replace(base, speculate_tokens=spec_tokens)

    eng = Engine(cfg, base, seed=seed)
    params = eng.params
    if not Engine(cfg, spec, params).spec_k:
        return {"arch": cfg.name, "skipped":
                "speculation needs a paged non-enc-dec cache family"}
    # warm every jit shape (incl. the small-q verify step) for both configs
    for prompts in workloads.values():
        Engine(cfg, base, params).run_offline(prompts, gen)
        Engine(cfg, spec, params).run_offline(prompts, gen)

    out = {"arch": cfg.name, "spec_tokens": spec_tokens,
           "attn_backend": "", "requests": requests}
    def _decode_tok_s(res):
        # post-first-token tokens over the concurrent decode window
        window = (max(r.finish_s for r in res)
                  - min(r.ttft_s for r in res))
        return sum(len(r.tokens) - 1 for r in res) / max(window, 1e-9)

    for name, prompts in workloads.items():
        res_b, m_b = Engine(cfg, base, params).run_offline(prompts, gen)
        res_s, m_s = Engine(cfg, spec, params).run_offline(prompts, gen)
        match = ([r.tokens for r in res_s] == [r.tokens for r in res_b])
        out["attn_backend"] = m_s["attn_backend"]
        dec_b, dec_s = _decode_tok_s(res_b), _decode_tok_s(res_s)
        out[name] = {
            "tokens_per_s_base": m_b["tokens_per_s"],
            "tokens_per_s_spec": m_s["tokens_per_s"],
            "decode_tokens_per_s_base": dec_b,
            "decode_tokens_per_s_spec": dec_s,
            "speedup": dec_s / max(dec_b, 1e-9),
            "speedup_total": (m_s["tokens_per_s"]
                              / max(m_b["tokens_per_s"], 1e-9)),
            "spec_proposed": m_s["spec_proposed"],
            "spec_accepted": m_s["spec_accepted"],
            "accept_rate": m_s["spec_accept_rate"],
            "tokens_match": match,
        }
        print(f"serve_throughput,speculation,{name},K={spec_tokens},"
              f"decode_tok_s={dec_b:.1f}->{dec_s:.1f}"
              f" (x{out[name]['speedup']:.2f}),"
              f"total x{out[name]['speedup_total']:.2f},"
              f"accept_rate={m_s['spec_accept_rate']:.2f},match={match}")
    return out


# one reduced arch per cache family (see src/repro/models/cache_spec.py)
FAMILY_MATRIX = (
    ("paged_kv", "qwen2-0.5b"),
    ("paged_mla", "deepseek-v2-236b"),
    ("windowed_kv", "starcoder2-7b"),
    ("state_slot_ssm", "mamba2-780m"),
    ("state_slot_hybrid", "recurrentgemma-2b"),
    ("cross_kv_encdec", "seamless-m4t-large-v2"),
)


def family_matrix(requests: int = 8, slots: int = 4, gen: int = 16,
                  seed: int = 0, attn_backend: str = "auto"):
    """Continuous-vs-static throughput for one arch per cache family.

    Every family runs the same mixed-length workload; tokens are checked
    exact against the single-request static baseline (the verify contract
    the engine upholds for every family), and the timed static path uses
    the same concurrency cap as the engine."""
    import dataclasses as _dc

    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine, generate_static

    rng = np.random.RandomState(seed)
    lens = [int(rng.randint(6, 28)) for _ in range(requests)]
    # head-of-line mix: one long-form generation per arrival group of
    # ``slots`` — the static batch stalls on it, continuous backfills
    budgets = [gen * 4 if i % slots == slots - 1 else max(gen // 4, 2)
               for i in range(requests)]
    out = {}
    for family, arch in FAMILY_MATRIX:
        cfg = _dc.replace(reduced(get_arch(arch)), remat="none")
        ps = 8
        max_len = ((max(lens) + max(budgets) + ps - 1) // ps) * ps
        scfg = ServeConfig(page_size=ps, max_slots=slots, max_len=max_len,
                           attn_backend=attn_backend)
        prompts = [rng.randint(1, cfg.vocab, size=n).tolist() for n in lens]
        eng = Engine(cfg, scfg, seed=seed)
        params = eng.params
        # warm every jit shape both paths will use — the exact workload,
        # since batched prefill admission makes the prefill shapes
        # (bucket, pow2 batch rows) depend on budgets too
        eng.run_offline(prompts, budgets)
        generate_static(cfg, params, prompts, budgets, scfg,
                        batch_size=slots, seed=seed)
        results, cont_m = Engine(cfg, scfg, params,
                                 seed=seed).run_offline(prompts, budgets)
        _, static_m = generate_static(cfg, params, prompts, budgets, scfg,
                                      batch_size=slots, seed=seed)
        ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                                 batch_size=1, seed=seed)
        out[family] = {
            "arch": cfg.name,
            "tokens_match_static": [r.tokens for r in results] == ref,
            "tokens_per_s": cont_m["tokens_per_s"],
            "static_tokens_per_s": static_m["tokens_per_s"],
            "speedup_tokens_per_s": (cont_m["tokens_per_s"]
                                     / max(static_m["tokens_per_s"], 1e-9)),
            "ttft_p50_s": cont_m["ttft_p50_s"],
            "multi_admit_prefills": cont_m["multi_admit_prefills"],
            "attn_backend": cont_m["attn_backend"],
            "decode_step_ms_p50": cont_m["decode_step_ms_p50"],
            "decode_step_ms_p95": cont_m["decode_step_ms_p95"],
        }
        print(f"serve_throughput,family={family},arch={cfg.name},"
              f"cont_tok_s={cont_m['tokens_per_s']:.1f},"
              f"static_tok_s={static_m['tokens_per_s']:.1f},"
              f"ttft_p50_ms={cont_m['ttft_p50_s']*1e3:.1f},"
              f"match={out[family]['tokens_match_static']}")
    return out


def run(arch: str = "qwen2-0.5b", requests: int = 16, slots: int = 4,
        families: int = 4, prefix_len: int = 24, suffix_lo: int = 4,
        suffix_hi: int = 24, gen_short: int = 4, gen_long: int = 128,
        seed: int = 0, out: str = "BENCH_serve.json",
        attn_backend: str = "auto", chunk: int = 256,
        adversarial_long: int = 2048):
    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine, generate_static

    cfg = dataclasses.replace(reduced(get_arch(arch)), remat="none")
    ps = 16
    max_len = ((prefix_len + suffix_hi + gen_long + ps - 1) // ps) * ps
    scfg = ServeConfig(page_size=ps, max_slots=slots, max_len=max_len,
                       attn_backend=attn_backend)
    scfg_cache = dataclasses.replace(scfg, prefix_cache=True)

    prompts, budgets = make_workload(cfg.vocab, requests, families,
                                     prefix_len, suffix_lo, suffix_hi, slots,
                                     gen_short, gen_long, seed)

    eng = Engine(cfg, scfg, seed=seed)
    params = eng.params

    # warm-up: replay the whole workload so every prefill shape — bucket
    # AND pow2 admission-batch rows, which depend on the budget mix now that
    # admission is batched — and decode step all three paths will use is
    # compiled before the timed runs (jitted steps are cached per
    # ArchConfig, so the timed engines below reuse these compilations)
    eng.run_offline(prompts, budgets)
    Engine(cfg, scfg_cache, params).run_offline(prompts, budgets)
    generate_static(cfg, params, prompts, budgets, scfg, batch_size=slots)

    # timed: static
    static_tokens, static_m = generate_static(
        cfg, params, prompts, budgets, scfg, batch_size=slots)

    # timed: continuous, prefix cache off (fresh pool, same params)
    results, cont_m = Engine(cfg, scfg, params).run_offline(prompts, budgets)

    # timed: continuous, prefix cache on; keep the engine around — its
    # metrics-registry snapshot (pool occupancy, radix hit accounting,
    # admission/preemption counters) goes into the payload
    eng_c = Engine(cfg, scfg_cache, params)
    results_c, cache_m = eng_c.run_offline(prompts, budgets)

    match = ([r.tokens for r in results] == static_tokens
             and [r.tokens for r in results_c] == static_tokens)
    speedup = cont_m["tokens_per_s"] / max(static_m["tokens_per_s"], 1e-9)
    cache_speedup = (cache_m["tokens_per_s"]
                     / max(cont_m["tokens_per_s"], 1e-9))
    payload = {
        "arch": cfg.name,
        "requests": requests,
        "concurrency": slots,
        # resolved backend + decode-step percentiles also sit inside each
        # engine metrics dict; top-level copy for easy trajectory diffing
        "attn_backend": cont_m["attn_backend"],
        "decode_step_ms_p50": cont_m["decode_step_ms_p50"],
        "decode_step_ms_p95": cont_m["decode_step_ms_p95"],
        "prefix_families": families,
        "prefix_len": prefix_len,
        "prompt_lens": [len(p) for p in prompts],
        "token_budgets": budgets,
        "tokens_match_static": match,
        "static": static_m,
        "continuous": cont_m,
        "continuous_prefix_cache": cache_m,
        # full registry snapshot of the prefix-cache run: every pool /
        # radix / scheduler / engine counter-gauge-histogram in one place
        "telemetry_prefix_cache": eng_c.metrics_snapshot(),
        "speedup_tokens_per_s": speedup,
        "prefix_cache_speedup_tokens_per_s": cache_speedup,
        "prefix_cache_prefill_tokens_saved":
            cont_m["prefill_tokens"] - cache_m["prefill_tokens"],
        "prefix_cache_ttft_p50_ratio":
            cache_m["ttft_p50_s"] / max(cont_m["ttft_p50_s"], 1e-9),
        "cache_families": family_matrix(slots=slots, seed=seed,
                                        attn_backend=attn_backend),
        "chunked_prefill": adversarial_mix(
            arch=arch, slots=slots, long_len=adversarial_long, chunk=chunk,
            seed=seed, attn_backend=attn_backend),
        "poisson_openloop": poisson_openloop(
            arch=arch, requests=requests, slots=slots, seed=seed,
            attn_backend=attn_backend),
        "overload": overload(
            arch=arch, requests=requests, slots=slots, seed=seed,
            attn_backend=attn_backend),
        "quantization": quantization(
            arch=arch, slots=slots, seed=seed, attn_backend=attn_backend),
        # speculation keeps its own single-stream defaults (see docstring):
        # the latency regime it targets, not the batched-throughput one
        "speculation": speculation(
            arch=arch, seed=seed, attn_backend=attn_backend),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), out) if not os.path.isabs(out) else out
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    # append-style perf trajectory: one summary line per benchmark run, so
    # regressions show as a series instead of a silent overwrite
    adv = payload["chunked_prefill"]
    poi = payload["poisson_openloop"]
    ovl = payload["overload"]
    quant = payload["quantization"]
    spec = payload["speculation"]
    with open(os.path.join(os.path.dirname(path), "BENCH_history.jsonl"),
              "a") as f:
        # kv_dtype and spec_tokens are part of every line so
        # check_regression groups never mix modes — an int8 or speculative
        # run must not drag down the bf16 non-speculative baseline
        # (or vice versa)
        f.write(json.dumps({
            "timestamp": payload["timestamp"],
            "arch": payload["arch"],
            "attn_backend": payload["attn_backend"],
            "kv_dtype": "bf16",
            "spec_tokens": 0,
            "tokens_per_s_static": static_m["tokens_per_s"],
            "tokens_per_s_continuous": cont_m["tokens_per_s"],
            "tokens_per_s_prefix_cache": cache_m["tokens_per_s"],
            "decode_step_ms_p50": cont_m["decode_step_ms_p50"],
            "ttft_p50_s": cont_m["ttft_p50_s"],
            "cache_hit_rate": cache_m["cache_hit_rate"],
            "decode_stall_ms_max": cont_m["decode_stall_ms_max"],
            "prefill_padding_waste": cont_m["prefill_padding_waste"],
            "adversarial_ttft_short_p50_ratio": adv["ttft_short_p50_ratio"],
            "adversarial_stall_max_ratio": adv["decode_stall_max_ratio"],
            "poisson_goodput_tokens_per_s": poi["goodput_tokens_per_s"],
            "poisson_slo_attainment": poi["slo_attainment"],
            "poisson_ttft_p95_s": poi["ttft_p95_s"],
            "overload_goodput_tokens_per_s":
                ovl["with_admission"]["goodput_tokens_per_s"],
            "overload_shed_rate": ovl["with_admission"]["shed_rate"],
            "overload_deadline_attainment":
                ovl["with_admission"]["deadline_attainment"],
            "overload_accounting_ok": ovl["terminal_accounting_ok"],
            **({"kv_bytes_per_token":
                quant["bf16"]["kv_bytes_per_token"]}
               if "bf16" in quant else {}),
            "tokens_match": bool(match and adv["tokens_match_static"]
                                 and poi["tokens_match_static"]),
        }) + "\n")
        if "int8" in quant:
            # second trajectory line for the quantized mode: its own
            # (arch, backend, kv_dtype=int8) group gates int8 throughput
            # and bytes/token without polluting the bf16 series
            f.write(json.dumps({
                "timestamp": payload["timestamp"],
                "arch": payload["arch"],
                "attn_backend": quant["attn_backend"],
                "kv_dtype": "int8",
                "spec_tokens": 0,
                "tokens_per_s_continuous":
                    quant["int8"]["tokens_per_s"],
                "decode_step_ms_p50":
                    quant["int8"]["decode_step_ms_p50"],
                "kv_bytes_per_token":
                    quant["int8"]["kv_bytes_per_token"],
                "max_logit_err": quant["quant_verify"]["max_logit_err"],
                "tokens_match": bool(quant["dual_gate_ok"]),
            }) + "\n")
        if "repetitive" in spec:
            # third trajectory line for the speculative mode: its own
            # (arch, backend, kv_dtype, spec_tokens=K) group gates the
            # repetitive-workload speedup and the adversarial overhead
            f.write(json.dumps({
                "timestamp": payload["timestamp"],
                "arch": payload["arch"],
                "attn_backend": spec["attn_backend"],
                "kv_dtype": "bf16",
                "spec_tokens": spec["spec_tokens"],
                "tokens_per_s_continuous":
                    spec["repetitive"]["tokens_per_s_spec"],
                "spec_speedup_repetitive":
                    spec["repetitive"]["speedup"],
                "spec_speedup_adversarial":
                    spec["adversarial"]["speedup"],
                "spec_accept_rate_repetitive":
                    spec["repetitive"]["accept_rate"],
                "tokens_match":
                    bool(spec["repetitive"]["tokens_match"]
                         and spec["adversarial"]["tokens_match"]),
            }) + "\n")
    print(f"serve_throughput,arch={cfg.name},requests={requests},"
          f"concurrency={slots},families={families},"
          f"static_tok_s={static_m['tokens_per_s']:.1f},"
          f"cont_tok_s={cont_m['tokens_per_s']:.1f},"
          f"cache_tok_s={cache_m['tokens_per_s']:.1f},"
          f"speedup={speedup:.2f},cache_speedup={cache_speedup:.2f},"
          f"match={match}")
    print(f"serve_throughput,prefill_tokens="
          f"{cont_m['prefill_tokens']}->{cache_m['prefill_tokens']},"
          f"hit_rate={cache_m['cache_hit_rate']:.2f},"
          f"ttft_p50_ms={cont_m['ttft_p50_s']*1e3:.1f}"
          f"->{cache_m['ttft_p50_s']*1e3:.1f}")
    print(f"serve_throughput,wrote={path}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--families", type=int, default=4)
    ap.add_argument("--prefix-len", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--attn-backend",
                    choices=("auto", "reference", "pallas"), default="auto",
                    help="paged-attention backend for the continuous paths "
                         "(recorded in BENCH_serve.json)")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=256,
                    help="chunk budget for the adversarial long+short mix")
    ap.add_argument("--adversarial-long", type=int, default=2048,
                    help="long-prompt length for the adversarial mix")
    args = ap.parse_args()
    run(arch=args.arch, requests=args.requests, slots=args.slots,
        families=args.families, prefix_len=args.prefix_len,
        seed=args.seed, out=args.out, attn_backend=args.attn_backend,
        chunk=args.prefill_chunk_tokens,
        adversarial_long=args.adversarial_long)


if __name__ == "__main__":
    main()
