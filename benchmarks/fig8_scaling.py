"""Paper Fig. 8: running time vs number of workers (MapReduce speed-up).

Each worker count runs in a fresh subprocess with that many forced host
devices; the SAME global batch of RBM CD-1 work is map/combine/reduced across
them (strong scaling, as in the paper's EC2 experiment).  On a single physical
CPU core the wall-clock speedup saturates, so we also report the *per-device
work fraction* (mapper work / workers) and the communication-byte model — the
quantities that transfer to a real fleet.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

WORKER = textwrap.dedent("""
    import os, sys, json, time
    n = int(sys.argv[1])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    import jax, jax.numpy as jnp
    from repro.core.rbm import RBMConfig, make_rbm_step, rbm_init
    from repro.data import dataset
    from repro.launch.mesh import make_host_mesh

    cfg = RBMConfig(n_vis=784, n_hid=512)
    mesh = make_host_mesh(data=n)
    X, _ = dataset(2048, seed=0)
    X = jnp.asarray(X)
    key = jax.random.PRNGKey(0)
    p = rbm_init(key, cfg)
    vel = jax.tree.map(jnp.zeros_like, p)
    step = make_rbm_step(cfg, mesh)
    # warmup/compile
    p2, v2, err = step(p, vel, X, key, 0)
    jax.block_until_ready(err)
    t0 = time.perf_counter()
    iters = 10
    for i in range(iters):
        p, vel, err = step(p, vel, X, jax.random.fold_in(key, i), 0)
    jax.block_until_ready(err)
    dt = (time.perf_counter() - t0) / iters
    print("RESULT" + json.dumps({"workers": n, "s_per_job": dt,
                                 "err": float(err)}))
""")


def run(worker_counts=(1, 2, 4, 8), csv=True):
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    rows = []
    base = None
    for n in worker_counts:
        proc = subprocess.run([sys.executable, "-c", WORKER, str(n)],
                              capture_output=True, text=True, timeout=600,
                              env=env)
        assert proc.returncode == 0, proc.stderr[-1500:]
        line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
        rec = json.loads(line[len("RESULT"):])
        if base is None:
            base = rec["s_per_job"]
        # analytic model for a real fleet: mapper work scales 1/n; the reducer
        # all-reduce moves 2(n-1)/n * |W| bytes per device
        wire_mb = 2 * (n - 1) / n * (784 * 512 * 4) / 1e6
        rec["ideal_work_fraction"] = 1.0 / n
        rec["allreduce_mb_per_device"] = wire_mb
        rec["speedup_measured"] = base / rec["s_per_job"]
        rows.append(rec)
        if csv:
            print(f"fig8_scaling,workers={n},s_per_job={rec['s_per_job']:.4f},"
                  f"speedup={rec['speedup_measured']:.2f},"
                  f"ideal_work_fraction={rec['ideal_work_fraction']:.3f},"
                  f"allreduce_mb_per_dev={wire_mb:.2f}")
    return rows


if __name__ == "__main__":
    run()
