"""Paper Fig. 7: train/test misclassification vs iteration (supervised),
including the paper's observed over-fitting signature (train error -> 0 while
test error bottoms out / rises)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import DBNConfig, finetune, train_dbn
from repro.data import train_test


def run(n_train=2048, n_test=512, epochs=25, stack=(784, 256, 64),
        batch=128, seed=0, csv=True):
    Xtr, ytr, Xte, yte = train_test(n_train=n_train, n_test=n_test, seed=seed)
    key = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    dbn_cfg = DBNConfig(stack=stack, max_epoch=3, batch_size=batch)
    rbm_stack = train_dbn(Xtr, dbn_cfg, key)
    params = finetune.classifier_init(rbm_stack, 10, key)
    step = finetune.make_classifier_step(None, lr=1.0)
    vel = jax.tree.map(jnp.zeros_like, params)
    rows = []
    for epoch in range(epochs):
        for b in range(0, n_train - batch + 1, batch):
            params, vel, loss, aux = step(
                params, vel, {"x": jnp.asarray(Xtr[b:b + batch]),
                              "y": jnp.asarray(ytr[b:b + batch])})
        tr = finetune.error_rate(params, Xtr, ytr)
        te = finetune.error_rate(params, Xte, yte)
        rows.append((epoch, tr, te))
        if csv:
            print(f"fig7_sup_error,epoch={epoch},train_err={tr:.4f},"
                  f"test_err={te:.4f}")
    dt = time.perf_counter() - t0
    if csv:
        print(f"fig7_sup_error,total_s={dt:.1f},final_train={rows[-1][1]:.4f},"
              f"final_test={rows[-1][2]:.4f}")
    return rows


if __name__ == "__main__":
    run()
