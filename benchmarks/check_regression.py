"""Perf-regression gate over the BENCH_history.jsonl trajectory.

``serve_throughput`` appends one summary line per run *per (kv_dtype,
spec_tokens)*; this script compares the newest entry of each ``(arch,
attn_backend, kv_dtype, spec_tokens)`` group against the *median* of that
group's prior entries (median, not mean, so one historical outlier cannot
poison the baseline) and exits nonzero when the newest run regressed:

* ``tokens_per_s_continuous`` dropped more than 15%, or
* ``decode_step_ms_p50`` rose more than 25%, or
* ``poisson_goodput_tokens_per_s`` (the open-loop streaming section)
  dropped more than 20% — gated only when the newest entry *and* every
  prior in the group carry the key, so histories that predate the Poisson
  section never fail on it, or
* ``overload_goodput_tokens_per_s`` (the 1.5x-overload section with
  admission control on) dropped more than 20% — same whole-group rule; a
  drop means the engine got slower under pressure or the admission
  estimator started shedding servable work, or
* ``kv_bytes_per_token`` rose more than 15% — same whole-group-carries-it
  rule.  Bytes/token is a *pool layout* property, so any rise means someone
  fattened the page format (e.g. widened the int8 scale dtype) and the
  quantization win quietly shrank.

``kv_dtype`` defaults to ``bf16`` and ``spec_tokens`` to 0 for entries
that predate those modes, so old histories fold into the baseline group
instead of forming phantom ones; the int8 and speculative series (whose
throughput sits on a different scale) are gated against their own priors
only.

A group with fewer than 3 entries (newest + at least 2 priors) has no
trustworthy baseline — it is reported but never failed.  ``--warn-only``
downgrades every failure to a warning (CI uses it while the history is
young; drop the flag once enough runs have accumulated).

  PYTHONPATH=src python -m benchmarks.check_regression [BENCH_history.jsonl]
      [--warn-only] [--max-tok-drop 0.15] [--max-step-rise 0.25]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List

MIN_ENTRIES = 3           # newest + >=2 priors before the gate can fail


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else (s[n // 2 - 1] + s[n // 2]) / 2.0


def load_history(path: str) -> List[Dict[str, Any]]:
    entries = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError as e:
                print(f"[check_regression] WARNING: skipping malformed "
                      f"line {i + 1}: {e}", file=sys.stderr)
    return entries


def check(entries: List[Dict[str, Any]], max_tok_drop: float,
          max_step_rise: float, max_goodput_drop: float = 0.20,
          max_kv_bytes_rise: float = 0.15) -> List[Dict[str, Any]]:
    """One verdict row per (arch, attn_backend, kv_dtype, spec_tokens)
    group, newest vs median of priors.  ``status`` is ok / regressed /
    insufficient-history."""
    groups: Dict[tuple, List[Dict[str, Any]]] = {}
    for e in entries:                     # file order == append order
        groups.setdefault((e.get("arch"), e.get("attn_backend"),
                           e.get("kv_dtype", "bf16"),
                           e.get("spec_tokens", 0)), []).append(e)

    rows = []
    for (arch, backend, kv_dtype, spec_tokens), group in sorted(
            groups.items()):
        newest, priors = group[-1], group[:-1]
        row: Dict[str, Any] = {
            "arch": arch, "attn_backend": backend, "kv_dtype": kv_dtype,
            "spec_tokens": spec_tokens,
            "n_entries": len(group), "status": "ok", "problems": [],
        }
        if len(group) < MIN_ENTRIES:
            row["status"] = "insufficient-history"
            rows.append(row)
            continue
        tok_base = _median([p["tokens_per_s_continuous"] for p in priors])
        step_base = _median([p["decode_step_ms_p50"] for p in priors])
        tok_now = newest["tokens_per_s_continuous"]
        step_now = newest["decode_step_ms_p50"]
        row["tokens_per_s"] = {"baseline": tok_base, "newest": tok_now,
                               "ratio": tok_now / max(tok_base, 1e-12)}
        row["decode_step_ms_p50"] = {"baseline": step_base,
                                     "newest": step_now,
                                     "ratio": step_now / max(step_base,
                                                             1e-12)}
        if tok_now < tok_base * (1.0 - max_tok_drop):
            row["problems"].append(
                f"tokens_per_s_continuous {tok_now:.1f} is "
                f"{(1 - tok_now / tok_base) * 100:.1f}% below the "
                f"median-of-priors {tok_base:.1f} "
                f"(threshold {max_tok_drop * 100:.0f}%)")
        if step_now > step_base * (1.0 + max_step_rise):
            row["problems"].append(
                f"decode_step_ms_p50 {step_now:.2f} is "
                f"{(step_now / step_base - 1) * 100:.1f}% above the "
                f"median-of-priors {step_base:.2f} "
                f"(threshold {max_step_rise * 100:.0f}%)")
        # Poisson open-loop goodput: only gate when the whole group carries
        # the key (entries from before the streaming front-end lack it)
        good_key = "poisson_goodput_tokens_per_s"
        if good_key in newest and all(good_key in p for p in priors):
            good_base = _median([p[good_key] for p in priors])
            good_now = newest[good_key]
            row["poisson_goodput"] = {
                "baseline": good_base, "newest": good_now,
                "ratio": good_now / max(good_base, 1e-12)}
            if good_now < good_base * (1.0 - max_goodput_drop):
                row["problems"].append(
                    f"poisson_goodput_tokens_per_s {good_now:.1f} is "
                    f"{(1 - good_now / good_base) * 100:.1f}% below the "
                    f"median-of-priors {good_base:.1f} "
                    f"(threshold {max_goodput_drop * 100:.0f}%)")
        # Goodput under 1.5x overload with admission control on: same
        # whole-group-carries-it rule (entries from before the overload
        # section lack it).  A drop means either the engine got slower
        # under pressure or the admission estimator started shedding work
        # it could have served.
        ovl_key = "overload_goodput_tokens_per_s"
        if ovl_key in newest and all(ovl_key in p for p in priors):
            ovl_base = _median([p[ovl_key] for p in priors])
            ovl_now = newest[ovl_key]
            row["overload_goodput"] = {
                "baseline": ovl_base, "newest": ovl_now,
                "ratio": ovl_now / max(ovl_base, 1e-12)}
            if ovl_now < ovl_base * (1.0 - max_goodput_drop):
                row["problems"].append(
                    f"overload_goodput_tokens_per_s {ovl_now:.1f} is "
                    f"{(1 - ovl_now / ovl_base) * 100:.1f}% below the "
                    f"median-of-priors {ovl_base:.1f} "
                    f"(threshold {max_goodput_drop * 100:.0f}%)")
        if newest.get("overload_accounting_ok") is False:
            row["problems"].append(
                "newest run reports overload_accounting_ok=false — a "
                "submission ended in neither finished/shed/"
                "deadline_exceeded, or a shed lacked a backoff hint "
                "(fault-tolerance contract, not perf)")
        # KV bytes/token (pool page layout): only gate when the whole group
        # carries the key (entries from before the quantized-KV mode lack it)
        kb_key = "kv_bytes_per_token"
        if kb_key in newest and all(kb_key in p for p in priors):
            kb_base = _median([p[kb_key] for p in priors])
            kb_now = newest[kb_key]
            row["kv_bytes_per_token"] = {
                "baseline": kb_base, "newest": kb_now,
                "ratio": kb_now / max(kb_base, 1e-12)}
            if kb_now > kb_base * (1.0 + max_kv_bytes_rise):
                row["problems"].append(
                    f"kv_bytes_per_token {kb_now:.1f} is "
                    f"{(kb_now / kb_base - 1) * 100:.1f}% above the "
                    f"median-of-priors {kb_base:.1f} "
                    f"(threshold {max_kv_bytes_rise * 100:.0f}%)")
        if newest.get("tokens_match") is False:
            row["problems"].append("newest run reports tokens_match=false "
                                   "(correctness, not just perf)")
        if row["problems"]:
            row["status"] = "regressed"
        rows.append(row)
    return rows


def main(argv=None) -> int:
    default_hist = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_history.jsonl")
    ap = argparse.ArgumentParser()
    ap.add_argument("history", nargs="?", default=default_hist,
                    help="BENCH_history.jsonl path (default: repo root)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but always exit 0")
    ap.add_argument("--max-tok-drop", type=float, default=0.15,
                    help="max tolerated tokens_per_s_continuous drop "
                         "(fraction, default 0.15)")
    ap.add_argument("--max-step-rise", type=float, default=0.25,
                    help="max tolerated decode_step_ms_p50 rise "
                         "(fraction, default 0.25)")
    ap.add_argument("--max-goodput-drop", type=float, default=0.20,
                    help="max tolerated poisson_goodput_tokens_per_s drop "
                         "(fraction, default 0.20; only gated when every "
                         "entry in the group has the Poisson section)")
    ap.add_argument("--max-kv-bytes-rise", type=float, default=0.15,
                    help="max tolerated kv_bytes_per_token rise (fraction, "
                         "default 0.15; only gated when every entry in the "
                         "group has the key)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.history):
        print(f"[check_regression] no history at {args.history}; "
              f"nothing to gate")
        return 0
    entries = load_history(args.history)
    if not entries:
        print("[check_regression] empty history; nothing to gate")
        return 0

    rows = check(entries, args.max_tok_drop, args.max_step_rise,
                 args.max_goodput_drop, args.max_kv_bytes_rise)
    print(f"[check_regression] {len(entries)} history entries, "
          f"{len(rows)} (arch, attn_backend, kv_dtype, spec_tokens) groups")
    print(f"  {'arch':<24} {'backend':<10} {'kv':<5} {'K':>2} {'n':>3} "
          f"{'tok/s':>16} {'step_ms_p50':>16}  status")
    failed = False
    for r in rows:
        if r["status"] == "insufficient-history":
            tok = step = f"{'—':>16}"
        else:
            tok = (f"{r['tokens_per_s']['newest']:7.1f}/"
                   f"{r['tokens_per_s']['baseline']:<8.1f}")
            step = (f"{r['decode_step_ms_p50']['newest']:7.2f}/"
                    f"{r['decode_step_ms_p50']['baseline']:<8.2f}")
        print(f"  {r['arch']:<24} {r['attn_backend']:<10} "
              f"{r['kv_dtype']:<5} {r['spec_tokens']:>2} "
              f"{r['n_entries']:>3} {tok:>16} "
              f"{step:>16}  {r['status']}")
        if "poisson_goodput" in r:
            g = r["poisson_goodput"]
            print(f"    poisson goodput tok/s: {g['newest']:.1f} vs "
                  f"median-of-priors {g['baseline']:.1f} "
                  f"(ratio {g['ratio']:.2f})")
        if "overload_goodput" in r:
            g = r["overload_goodput"]
            print(f"    overload goodput tok/s: {g['newest']:.1f} vs "
                  f"median-of-priors {g['baseline']:.1f} "
                  f"(ratio {g['ratio']:.2f})")
        if "kv_bytes_per_token" in r:
            g = r["kv_bytes_per_token"]
            print(f"    kv bytes/token: {g['newest']:.1f} vs "
                  f"median-of-priors {g['baseline']:.1f} "
                  f"(ratio {g['ratio']:.2f})")
        for p in r["problems"]:
            print(f"    - {p}")
        if r["status"] == "regressed":
            failed = True

    if failed and not args.warn_only:
        print("[check_regression] FAIL: perf regression vs "
              "median-of-priors baseline")
        return 1
    if failed:
        print("[check_regression] regression detected but --warn-only set")
    else:
        print("[check_regression] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
