# One function per paper table/figure. Prints CSV rows (name,key=value,...).
"""Benchmark harness entry point:

  fig6  — unsupervised reconstruction error vs iteration   (paper Fig. 6)
  fig7  — supervised misclassification vs iteration        (paper Fig. 7)
  fig8  — MapReduce scaling: time vs #workers              (paper Fig. 8)
  roofline — 3-term roofline per (arch x shape x mesh) from the dry-run sweep

``--quick`` shrinks sizes so the full harness runs in a few minutes on CPU.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "fig6", "fig7", "fig8", "roofline"])
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()

    t0 = time.time()
    if args.only in (None, "fig6"):
        from . import fig6_unsup_error
        if args.quick:
            fig6_unsup_error.run(n_train=1024, n_test=256, epochs=4,
                                 stack=(784, 128, 32))
        else:
            fig6_unsup_error.run()
    if args.only in (None, "fig7"):
        from . import fig7_sup_error
        if args.quick:
            fig7_sup_error.run(n_train=1024, n_test=256, epochs=10,
                               stack=(784, 128))
        else:
            fig7_sup_error.run()
    if args.only in (None, "fig8"):
        from . import fig8_scaling
        fig8_scaling.run(worker_counts=(1, 2, 4, 8))
    if args.only in (None, "roofline"):
        from . import roofline
        roofline.run()
    print(f"benchmarks,total_s={time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
