"""RBM / DBN / autoencoder / classifier correctness on synthetic MNIST."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DBNConfig, RBMConfig, autoencoder, finetune, rbm,
                        train_dbn)
from repro.core.rbm import (cd_statistics, free_energy, getnegphase,
                            getposphase, make_rbm_step, rbm_init)
from repro.data import train_test


def test_cd_statistics_shapes_and_signs():
    cfg = RBMConfig(n_vis=20, n_hid=8)
    key = jax.random.PRNGKey(0)
    p = rbm_init(key, cfg)
    v = jax.random.uniform(jax.random.fold_in(key, 1), (16, 20))
    stats = cd_statistics(p, v, key, cfg)
    assert stats["W"].shape == (20, 8)
    assert stats["bv"].shape == (20,)
    assert stats["bh"].shape == (8,)
    assert jnp.isfinite(stats["err"])


def test_rbm_learning_reduces_reconstruction_error():
    cfg = RBMConfig(n_vis=784, n_hid=64, lr=0.1)
    key = jax.random.PRNGKey(0)
    X, _ = __import__("repro.data", fromlist=["dataset"]).dataset(512, seed=3)
    p = rbm_init(key, cfg)
    vel = jax.tree.map(jnp.zeros_like, p)
    step = make_rbm_step(cfg, None)
    errs = []
    for epoch in range(6):
        for b in range(0, 512, 128):
            key, sub = jax.random.split(key)
            p, vel, err = step(p, vel, jnp.asarray(X[b:b + 128]), sub, epoch)
        errs.append(float(err))
    assert errs[-1] < errs[0] * 0.7, errs


def test_free_energy_gap_data_vs_noise_widens():
    """Training must lower the free energy of data *relative to* noise (the
    absolute level is not monotone as weights grow)."""
    cfg = RBMConfig(n_vis=784, n_hid=32)
    key = jax.random.PRNGKey(1)
    X, _ = __import__("repro.data", fromlist=["dataset"]).dataset(256, seed=5)
    X = jnp.asarray(X)
    noise = jax.random.uniform(jax.random.fold_in(key, 9), X.shape)
    p = rbm_init(key, cfg)
    gap0 = float(jnp.mean(free_energy(p, X)) - jnp.mean(free_energy(p, noise)))
    vel = jax.tree.map(jnp.zeros_like, p)
    step = make_rbm_step(cfg, None)
    for epoch in range(5):
        key, sub = jax.random.split(key)
        p, vel, _ = step(p, vel, X, sub, epoch)
    gap1 = float(jnp.mean(free_energy(p, X)) - jnp.mean(free_energy(p, noise)))
    assert gap1 < gap0


def test_dbn_autoencoder_end_to_end():
    """Algorithm 1 + unroll + fine-tune: reconstruction error improves."""
    Xtr, ytr, Xte, yte = train_test(n_train=512, n_test=128, seed=0)
    cfg = DBNConfig(stack=(784, 128, 32), max_epoch=3, batch_size=128, lr=0.1)
    key = jax.random.PRNGKey(0)
    stack = train_dbn(Xtr, cfg, key)
    assert len(stack) == 2
    params = autoencoder.unroll(stack)
    err_pre = autoencoder.reconstruction_error(params, Xte)
    step = autoencoder.make_finetune_step(None, lr=0.02)
    vel = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    for e in range(4):
        for b in range(0, 512, 128):
            params, vel, loss, aux = step(params, vel,
                                          {"x": jnp.asarray(Xtr[b:b + 128])})
    err_post = autoencoder.reconstruction_error(params, Xte)
    assert err_post < err_pre, (err_pre, err_post)


def test_classifier_beats_chance():
    Xtr, ytr, Xte, yte = train_test(n_train=1024, n_test=256, seed=1)
    cfg = DBNConfig(stack=(784, 64), max_epoch=2, batch_size=128)
    key = jax.random.PRNGKey(0)
    stack = train_dbn(Xtr, cfg, key)
    params = finetune.classifier_init(stack, 10, key)
    step = finetune.make_classifier_step(None, lr=1.0)
    vel = jax.tree.map(jnp.zeros_like, params)
    for e in range(15):
        for b in range(0, 1024, 128):
            params, vel, loss, aux = step(
                params, vel, {"x": jnp.asarray(Xtr[b:b + 128]),
                              "y": jnp.asarray(ytr[b:b + 128])})
    err = finetune.error_rate(params, Xte, yte)
    assert err < 0.5, f"test error {err} (chance = 0.9)"
