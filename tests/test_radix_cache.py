"""Radix prefix-cache edge cases + admission atomicity.

* partial-page prefix match forks copy-on-write instead of sharing
* double-insert of an identical prompt takes no extra page references
* LRU eviction never frees a page a live owner still references
* preemption of a cache-hit request returns only exclusively-owned pages
* admission is all-or-nothing: a failed attempt mutates nothing
* shared-prefix workloads stay token-exact and leak-free end to end
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, ServeConfig, reduced
from repro.models.registry import init_params
from repro.serving import (Engine, PagedKVPool, RadixCache, generate_static)

PS = 8


def _cfg(name="qwen2-0.5b"):
    return dataclasses.replace(reduced(ARCHS[name]), remat="none")


def _pool(max_slots=2, max_len=64, num_pages=0):
    scfg = ServeConfig(page_size=PS, max_slots=max_slots, max_len=max_len,
                       num_pages=num_pages)
    return PagedKVPool(_cfg(), scfg)


def _toks(n, seed=0):
    return np.random.RandomState(seed).randint(1, 500, size=n).tolist()


# ------------------------------------------------------------- tree mechanics

def test_full_page_match_shares_pages():
    pool = _pool()
    cache = RadixCache(pool, PS)
    toks = _toks(2 * PS)
    pages = pool.alloc(2)
    cache.insert(toks, pages)
    assert [pool.ref(p) for p in pages] == [2, 2]   # owner + tree

    m = cache.match(toks + _toks(5, seed=1), max_match=2 * PS + 4)
    assert m.pages == pages and m.n_matched == 2 * PS
    assert m.cow_src is None and m.cow_len == 0
    # match alone must not take references — the caller commits
    assert [pool.ref(p) for p in pages] == [2, 2]


def test_partial_page_match_cows_not_shares():
    pool = _pool()
    cache = RadixCache(pool, PS)
    toks = _toks(2 * PS)
    pages = pool.alloc(2)
    cache.insert(toks, pages)

    # diverges 4 tokens into the second page: first page shareable, second
    # only reusable by forking its matched slots into an exclusive copy
    prompt = toks[:PS + 4] + [t + 1 for t in toks[PS + 4:]]
    m = cache.match(prompt, max_match=len(prompt) - 1)
    assert m.pages == [pages[0]]
    assert m.cow_src == pages[1] and m.cow_len == 4
    assert m.n_matched == PS + 4
    assert m.cow_src not in m.pages


def test_identical_prompt_match_is_clamped_to_cow():
    """A full re-match must leave >= 1 tail token, so the last page of an
    identical prompt comes back as a COW fork, not a share."""
    pool = _pool()
    cache = RadixCache(pool, PS)
    toks = _toks(2 * PS)
    pages = pool.alloc(2)
    cache.insert(toks, pages)
    m = cache.match(toks, max_match=len(toks) - 1)
    assert m.pages == [pages[0]]
    assert m.cow_src == pages[1] and m.cow_len == PS - 1
    assert m.n_matched == 2 * PS - 1


def test_double_insert_takes_no_extra_refs():
    pool = _pool()
    cache = RadixCache(pool, PS)
    toks = _toks(2 * PS)
    first = pool.alloc(2)
    assert cache.insert(toks, first) == 2
    # a second request with the identical prompt re-inserts its own pages
    second = pool.alloc(2)
    assert cache.insert(toks, second) == 0          # nothing new cached
    assert [pool.ref(p) for p in first] == [2, 2]   # unchanged
    assert [pool.ref(p) for p in second] == [1, 1]  # tree took nothing
    assert cache.num_nodes == 2


def test_lru_eviction_never_frees_live_pages():
    pool = _pool()
    cache = RadixCache(pool, PS)
    a, b, c = (pool.alloc(1) for _ in range(3))
    cache.insert(_toks(PS, seed=1), a)
    cache.insert(_toks(PS, seed=2), b)
    cache.insert(_toks(PS, seed=3), c)
    # a "slot" still owns a's page; c's node is pinned by a live match
    slot_pages = list(a)
    (n_c,) = cache.match(_toks(PS, seed=3) + [1], max_match=PS).nodes
    cache.lock([n_c])
    pool.release(a)          # original owners hand over; tree keeps refs
    pool.release(b)
    pool.release(c)
    pool.share(slot_pages)   # the live slot's reference on a

    free_before = pool.num_free
    assert cache.evict(3) == 2                  # a, b evicted; c locked
    assert pool.num_free == free_before + 1     # only b actually freed
    assert pool.ref(a[0]) == 1                  # live slot still owns it
    assert pool.ref(c[0]) == 1                  # locked node survived
    assert cache.num_nodes == 1
    cache.unlock([n_c])
    assert cache.evict(1) == 1
    pool.release(slot_pages)
    assert pool.num_allocated == 0


def test_eviction_is_lru_ordered():
    pool = _pool()
    cache = RadixCache(pool, PS)
    old, new = pool.alloc(1), pool.alloc(1)
    t_old, t_new = _toks(PS, seed=4), _toks(PS, seed=5)
    cache.insert(t_old, old)
    cache.insert(t_new, new)
    cache.match(t_old + [1], max_match=PS)      # refresh `old`
    pool.release(old)
    pool.release(new)
    cache.evict(1)
    assert cache.cached_pages == old            # `new` was the LRU victim


# -------------------------------------------------- engine-level invariants

def test_preemption_returns_only_exclusive_pages():
    cfg = _cfg()
    scfg = ServeConfig(page_size=PS, max_slots=2, max_len=48,
                       prefix_cache=True)
    eng = Engine(cfg, scfg, init_params(cfg, jax.random.PRNGKey(3)))
    prompt = _toks(2 * PS + 3, seed=6)          # 2 shareable pages + partial

    # request A publishes its prompt pages, runs to completion
    eng.add_request(prompt, max_new_tokens=2)
    while eng.step():
        pass
    eng.collect()
    tree_pages = set(eng.radix.cached_pages)
    assert len(tree_pages) == 2
    free_before = eng.pool.num_free

    # request B is a cache hit on the same prompt: both full pages shared
    # (A's partial last page was never cached, so B computes the 3-token tail)
    eng.add_request(prompt, max_new_tokens=8)
    assert eng.step()                           # the prefill
    slot = eng.sched.slots[0]
    assert slot is not None and slot.n_shared == 2
    assert slot.req.cached_tokens == 2 * PS

    eng.sched.preempt(0)
    # only B's exclusively-owned pages went back; shared ones stay cached
    assert eng.pool.num_free == free_before
    assert set(eng.radix.cached_pages) == tree_pages
    assert all(eng.pool.ref(p) == 1 for p in tree_pages)
    eng.sched.queue.clear()
    eng.radix.reset()
    assert eng.pool.num_allocated == 0


def test_admission_is_all_or_nothing():
    cfg = _cfg()
    # pool so tight a second long request cannot be admitted
    scfg = ServeConfig(page_size=PS, max_slots=2, max_len=32, num_pages=5,
                       prefix_cache=True)
    eng = Engine(cfg, scfg, init_params(cfg, jax.random.PRNGKey(4)))
    eng.add_request(_toks(25, seed=7), max_new_tokens=6)
    assert eng.step()                           # A admitted: 4 of 4 pages
    eng.add_request(_toks(26, seed=8), max_new_tokens=4)

    sched, pool = eng.sched, eng.pool
    before = (len(sched.queue), pool.num_free, pool.refcounts,
              eng.radix.num_nodes, [n.lock for n in eng.radix._walk()])
    assert sched.try_admit() is None            # needs 4 pages, 0 free
    after = (len(sched.queue), pool.num_free, pool.refcounts,
             eng.radix.num_nodes, [n.lock for n in eng.radix._walk()])
    # failed attempt took nothing — not even cache contents (the live slot
    # co-owns every cached page, so eviction could not have freed any)
    assert before == after
    # the scheduler falls back to decoding the live slot, not deadlock
    action = sched.next_action()
    assert action is not None and action[0] == "decode"

    while eng.step():                           # drains both (A frees pages)
        pass
    results = sorted(eng.collect(), key=lambda r: r.rid)
    ref, _ = generate_static(cfg, eng.params,
                             [r.prompt for r in results], [6, 4], scfg,
                             batch_size=1)
    assert [r.tokens for r in results] == ref
    eng.radix.reset()
    assert eng.pool.num_allocated == 0


def test_shared_prefix_workload_exact_and_leak_free():
    """Fixed-case version of the hypothesis suite (runs without hypothesis):
    shared-prefix mix, cache on vs off, token-exact, pool drained to zero."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(5))
    rng = np.random.RandomState(11)
    fams = [rng.randint(1, cfg.vocab, size=18).tolist() for _ in range(2)]
    prompts = [fams[i % 2] + rng.randint(1, cfg.vocab, size=1 + i).tolist()
               for i in range(6)]
    budgets = [5, 3, 6, 4, 2, 5]
    scfg = ServeConfig(page_size=PS, max_slots=3, max_len=48)
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    for pc in (False, True):
        scfg_i = dataclasses.replace(scfg, prefix_cache=pc)
        eng = Engine(cfg, scfg_i, params)
        results, metrics = eng.run_offline(prompts, budgets)
        assert [r.tokens for r in results] == ref
        assert (metrics["cached_tokens"] > 0) == pc
        if eng.radix is not None:
            eng.radix.reset()
        assert eng.pool.num_allocated == 0
        assert eng.pool.num_free == scfg_i.total_pages - 1
        assert eng.pool.refcounts == {}


def test_pool_share_release_refcounts():
    pool = _pool()
    (p,) = pool.alloc(1)
    pool.share([p])
    pool.share([p])
    assert pool.ref(p) == 3
    pool.release([p])
    pool.release([p])
    assert pool.ref(p) == 1 and pool.num_free == pool.scfg.total_pages - 2
    pool.release([p])
    assert pool.ref(p) == 0 and pool.num_allocated == 0
    with pytest.raises(AssertionError):
        pool.release([p])                       # double free
    with pytest.raises(AssertionError):
        pool.share([p])                         # share of unallocated page
