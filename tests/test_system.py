"""End-to-end behaviour tests for the paper's system: the full pipeline of
Fig. 2 — dedup -> RBM pre-training (MapReduce) -> unroll -> BP fine-tune ->
AdaBoost refinement — plus the LM train/serve drivers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import DBNConfig, adaboost, autoencoder, finetune, train_dbn
from repro.data import dedup, train_test


def test_paper_pipeline_end_to_end():
    """The complete Fig. 2 flow on synthetic MNIST (scaled down)."""
    Xtr, ytr, Xte, yte = train_test(n_train=768, n_test=192, seed=2,
                                    duplicate_frac=0.2)
    # stage 0 (paper §III-A): diversity-based dedup
    n_before = len(Xtr)
    Xd, yd = dedup(Xtr, ytr, max_dup=1)
    assert len(Xd) < n_before

    # stage 1 (paper §IV-A): greedy layer-wise RBM pre-training (Algorithm 1)
    cfg = DBNConfig(stack=(784, 96, 24), max_epoch=2, batch_size=128)
    stack = train_dbn(Xd, cfg, jax.random.PRNGKey(0))

    # stage 2 (paper §IV-B): supervised BP fine-tuning
    params = finetune.classifier_init(stack, 10, jax.random.PRNGKey(1))
    step = finetune.make_classifier_step(None, lr=1.0)
    vel = jax.tree.map(jnp.zeros_like, params)
    err_init = finetune.error_rate(params, Xte, yte)
    for e in range(10):
        for b in range(0, len(Xd) - 128, 128):
            params, vel, loss, aux = step(
                params, vel, {"x": jnp.asarray(Xd[b:b + 128]),
                              "y": jnp.asarray(yd[b:b + 128])})
    err_ft = finetune.error_rate(params, Xte, yte)
    assert err_ft < err_init, (err_init, err_ft)

    # stage 3 (paper §IV-C): AdaBoost precision refinement
    boost_cfg = adaboost.BoostConfig(n_rounds=3, epochs=2, n_hidden=32)
    learners, alphas = adaboost.fit(Xd, yd, boost_cfg, jax.random.PRNGKey(2))
    assert len(learners) >= 1
    err_boost = adaboost.error_rate(learners, alphas, Xte, yte)
    assert err_boost < 0.9   # beats chance


def test_lm_train_driver_loss_decreases():
    from repro.launch.train import main as train_main
    out = train_main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "12",
                      "--global-batch", "4", "--seq-len", "64",
                      "--lr", "3e-3"])
    hist = out["history"]
    assert len(hist) == 12
    assert hist[-1] < hist[0], hist   # synthetic bigram data is learnable


def test_lm_serve_driver_generates():
    from repro.launch.serve import main as serve_main
    # continuous engine, verified against the static single-request baseline
    gen = serve_main(["--arch", "qwen2-0.5b", "--reduced", "--requests", "2",
                      "--batch", "2", "--prompt-len", "16", "--gen", "4",
                      "--engine", "continuous", "--verify"])
    assert np.asarray(gen).shape == (2, 4)
    assert all(isinstance(t, int) for row in gen for t in row)


def test_mapreduce_engine_trains_lm():
    from repro.launch.train import main as train_main
    out = train_main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "6",
                      "--global-batch", "4", "--seq-len", "32",
                      "--engine", "mapreduce"])
    assert np.isfinite(out["final_loss"])
