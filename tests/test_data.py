"""Data pipeline: determinism, sharding, resume, dedup (paper §III-A)."""
import numpy as np

from repro.data import (Prefetcher, ShardedBatches, dataset, dedup,
                        duplicate_stats, token_batches)


def test_dataset_deterministic_and_labeled():
    X1, y1 = dataset(64, seed=3)
    X2, y2 = dataset(64, seed=3)
    np.testing.assert_array_equal(X1, X2)
    assert X1.shape == (64, 784) and y1.shape == (64,)
    assert X1.min() >= 0 and X1.max() <= 1
    assert set(np.unique(y1)) <= set(range(10))


def test_dedup_removes_exact_duplicates():
    X, y = dataset(200, seed=0, duplicate_frac=0.3)
    stats = duplicate_stats(X)
    assert stats["dup_frac"] > 0.05
    X2, y2 = dedup(X, y, max_dup=1)
    assert duplicate_stats(X2)["dup_frac"] == 0.0
    assert len(X2) < len(X)


def test_sharded_batches_cover_and_resume():
    X = np.arange(64, dtype=np.float32).reshape(64, 1)
    y = np.arange(64, dtype=np.int32)
    sb = ShardedBatches(X, y, global_batch=8, seed=1)
    b0 = sb.batch_at(0)
    b0_again = sb.batch_at(0)
    np.testing.assert_array_equal(b0["x"], b0_again["x"])  # pure function of step

    # shards partition the global batch
    sh0 = ShardedBatches(X, y, global_batch=8, seed=1, shard_index=0, shard_count=2)
    sh1 = ShardedBatches(X, y, global_batch=8, seed=1, shard_index=1, shard_count=2)
    a, b = sh0.batch_at(3)["y"], sh1.batch_at(3)["y"]
    both = np.concatenate([a, b])
    np.testing.assert_array_equal(np.sort(both), np.sort(sb.batch_at(3)["y"]))

    # resume: state roundtrip
    it = iter(sb)
    next(it); next(it)
    st = sb.state()
    sb2 = ShardedBatches(X, y, global_batch=8, seed=1)
    sb2.restore(st)
    np.testing.assert_array_equal(sb2.batch_at(sb2.step)["x"],
                                  sb.batch_at(sb.step)["x"])


def test_prefetcher_yields_same_stream():
    X = np.arange(32, dtype=np.float32).reshape(32, 1)
    sb1 = ShardedBatches(X, None, global_batch=4, seed=2)
    sb2 = ShardedBatches(X, None, global_batch=4, seed=2)
    it = iter(sb2)
    pf = Prefetcher(it)
    for i, item in zip(range(5), pf):
        np.testing.assert_array_equal(item["x"], sb1.batch_at(i)["x"])


def test_token_batches_deterministic_and_sharded():
    a = next(token_batches(100, 8, 16, seed=0))
    b = next(token_batches(100, 8, 16, seed=0))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    s0 = next(token_batches(100, 8, 16, seed=0, shard_index=0, shard_count=2))
    assert s0["tokens"].shape == (4, 16)
