"""Continuous serving across every cache family (see models.cache_spec).

* greedy-token parity vs the static single-request baseline for MLA latent
  pages, sliding-window page rings, SSM / RG-LRU state slots, and the
  enc-dec pinned cross cache (plus the vlm image-prefix variant)
* sliding-window requests hold O(window) pages no matter how long they
  generate (the pool is sized so unbounded growth would be impossible)
* state-slot lifetime: exactly one slot per live request, checkpoint-on-
  preempt restores mid-generation, accounting unwinds leak-free
* prefix-cache degradation: state-slot / windowed / frame-conditioned archs
  warn and serve uncached instead of raising
* batched prefill admission: same-bucket queued requests share one prefill
  call, counted by the multi_admit_prefills metric
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, ServeConfig, reduced
from repro.models import build_model
from repro.models.cache_spec import window_pages
from repro.models.registry import init_params
from repro.serving import Engine, generate_static

FAMILY_CASES = [
    "deepseek-v2-236b",        # paged MLA latent
    "command-r-plus-104b",     # windowed KV ring
    "starcoder2-7b",           # windowed KV ring (biased qkv, gelu mlp)
    "mamba2-780m",             # SSM state slots
    "recurrentgemma-2b",       # RG-LRU state slots + local-attention ring
    "seamless-m4t-large-v2",   # paged self KV + pinned cross cache
    "llava-next-34b",          # paged KV with an image-token prefix
]


def _cfg(name):
    return dataclasses.replace(reduced(ARCHS[name]), remat="none")


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, size=n).tolist() for n in lens]


def _leak_free(eng):
    if eng.radix is not None:
        eng.radix.reset()
    ok = (eng.pool.num_allocated == 0
          and eng.pool.num_free == eng.pool.total_pages - 1
          and all(s is None for s in eng.sched.slots))
    if eng.states is not None:
        ok = ok and eng.states.num_claimed == 0
    return ok


# ------------------------------------------------- continuous == static

@pytest.mark.parametrize("arch", FAMILY_CASES)
def test_family_matches_single_request_baseline(arch):
    cfg = _cfg(arch)
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48)
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = _prompts(cfg, [4, 30, 11, 7, 22, 15])
    budgets = [6, 4, 8, 5, 7, 3]

    eng = Engine(cfg, scfg, params, seed=1)
    results, metrics = eng.run_offline(prompts, budgets)
    got = [r.tokens for r in results]
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1, seed=1)
    assert got == ref
    assert metrics["new_tokens"] == sum(budgets)
    assert _leak_free(eng)


# ----------------------------------------------------- windowed families

def test_windowed_allocation_is_o_window():
    """A sliding-window request holds at most ``window_pages`` pages however
    long it generates: the pool here could not cover unbounded growth, yet
    nothing is preempted and tokens stay exact through the ring wrap."""
    cfg = _cfg("starcoder2-7b")           # reduced window 32
    ps = 8
    horizon = window_pages(cfg.sliding_window, ps)
    slots = 3
    scfg = ServeConfig(page_size=ps, max_slots=slots, max_len=64,
                       num_pages=slots * horizon + 1)
    params = init_params(cfg, jax.random.PRNGKey(2))
    # 44 > ring span: the prefill itself wraps; budgets decode past the ring
    prompts = _prompts(cfg, [10, 44, 25], seed=2)
    budgets = [50, 18, 30]
    eng = Engine(cfg, scfg, params)
    assert eng.pool.table_width == horizon
    results, _ = eng.run_offline(prompts, budgets)
    assert all(r.n_preemptions == 0 for r in results)
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    assert [r.tokens for r in results] == ref
    assert _leak_free(eng)


# ---------------------------------------------------- state-slot families

@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-2b"])
def test_state_slot_lifetime_and_checkpoint_restore(arch):
    """alloc -> checkpoint-on-preempt -> restore -> free: a mid-decode
    preemption snapshots the slot, re-admission restores it, and the final
    tokens still match the baseline with the earlier generations intact."""
    cfg = _cfg(arch)
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48)
    params = init_params(cfg, jax.random.PRNGKey(3))
    prompts = _prompts(cfg, [9, 14, 6], seed=3)
    eng = Engine(cfg, scfg, params)
    for p in prompts:
        eng.add_request(p, max_new_tokens=10)
    steps, preempted = 0, False
    while eng.step():
        steps += 1
        active = eng.sched.active_slots()
        # one slot claimed per live request, exactly
        assert eng.states.claimed == set(active)
        if steps == 4 and active and not preempted:
            victim = active[-1]
            before = list(eng.sched.slots[victim].req.generated)
            req = eng.sched.preempt(victim)
            preempted = True
            assert req.checkpoint is not None         # snapshot taken
            assert req.generated == before            # tokens survive
        assert steps < 500
    assert preempted and eng._restores == 1
    results = sorted(eng.collect(), key=lambda r: r.rid)
    assert sum(r.n_preemptions for r in results) == 1
    ref, _ = generate_static(cfg, params, prompts, 10, scfg, batch_size=1)
    assert [r.tokens for r in results] == ref
    assert _leak_free(eng)


def test_state_slot_pool_claim_release_invariants():
    from repro.serving import StateSlotPool
    cfg = _cfg("mamba2-780m")
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=32)
    pool = StateSlotPool(cfg, scfg)
    pool.claim(0)
    pool.claim(2)
    assert pool.num_claimed == 2 and pool.claimed == {0, 2}
    with pytest.raises(AssertionError):
        pool.claim(0)                     # double claim
    with pytest.raises(AssertionError):
        pool.release(1)                   # release of unclaimed
    with pytest.raises(AssertionError):
        pool.checkpoint(1)                # checkpoint of unclaimed
    snap = pool.checkpoint(0)
    pool.restore(0, snap)
    pool.release(0)
    pool.release(2)
    assert pool.num_claimed == 0


# ------------------------------------------------ prefix-cache degradation

@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-2b",
                                  "seamless-m4t-large-v2", "starcoder2-7b"])
def test_prefix_cache_degrades_gracefully(arch, capsys):
    """--prefix-cache on a non-token-addressable family logs one warning and
    serves uncached (exactly) instead of raising."""
    cfg = _cfg(arch)
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48,
                       prefix_cache=True)
    params = init_params(cfg, jax.random.PRNGKey(4))
    eng = Engine(cfg, scfg, params, seed=4)
    out = capsys.readouterr().out
    assert "prefix cache disabled" in out
    assert eng.radix is None
    prompts = _prompts(cfg, [8, 12], seed=4)
    results, metrics = eng.run_offline(prompts, 5)
    assert metrics["cached_tokens"] == 0
    ref, _ = generate_static(cfg, params, prompts, 5, scfg, batch_size=1,
                             seed=4)
    assert [r.tokens for r in results] == ref


def test_prefix_cache_still_works_on_mla():
    """MLA latent pages are token-addressable and immutable: the radix cache
    stays enabled and shared prefixes actually hit.

    Prompts stay <= 16 tokens so the MoE expert capacity never binds at any
    bucket: deepseek is MoE, and capacity-dropping depends on the prefill
    bucket, so a tail-bucketed cached prefill is only guaranteed to match
    the full-prompt static prefill in the no-drop regime (see the serving
    README's MoE + prefix-cache caveat)."""
    cfg = _cfg("deepseek-v2-236b")
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48,
                       prefix_cache=True)
    params = init_params(cfg, jax.random.PRNGKey(5))
    rng = np.random.RandomState(5)
    fam = rng.randint(1, cfg.vocab, size=8).tolist()    # one full KV page
    prompts = [fam + rng.randint(1, cfg.vocab, size=4).tolist()
               for _ in range(4)]
    eng = Engine(cfg, scfg, params, seed=5)
    assert eng.radix is not None
    results, metrics = eng.run_offline(prompts, 5)
    assert metrics["cached_tokens"] > 0
    ref, _ = generate_static(cfg, params, prompts, 5, scfg, batch_size=1,
                             seed=5)
    assert [r.tokens for r in results] == ref
    assert _leak_free(eng)


# ------------------------------------------------- batched prefill admission

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m"])
def test_batched_prefill_admission(arch):
    """Same-bucket queued requests are admitted in one prefill call; the
    engine counts those steps and output stays exact."""
    cfg = _cfg(arch)
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48)
    params = init_params(cfg, jax.random.PRNGKey(6))
    prompts = _prompts(cfg, [10, 12, 14, 9, 11, 13], seed=6)
    budgets = [6, 5, 7, 6, 5, 7]
    eng = Engine(cfg, scfg, params)
    results, metrics = eng.run_offline(prompts, budgets)
    assert metrics["multi_admit_prefills"] >= 1
    assert metrics["prefill_steps"] < len(prompts)    # batching happened
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    assert [r.tokens for r in results] == ref
    assert _leak_free(eng)


# ------------------------------------------------------------ cache specs

def test_cache_specs_cover_all_archs():
    expect = {
        "qwen2-0.5b": ("paged_kv",),
        "minitron-4b": ("paged_kv",),
        "dbrx-132b": ("paged_kv",),
        "deepseek-v2-236b": ("paged_mla",),
        "starcoder2-7b": ("windowed_kv",),
        "command-r-plus-104b": ("windowed_kv",),
        "mamba2-780m": ("state_slot",),
        "recurrentgemma-2b": ("state_slot", "state_slot"),
        "seamless-m4t-large-v2": ("paged_kv", "cross_kv"),
        "llava-next-34b": ("paged_kv",),
    }
    for name, kinds in expect.items():
        spec = build_model(reduced(ARCHS[name])).cache_spec()
        assert tuple(k.kind for k in spec.kinds) == kinds, name
        assert spec.paged == (kinds[0] != "state_slot"), name
    assert build_model(reduced(ARCHS["llava-next-34b"])).cache_spec() \
        .prefix_tokens > 0
    assert not build_model(reduced(ARCHS["command-r-plus-104b"])) \
        .cache_spec().prefix_cacheable
