"""Speculative decoding: small-q verify cores, proposer, and engine parity.

Five rungs of the speculation contract (``ServeConfig.speculate_tokens=K``):

1. *Verify-core parity* — the Pallas small-q ``verify_attend`` /
   ``mla_verify_attend`` kernels against the reference backend's XLA
   gather+mask oracle, swept over q_len 1..K, page sizes, GQA ratios,
   sliding-window rings, softcap, and int8 scale operands; dead query rows
   (``j >= n_q``) return exact zeros on every backend.
2. *q_len=1 degeneracy* — a verify step with no draft IS a decode step:
   the Pallas verify core at Q=1 reproduces the existing decode core
   bit-exactly (``assert_array_equal``, not allclose), bf16 and int8, so
   speculation can never perturb the non-speculative path it falls back to.
3. *Proposer + acceptance units* — ``NgramProposer`` (longest trailing
   n-gram, most recent occurrence, self-overlap, no-match), ``verify_meta``
   write targets (ring wrap, dead-row null-page routing), ``accept_length``
   planted divergence at every position, and the ``speculation_k`` family
   gate (state-slot and enc-dec families serve non-speculatively).
4. *Engine parity* — accepted tokens match the non-speculative greedy
   stream token-for-token across the three paged families x both backends
   x K in {2, 4, 8}, composed with the radix prefix cache, chunked
   prefill, the overlapped pump loop, and the int8 KV pool.
5. *Falsifiability* — a planted oracle proposer (drafts the true
   continuation) must accept everything and an anti-oracle (drafts
   guaranteed-wrong tokens, including rejects landing exactly on page
   boundaries) must accept nothing, while BOTH emit the identical token
   stream — acceptance bookkeeping and rollback are observable, not
   vacuous, and rejected drafts never poison the prefix cache.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ServeConfig, get_arch, reduced
from repro.models import build_model
from repro.models.attention import quantize_int8
from repro.models.attn_backend import get_backend, verify_meta
from repro.serving import (Engine, NgramProposer, accept_length,
                           speculation_k)

jax.config.update("jax_platform_name", "cpu")


def _cfg(name="qwen2-0.5b"):
    return dataclasses.replace(reduced(get_arch(name)), remat="none")


# ------------------------------------------------------- verify-core parity

def _tables(rng, B, maxp, P):
    perm = rng.permutation(np.arange(1, P))[:B * maxp]
    return jnp.asarray(perm.reshape(B, maxp), jnp.int32)


def _quant_pool(rng, P, ps, K, D):
    kf = rng.randn(P, ps, K, D).astype(np.float32)
    vf = rng.randn(P, ps, K, D).astype(np.float32)
    kq, ks = quantize_int8(jnp.asarray(kf))
    vq, vs = quantize_int8(jnp.asarray(vf))
    return kq, ks, vq, vs


VERIFY_CASES = [
    # (B, H, K, D, ps, maxp, window, softcap)
    (3, 4, 2, 32, 8, 5, 0, 0.0),       # GQA 2:1
    (2, 6, 1, 64, 16, 3, 0, 0.0),      # MQA
    (2, 4, 4, 16, 4, 6, 0, 0.0),       # MHA-ish, small pages
    (2, 4, 2, 32, 8, 5, 0, 30.0),      # logit softcap
    (3, 4, 2, 32, 8, 5, 20, 0.0),      # sliding-window ring
    (2, 4, 2, 32, 8, 4, 12, 0.0),      # tighter ring, window < page span
]


def _verify_inputs(rng, B, H, K, D, ps, maxp, Q):
    q = jnp.asarray(rng.randn(B, Q, H, D), jnp.float32)
    kp = jnp.asarray(rng.randn(4 * maxp, ps, K, D), jnp.float32)
    vp = jnp.asarray(rng.randn(4 * maxp, ps, K, D), jnp.float32)
    tables = _tables(rng, B, maxp, 4 * maxp)
    # row 0 keeps the degenerate fresh-sequence case (pos=0, single query);
    # the rest sit anywhere the Q-token window still fits the table span
    pos = np.concatenate([[0], rng.randint(1, maxp * ps - Q, size=B - 1)])
    n_q = np.concatenate([[1], rng.randint(1, Q + 1, size=B - 1)])
    return q, kp, vp, tables, jnp.asarray(pos, jnp.int32), \
        jnp.asarray(n_q, jnp.int32)


@pytest.mark.parametrize("Q", [1, 2, 3, 5])
@pytest.mark.parametrize("B,H,K,D,ps,maxp,window,softcap", VERIFY_CASES)
def test_verify_attend_matches_reference(B, H, K, D, ps, maxp, window,
                                         softcap, Q):
    rng = np.random.RandomState(B * 100 + ps + Q)
    q, kp, vp, tables, pos, n_q = _verify_inputs(rng, B, H, K, D, ps,
                                                 maxp, Q)
    scale = 1.0 / math.sqrt(D)
    ref = get_backend("reference").verify_attend(
        q, kp, vp, tables, pos, n_q, scale=scale, softcap=softcap,
        window=window)
    out = get_backend("pallas").verify_attend(
        q, kp, vp, tables, pos, n_q, scale=scale, softcap=softcap,
        window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)
    # dead query rows are exact zeros on both backends — the engine relies
    # on this to ignore the padded tail without masking on the host
    for arr in (np.asarray(ref, np.float32), np.asarray(out, np.float32)):
        for b in range(B):
            assert np.all(arr[b, int(n_q[b]):] == 0.0)


@pytest.mark.parametrize("Q", [1, 2, 4])
def test_int8_verify_attend_matches_reference(Q):
    B, H, K, D, ps, maxp = 3, 4, 2, 32, 8, 5
    rng = np.random.RandomState(10 + Q)
    q = jnp.asarray(rng.randn(B, Q, H, D), jnp.float32)
    kq, ks, vq, vs = _quant_pool(rng, 4 * maxp, ps, K, D)
    tables = _tables(rng, B, maxp, 4 * maxp)
    pos = jnp.asarray(np.concatenate(
        [[0], rng.randint(1, maxp * ps - Q, size=B - 1)]), jnp.int32)
    n_q = jnp.asarray(np.concatenate(
        [[1], rng.randint(1, Q + 1, size=B - 1)]), jnp.int32)
    scale = 1.0 / math.sqrt(D)
    ref = get_backend("reference").verify_attend(
        q, kq, vq, tables, pos, n_q, scale=scale, k_scale=ks, v_scale=vs)
    out = get_backend("pallas").verify_attend(
        q, kq, vq, tables, pos, n_q, scale=scale, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("Q", [1, 2, 4])
def test_mla_verify_attend_matches_reference(Q):
    B, H, L, R, ps, maxp = 3, 4, 16, 8, 8, 5
    P = 4 * maxp
    rng = np.random.RandomState(20 + Q)
    q_eff = jnp.asarray(rng.randn(B, Q, H, L), jnp.float32)
    q_rope = jnp.asarray(rng.randn(B, Q, H, R), jnp.float32)
    cc = jnp.asarray(rng.randn(P, ps, L), jnp.float32)
    cr = jnp.asarray(rng.randn(P, ps, R), jnp.float32)
    tables = _tables(rng, B, maxp, P)
    pos = jnp.asarray(np.concatenate(
        [[0], rng.randint(1, maxp * ps - Q, size=B - 1)]), jnp.int32)
    n_q = jnp.asarray(np.concatenate(
        [[1], rng.randint(1, Q + 1, size=B - 1)]), jnp.int32)
    scale = 1.0 / math.sqrt(L + R)
    ref = get_backend("reference").mla_verify_attend(
        q_eff, q_rope, cc, cr, tables, pos, n_q, scale=scale)
    out = get_backend("pallas").mla_verify_attend(
        q_eff, q_rope, cc, cr, tables, pos, n_q, scale=scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)


def test_int8_mla_verify_attend_matches_reference():
    B, H, L, R, ps, maxp, Q = 3, 4, 16, 8, 8, 5, 3
    P = 4 * maxp
    rng = np.random.RandomState(30)
    q_eff = jnp.asarray(rng.randn(B, Q, H, L), jnp.float32)
    q_rope = jnp.asarray(rng.randn(B, Q, H, R), jnp.float32)
    cq, cs = quantize_int8(jnp.asarray(rng.randn(P, ps, L), jnp.float32))
    rq, rs = quantize_int8(jnp.asarray(rng.randn(P, ps, R), jnp.float32))
    tables = _tables(rng, B, maxp, P)
    pos = jnp.asarray(np.concatenate(
        [[0], rng.randint(1, maxp * ps - Q, size=B - 1)]), jnp.int32)
    n_q = jnp.asarray(np.concatenate(
        [[1], rng.randint(1, Q + 1, size=B - 1)]), jnp.int32)
    scale = 1.0 / math.sqrt(L + R)
    ref = get_backend("reference").mla_verify_attend(
        q_eff, q_rope, cq, rq, tables, pos, n_q, scale=scale,
        ckv_scale=cs, krope_scale=rs)
    out = get_backend("pallas").mla_verify_attend(
        q_eff, q_rope, cq, rq, tables, pos, n_q, scale=scale,
        ckv_scale=cs, krope_scale=rs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)


# -------------------------------------------------------- q_len=1 degeneracy

QLEN1_CASES = [
    # (window, softcap, int8)
    (0, 0.0, False),
    (0, 30.0, False),
    (20, 0.0, False),
    (0, 0.0, True),
]


@pytest.mark.parametrize("window,softcap,int8", QLEN1_CASES)
def test_verify_qlen1_reproduces_decode_bitexact(window, softcap, int8):
    """A verify step with an empty draft must BE a decode step: same pool,
    same masks, same launch math — Pallas vs Pallas is checked bit-exact,
    reference vs reference to fp32 ulp (its two paths order the einsums
    differently)."""
    B, H, K, D, ps, maxp = 3, 4, 2, 32, 8, 5
    rng = np.random.RandomState(40 + window + int(softcap) + int8)
    q = jnp.asarray(rng.randn(B, H, D), jnp.float32)
    if int8:
        kp, ks, vp, vs = _quant_pool(rng, 4 * maxp, ps, K, D)
    else:
        kp = jnp.asarray(rng.randn(4 * maxp, ps, K, D), jnp.float32)
        vp = jnp.asarray(rng.randn(4 * maxp, ps, K, D), jnp.float32)
        ks = vs = None
    tables = _tables(rng, B, maxp, 4 * maxp)
    pos = jnp.asarray(np.concatenate(
        [[0], rng.randint(1, maxp * ps, size=B - 1)]), jnp.int32)
    ones = jnp.ones((B,), jnp.int32)
    scale = 1.0 / math.sqrt(D)
    kw = dict(scale=scale, softcap=softcap, window=window,
              k_scale=ks, v_scale=vs)
    pal = get_backend("pallas")
    np.testing.assert_array_equal(
        np.asarray(pal.verify_attend(q[:, None], kp, vp, tables, pos, ones,
                                     **kw)[:, 0]),
        np.asarray(pal.decode_attend(q, kp, vp, tables, pos, **kw)))
    ref = get_backend("reference")
    np.testing.assert_allclose(
        np.asarray(ref.verify_attend(q[:, None], kp, vp, tables, pos, ones,
                                     **kw)[:, 0], np.float32),
        np.asarray(ref.decode_attend(q, kp, vp, tables, pos, **kw),
                   np.float32),
        atol=1e-6, rtol=1e-6)


def test_mla_verify_qlen1_reproduces_decode_bitexact():
    B, H, L, R, ps, maxp = 3, 4, 16, 8, 8, 5
    P = 4 * maxp
    rng = np.random.RandomState(50)
    q_eff = jnp.asarray(rng.randn(B, H, L), jnp.float32)
    q_rope = jnp.asarray(rng.randn(B, H, R), jnp.float32)
    cc = jnp.asarray(rng.randn(P, ps, L), jnp.float32)
    cr = jnp.asarray(rng.randn(P, ps, R), jnp.float32)
    tables = _tables(rng, B, maxp, P)
    pos = jnp.asarray(np.concatenate(
        [[0], rng.randint(1, maxp * ps, size=B - 1)]), jnp.int32)
    ones = jnp.ones((B,), jnp.int32)
    scale = 1.0 / math.sqrt(L + R)
    pal = get_backend("pallas")
    np.testing.assert_array_equal(
        np.asarray(pal.mla_verify_attend(q_eff[:, None], q_rope[:, None],
                                         cc, cr, tables, pos, ones,
                                         scale=scale)[:, 0]),
        np.asarray(pal.mla_decode_attend(q_eff, q_rope, cc, cr, tables,
                                         pos, scale=scale)))


# ------------------------------------------------- proposer/acceptance units

def test_ngram_proposer_longest_match_wins():
    # trailing 3-gram (4,2,3) never recurs; 2-gram (2,3) does, at index 1,
    # so the draft is the two tokens that followed it
    assert NgramProposer(2).propose([1, 2, 3, 4, 2, 3]) == [4, 2]


def test_ngram_proposer_prefers_most_recent_occurrence():
    # (1,2) occurs at index 0 and index 3 — recency must pick index 3,
    # whose continuation is 7, not index 0's 9
    assert NgramProposer(1).propose([1, 2, 9, 1, 2, 7, 1, 2]) == [7]


def test_ngram_proposer_self_overlap_and_history_cap():
    # periodic text: the match's continuation runs into the suffix itself;
    # the proposer reads through the overlap but never fabricates tokens
    # past the end of the history
    assert NgramProposer(4).propose([1, 2, 1, 2, 1, 2]) == [1, 2]


def test_ngram_proposer_no_match_and_degenerate_histories():
    assert NgramProposer(3).propose([1, 2, 3, 4, 5]) == []
    assert NgramProposer(3).propose([7]) == []
    assert NgramProposer(3).propose([]) == []


def test_accept_length_planted_divergence_every_position():
    draft = [5, 6, 7, 8]
    assert accept_length(draft, [5, 6, 7, 8]) == 4
    for j in range(4):
        verified = list(draft)
        verified[j] += 1
        assert accept_length(draft, verified) == j
    assert accept_length([], []) == 0


def test_verify_meta_write_targets_and_dead_rows():
    cfg = _cfg()
    tables = np.asarray([[3, 5, 7], [4, 6, 8]], np.int32)
    pos = np.asarray([5, 0], np.int32)
    n_q = np.asarray([3, 1], np.int32)
    meta = verify_meta(cfg, 4, tables, pos, n_q, 3)
    # row 0: positions 5,6,7 all land in table column 1 -> page 5
    np.testing.assert_array_equal(meta["write_page"][0], [5, 5, 5])
    np.testing.assert_array_equal(meta["write_off"][0], [1, 2, 3])
    # row 1: only query 0 is live; the dead tail routes to the null page
    np.testing.assert_array_equal(meta["write_page"][1], [4, 0, 0])
    assert meta["write_off"][1][0] == 0


def test_verify_meta_ring_wraps_at_table_width():
    cfg = dataclasses.replace(_cfg(), sliding_window=8)
    tables = np.asarray([[11, 13]], np.int32)
    meta = verify_meta(cfg, 4, tables, np.asarray([7], np.int32),
                       np.asarray([2], np.int32), 2)
    # positions 7, 8 -> columns 1, 2 % 2 = 0: the ring recycles column 0
    np.testing.assert_array_equal(meta["write_page"][0], [13, 11])
    np.testing.assert_array_equal(meta["write_off"][0], [3, 0])


def test_speculation_k_family_gate():
    scfg = ServeConfig(page_size=8, max_len=32, speculate_tokens=4)
    for arch, want in [("qwen2-0.5b", 4), ("starcoder2-7b", 4),
                       ("deepseek-v2-236b", 4), ("mamba2-780m", 0),
                       ("recurrentgemma-2b", 0),
                       ("seamless-m4t-large-v2", 0)]:
        cfg = _cfg(arch)
        spec = build_model(cfg).cache_spec()
        assert speculation_k(cfg, spec, scfg) == want, arch
        assert speculation_k(cfg, spec,
                             dataclasses.replace(scfg,
                                                 speculate_tokens=0)) == 0


# ------------------------------------------------------------- engine parity

def _prompts(cfg, rng, n=3, rep=True):
    """Mixed workload: repetitive prompts (prompt-lookup's best case, so the
    run exercises real acceptance) plus iid-random ones (accept ~0)."""
    out = []
    for i in range(n):
        if rep and i % 2 == 0:
            motif = rng.randint(1, cfg.vocab, size=4).tolist()
            out.append((motif * 4)[:14])
        else:
            out.append(rng.randint(1, cfg.vocab, size=12).tolist())
    return out


ENGINE_CASES = [
    # (arch, attn_backend, K) — three paged families x backends x K
    ("qwen2-0.5b", "reference", 2),
    ("qwen2-0.5b", "reference", 8),
    ("qwen2-0.5b", "pallas", 4),
    ("starcoder2-7b", "reference", 4),
    ("starcoder2-7b", "pallas", 2),
    ("deepseek-v2-236b", "reference", 4),
    ("deepseek-v2-236b", "pallas", 4),
]


@pytest.mark.parametrize("arch,attn_backend,K", ENGINE_CASES)
def test_engine_speculative_token_identity(arch, attn_backend, K):
    """The absolute contract: the speculative engine's emitted stream is
    token-for-token the non-speculative greedy stream."""
    cfg = _cfg(arch)
    rng = np.random.RandomState(60)
    prompts = _prompts(cfg, rng)
    ps = 16 if K >= 8 else 8
    base = ServeConfig(page_size=ps, max_slots=2, max_len=3 * ps + ps,
                       attn_backend=attn_backend)
    eng = Engine(cfg, dataclasses.replace(base, speculate_tokens=K), seed=0)
    assert eng.spec_k == K
    res, m = eng.run_offline(prompts, 12)
    assert m["spec_tokens"] == K and m["spec_proposed"] > 0
    ref, _ = Engine(cfg, base, eng.params, seed=0).run_offline(prompts, 12)
    assert [r.tokens for r in res] == [r.tokens for r in ref]


@pytest.mark.parametrize("attn_backend", ["reference", "pallas"])
def test_speculation_composes_cache_and_chunking(attn_backend):
    """Radix prefix sharing + Sarathi chunked prefill + speculation stay
    token-exact against the plain uncached non-speculative engine."""
    cfg = _cfg()
    rng = np.random.RandomState(61)
    fam = (rng.randint(1, cfg.vocab, size=4).tolist() * 5)[:18]
    prompts = [fam + rng.randint(1, cfg.vocab, size=4).tolist()
               for _ in range(4)]
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48,
                       prefix_cache=True, prefill_chunk_tokens=8,
                       speculate_tokens=3, attn_backend=attn_backend)
    eng = Engine(cfg, scfg, seed=0)
    res, m = eng.run_offline(prompts, 8)
    assert m["cached_tokens"] > 0 and m["spec_proposed"] > 0
    plain = ServeConfig(page_size=8, max_slots=2, max_len=48,
                        attn_backend=attn_backend)
    ref, _ = Engine(cfg, plain, eng.params, seed=0).run_offline(prompts, 8)
    assert [r.tokens for r in res] == [r.tokens for r in ref]


def test_speculation_under_overlap_pump():
    """The pipelined pump() loop emits the same stream as synchronous
    step() under speculation (staging auto-disables for verify steps)."""
    cfg = _cfg()
    rng = np.random.RandomState(62)
    prompts = _prompts(cfg, rng)
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=32,
                       speculate_tokens=2)
    eng = Engine(cfg, scfg, seed=0)
    sync, _ = eng.run_offline(prompts, 8)
    ovl, _ = Engine(cfg, scfg, eng.params, seed=0).run_offline(
        prompts, 8, overlap=True)
    assert [r.tokens for r in sync] == [r.tokens for r in ovl]


@pytest.mark.parametrize("attn_backend", ["reference", "pallas"])
def test_int8_speculative_token_identity(attn_backend):
    """Speculation composes with the quantized pool: int8+spec matches
    int8 non-spec exactly (same pool contents -> same argmax stream)."""
    cfg = _cfg()
    rng = np.random.RandomState(63)
    prompts = _prompts(cfg, rng)
    base = ServeConfig(page_size=8, max_slots=2, max_len=32,
                       kv_dtype="int8", attn_backend=attn_backend)
    eng = Engine(cfg, dataclasses.replace(base, speculate_tokens=4), seed=0)
    res, m = eng.run_offline(prompts, 10)
    assert m["spec_proposed"] > 0
    ref, _ = Engine(cfg, base, eng.params, seed=0).run_offline(prompts, 10)
    assert [r.tokens for r in res] == [r.tokens for r in ref]


def test_state_family_serves_non_speculatively():
    """ssm has no paged pool: the engine must quietly gate speculation off
    (spec_k == 0, no proposer) and serve the stream unchanged."""
    cfg = _cfg("mamba2-780m")
    rng = np.random.RandomState(64)
    prompts = [rng.randint(1, cfg.vocab, size=8).tolist() for _ in range(2)]
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=32,
                       speculate_tokens=4)
    eng = Engine(cfg, scfg, seed=0)
    assert eng.spec_k == 0 and eng.proposer is None
    res, m = eng.run_offline(prompts, 6)
    assert "spec_tokens" not in m
    ref, _ = Engine(cfg, dataclasses.replace(scfg, speculate_tokens=0),
                    eng.params, seed=0).run_offline(prompts, 6)
    assert [r.tokens for r in res] == [r.tokens for r in ref]


# ----------------------------------------------- falsifiability and rollback

class _Oracle:
    """Planted proposer: drafts the TRUE greedy continuation (learned from
    a baseline run), matched to the request by its prompt prefix."""

    def __init__(self, k, prompts, continuations):
        self.k = k
        self.plan = [(list(p), list(c))
                     for p, c in zip(prompts, continuations)]

    def propose(self, tokens):
        toks = list(tokens)
        for p, cont in self.plan:
            if toks[:len(p)] == p:
                g = len(toks) - len(p)
                return cont[g:g + self.k]
        return []


class _AntiOracle(_Oracle):
    """Drafts guaranteed-WRONG tokens: every draft position differs from
    the true continuation, so greedy verify must reject all of them."""

    def __init__(self, k, prompts, continuations, vocab):
        super().__init__(k, prompts, continuations)
        self.vocab = vocab

    def propose(self, tokens):
        return [(t + 1) % self.vocab for t in super().propose(tokens)]


def test_oracle_accepts_everything_anti_oracle_accepts_nothing():
    """Both planted proposers must reproduce the exact baseline stream;
    only the acceptance counters distinguish them.  An accept/rollback bug
    cannot pass both: over-accepting corrupts the anti-oracle stream,
    under-accepting shows up as oracle accepted < proposed."""
    cfg = _cfg()
    rng = np.random.RandomState(65)
    prompts = [rng.randint(1, cfg.vocab, size=int(n)).tolist()
               for n in rng.randint(6, 13, size=3)]
    base = ServeConfig(page_size=8, max_slots=2, max_len=32)
    ref_eng = Engine(cfg, base, seed=0)
    ref, _ = ref_eng.run_offline(prompts, 8)
    conts = [r.tokens for r in ref]

    scfg = dataclasses.replace(base, speculate_tokens=3)
    eng = Engine(cfg, scfg, ref_eng.params, seed=0)
    eng.proposer = _Oracle(eng.spec_k, prompts, conts)
    res, m = eng.run_offline(prompts, 8)
    assert [r.tokens for r in res] == conts
    assert m["spec_proposed"] > 0
    assert m["spec_accepted"] == m["spec_proposed"]
    assert m["spec_accept_rate"] == 1.0

    eng = Engine(cfg, scfg, ref_eng.params, seed=0)
    eng.proposer = _AntiOracle(eng.spec_k, prompts, conts, cfg.vocab)
    res, m = eng.run_offline(prompts, 8)
    assert [r.tokens for r in res] == conts
    assert m["spec_proposed"] > 0
    assert m["spec_accepted"] == 0


def test_full_accept_page_boundary_growth():
    """With the oracle every step emits K+1 tokens, so positions jump past
    page boundaries mid-step (page_size=4, K=3 -> one full page per step):
    the scheduler must have granted pages for pos..pos+K up front or the
    verify write lands on a clamped/null page and the stream diverges."""
    cfg = _cfg()
    rng = np.random.RandomState(66)
    prompts = [rng.randint(1, cfg.vocab, size=10).tolist()
               for _ in range(2)]
    base = ServeConfig(page_size=4, max_slots=2, max_len=32)
    ref_eng = Engine(cfg, base, seed=0)
    ref, _ = ref_eng.run_offline(prompts, 12)
    conts = [r.tokens for r in ref]
    eng = Engine(cfg, dataclasses.replace(base, speculate_tokens=3),
                 ref_eng.params, seed=0)
    eng.proposer = _Oracle(eng.spec_k, prompts, conts)
    res, m = eng.run_offline(prompts, 12)
    assert [r.tokens for r in res] == conts
    assert m["spec_accepted"] == m["spec_proposed"] > 0


def test_rejected_draft_on_page_boundary_never_reaches_radix():
    """Satellite regression: prompt length 10 with page_size=4 puts the
    first verify step's rejected drafts at positions 11..13 — position 12
    IS a page boundary.  Later identical prompts then restore from the
    radix cache; if rollback had published draft-polluted pages, their
    streams would diverge from the uncached baseline."""
    cfg = _cfg()
    rng = np.random.RandomState(67)
    fam = rng.randint(1, cfg.vocab, size=10).tolist()
    prompts = [list(fam) for _ in range(4)]
    base = ServeConfig(page_size=4, max_slots=2, max_len=32)
    ref_eng = Engine(cfg, base, seed=0)
    ref, _ = ref_eng.run_offline(prompts, 8)
    conts = [r.tokens for r in ref]
    scfg = dataclasses.replace(base, prefix_cache=True, speculate_tokens=3)
    eng = Engine(cfg, scfg, ref_eng.params, seed=0)
    eng.proposer = _AntiOracle(eng.spec_k, prompts, conts, cfg.vocab)
    res, m = eng.run_offline(prompts, 8)
    assert m["cached_tokens"] > 0          # the cache actually restored
    assert m["spec_proposed"] > 0 and m["spec_accepted"] == 0
    assert [r.tokens for r in res] == conts
