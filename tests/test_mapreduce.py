"""MapReduce engine invariants.

The central invariant (the paper's correctness claim): the distributed
map/combine/reduce gradient equals the single-device gradient on the same
global batch, for every reduce mode.  Multi-device cases run in a subprocess
with forced host devices so the main test process keeps 1 device."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

WORKER = textwrap.dedent("""
    import os, json, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.mapreduce import mapreduce_value_and_grad
    from repro.launch.mesh import make_host_mesh

    mode = sys.argv[1]
    mesh = make_host_mesh(data=4, pod=2)

    def loss_fn(params, batch):
        y = batch["x"] @ params["w"] + params["b"]
        l = jnp.mean(jnp.square(y - batch["y"]))
        return l, {}

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (16, 4)),
              "b": jnp.zeros((4,))}
    batch = {"x": jax.random.normal(jax.random.fold_in(key, 1), (32, 16)),
             "y": jax.random.normal(jax.random.fold_in(key, 2), (32, 4))}

    # single-device reference
    (ref_l, _), ref_g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    mr = mapreduce_value_and_grad(loss_fn, mesh, reduce_mode=mode, n_micro=2)
    err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), params) \\
        if mode == "compressed" else None
    loss, grads, new_err, aux = jax.jit(mr)(params, batch, err)

    out = {
        "loss_err": float(abs(loss - ref_l)),
        "grad_err": float(max(jnp.max(jnp.abs(a - b))
                              for a, b in zip(jax.tree.leaves(grads),
                                              jax.tree.leaves(ref_g)))),
        "mode": mode,
    }
    print("RESULT" + json.dumps(out))
""")


def run_worker(mode: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", WORKER, mode],
                          capture_output=True, text=True, timeout=300, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.parametrize("mode", ["allreduce", "hierarchical"])
def test_distributed_grad_equals_serial(mode):
    out = run_worker(mode)
    assert out["loss_err"] < 1e-5, out
    assert out["grad_err"] < 1e-5, out


def test_compressed_grad_close_to_serial():
    out = run_worker("compressed")
    # int8 quantization: bounded error, not exact
    assert out["loss_err"] < 1e-5, out
    assert out["grad_err"] < 0.05, out


def test_map_reduce_job_single_device():
    """On a 1-device mesh the generic job degrades to plain eval."""
    from repro.core.mapreduce import map_reduce_job
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=1)
    job = map_reduce_job(lambda p, b: {"s": jnp.sum(b["x"] * p)},
                         mesh, reduce="mean")
    out = jax.jit(job)(2.0, {"x": jnp.arange(4.0)})
    assert float(out["s"]) == 12.0
