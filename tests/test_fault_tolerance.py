"""Fault-injection, recovery, and overload-control coverage.

The contract under test is **exact-survivor recovery**: whatever fault is
injected (poisoned logits, raised step errors, page-pool pressure, client
disconnects), the engine quarantines only the offending request — failed
terminally, pages scrubbed and released, trace closed — while every other
request's tokens stay byte-identical to a fault-free run.  On top of that:

* cancel mid-prefill releases the unpublished page tail (pool conservation)
* deadline-aware admission sheds at the door with a backoff hint and evicts
  expired requests mid-flight (queued and bound)
* the health state machine walks starting → healthy → draining → drained
  and refuses invalid transitions
* the watchdog fails pending streams when the pipeline stops progressing
  (driven by a detok_stall fault) instead of hanging clients
* an HTTP client disconnect mid-stream leaves the other streams byte-exact
"""
import asyncio
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, ServeConfig, reduced
from repro.models.registry import init_params
from repro.serving import (AdmissionController, Engine, FaultPlan,
                           HealthState, ServingLoop, generate_static,
                           stream_request, validate_trace)


def _cfg(name="qwen2-0.5b"):
    return dataclasses.replace(reduced(ARCHS[name]), remat="none")


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, size=n).tolist() for n in lens]


def _baseline(cfg, params, prompts, budgets, scfg):
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    return ref


def _check_survivors(results, ref, targeted):
    """targeted: rid -> expected error substring."""
    for r in results:
        if r.rid in targeted:
            assert r.failed and targeted[r.rid] in r.error, (r.rid, r.error)
            # partial output is a strict prefix of the clean baseline
            assert r.tokens == ref[r.rid][:len(r.tokens)], r.rid
        else:
            assert not r.failed, (r.rid, r.error)
            assert r.tokens == ref[r.rid], r.rid


# ------------------------------------------------- quarantine per fault kind

def test_nan_logits_quarantine_survivors_exact():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48)
    prompts = _prompts(cfg, [6, 14, 9, 20], seed=1)
    budgets = [8, 6, 8, 5]
    plan = FaultPlan.parse("nan_logits:rid=2,at=2")
    eng = Engine(cfg, scfg, params, faults=plan)
    results, _ = eng.run_offline(prompts, budgets)

    assert plan.unfired() == []
    _check_survivors(results, _baseline(cfg, params, prompts, budgets, scfg),
                     {2: "nan_logits"})
    # the poisoned request produced exactly `at` tokens before quarantine
    assert len(results[2].tokens) == 2
    assert eng.metrics.value("engine.quarantined") == 1
    assert eng.metrics.get("engine.faults_injected").labels(
        kind="nan_logits").value == 1
    # its pages were NaN-scrubbed before returning to the free list
    assert eng.metrics.value("pool.pages_scrubbed") >= 1
    assert eng.pool.num_allocated == 0 and eng.pool.conservation_ok()
    assert validate_trace(eng.tracer.to_dict()) == []


def test_step_error_quarantine_survivors_exact():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48)
    prompts = _prompts(cfg, [6, 14, 9, 20], seed=2)
    budgets = [8, 6, 8, 5]
    plan = FaultPlan.parse("step_error:rid=0,at=3")
    eng = Engine(cfg, scfg, params, faults=plan)
    results, _ = eng.run_offline(prompts, budgets)

    assert plan.unfired() == []
    _check_survivors(results, _baseline(cfg, params, prompts, budgets, scfg),
                     {0: "step_error"})
    assert eng.metrics.value("engine.quarantined") == 1
    assert eng.pool.num_allocated == 0 and eng.pool.conservation_ok()


def test_pool_pressure_all_requests_survive_exact():
    """Hostage pages force eviction/preemption churn (and possibly an
    injector-resolved deadlock) but nobody fails and tokens stay exact."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=32, num_pages=9)
    prompts = _prompts(cfg, [7, 15, 9, 12], seed=3)
    budgets = [9, 8, 10, 7]
    plan = FaultPlan.parse("pool_pressure:at=3,pages=4,steps=4")
    eng = Engine(cfg, scfg, params, faults=plan)
    results, _ = eng.run_offline(prompts, budgets)

    assert plan.unfired() == []
    _check_survivors(results, _baseline(cfg, params, prompts, budgets, scfg),
                     {})
    assert eng.pool.num_allocated == 0 and eng.pool.conservation_ok()


def test_client_disconnect_cancels_only_target():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48)
    prompts = _prompts(cfg, [6, 14, 9], seed=4)
    budgets = [8, 8, 8]
    plan = FaultPlan.parse("client_disconnect:rid=1,at=2")
    eng = Engine(cfg, scfg, params, faults=plan)
    results, _ = eng.run_offline(prompts, budgets)

    assert plan.unfired() == []
    _check_survivors(results, _baseline(cfg, params, prompts, budgets, scfg),
                     {1: "cancelled"})
    assert eng.metrics.value("engine.cancelled") == 1
    assert eng.pool.num_allocated == 0 and eng.pool.conservation_ok()


def test_fault_plan_parse_rejects_bad_specs():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("frobnicate:rid=1")
    with pytest.raises(ValueError, match="unknown fault field"):
        FaultPlan.parse("nan_logits:rid=1,bogus=2")
    with pytest.raises(ValueError, match="at >= 1"):
        FaultPlan.parse("nan_logits:rid=1,at=0")
    with pytest.raises(ValueError, match="empty fault plan"):
        FaultPlan.parse(" ; ")


# ------------------------------------------------------- cancel mid-prefill

def test_cancel_mid_prefill_releases_unpublished_tail():
    """Cancel between prefill chunks: the pages holding the already-filled
    chunks are not yet radix-published and must still return to the pool."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=64,
                       prefill_chunk_tokens=8)
    eng = Engine(cfg, scfg, params)
    long_prompt = _prompts(cfg, [30], seed=5)[0]      # 4 chunks of 8
    rid = eng.add_request(long_prompt, 8)
    assert eng.step()                                 # first chunk only
    assert eng.pool.num_allocated > 0                 # mid-prefill, holding
    eng.cancel(rid)
    for _ in range(8):
        if not eng.step():
            break
    (res,) = eng.collect()
    assert res.failed and "cancelled" in res.error
    assert eng.pool.num_allocated == 0 and eng.pool.conservation_ok()
    # pool-conservation counters: everything allocated was released
    assert (eng.metrics.value("pool.pages_allocated")
            == eng.metrics.value("pool.pages_released"))
    assert validate_trace(eng.tracer.to_dict()) == []


# -------------------------------------------------- deadlines and admission

def _adm_engine(**scfg_kw):
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48,
                       admission_control=True, **scfg_kw)
    return cfg, params, scfg, Engine(cfg, scfg, params)


def test_admission_sheds_hopeless_deadline_with_backoff_hint():
    cfg, params, scfg, eng = _adm_engine()
    p = _prompts(cfg, [6], seed=6)[0]
    rid = eng.add_request(p, 4, deadline_s=1e-6)      # < step-time prior
    (res,) = eng.collect()
    assert res.rid == rid and res.failed
    assert "shed" in res.error and "overloaded" in res.error
    assert res.retry_after_s > 0
    assert res.tokens == []
    assert eng.metrics.get("admission.shed").labels(
        reason="overloaded").value == 1
    # no-deadline requests are never shed by the estimator
    rid2 = eng.add_request(p, 4)
    results, _ = eng.run_offline([], [])              # drain what's live
    assert eng.metrics.value("engine.deadline_evictions") == 0


def test_deadline_eviction_queued_and_live():
    cfg, params, scfg, eng = _adm_engine()
    prompts = _prompts(cfg, [6, 9, 7], seed=7)
    r0 = eng.add_request(prompts[0], 12, deadline_s=120.0)
    r1 = eng.add_request(prompts[1], 12, deadline_s=120.0)
    r2 = eng.add_request(prompts[2], 12, deadline_s=120.0)  # queued (2 slots)
    eng.step()
    # force expiry deterministically rather than racing wall-clock: one
    # queued victim and one bound victim; everything else keeps its 120 s
    past = time.perf_counter() - 1.0
    assert eng.sched.queue                            # r2 still waiting
    eng.sched.queue[-1].deadline = past
    live_slot = next(s for s in eng.sched.slots if s is not None)
    live_slot.req.deadline = past
    while eng.step():
        pass
    results = {r.rid: r for r in eng.collect()}
    expired = [r for r in results.values()
               if r.failed and "deadline_exceeded" in r.error]
    assert len(expired) == 2                          # one queued + one live
    assert eng.metrics.value("engine.deadline_evictions") == 2
    assert eng.pool.num_allocated == 0 and eng.pool.conservation_ok()
    assert validate_trace(eng.tracer.to_dict()) == []


def test_admission_controller_estimates():
    adm = AdmissionController(max_slots=4, step_s_prior=0.05)
    assert adm.estimate_queue_wait(0) == 0.0
    assert adm.check(0) is None                       # no deadline: admit
    assert adm.check(0, deadline_s=1e-6) == "overloaded"
    # calibration: observed service time drives the wave estimate
    for _ in range(8):
        adm.observe_result(ttft_s=0.1, service_s=1.0)
    assert adm.estimate_queue_wait(4) == pytest.approx(1.0)
    assert adm.estimate_queue_wait(5) == pytest.approx(2.0)
    assert adm.check(5, deadline_s=10.0) is None
    assert adm.check(5, deadline_s=2.5) == "overloaded"
    hint = adm.retry_after_s(5)
    assert 0.05 <= hint <= 45.0                       # jittered, bounded


# ----------------------------------------------------- health state machine

def test_health_state_machine_transitions():
    h = HealthState()
    assert h.state == "starting" and h.accepting
    assert h.mark_healthy()
    assert not h.mark_healthy()                       # idempotent
    assert h.begin_drain()
    assert not h.mark_healthy()                       # no way back
    assert h.draining and not h.accepting
    assert h.mark_drained()
    assert h.history == ["starting", "healthy", "draining", "drained"]
    assert not h.mark_degraded("too late")            # terminal
    d = h.to_dict()
    assert d["state"] == "drained" and d["ok"] is False


def test_draining_engine_sheds_new_requests():
    cfg, params, scfg, eng = _adm_engine()
    eng.health.mark_healthy()
    eng.health.begin_drain()
    rid = eng.add_request(_prompts(cfg, [5], seed=8)[0], 4)
    (res,) = eng.collect()
    assert res.failed and "draining" in res.error and res.retry_after_s > 0


# ------------------------------------------------ watchdog via detok stall

def test_watchdog_fails_pending_streams_on_stalled_pipeline():
    """A detok_stall fault wedges the bounded event queue; the watchdog
    must fail the pending stream with a terminal error instead of letting
    the client hang, and mark the server degraded."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48)
    warm = Engine(cfg, scfg, params)                  # jit warm-up run
    warm.run_offline(_prompts(cfg, [6], seed=9), 4)

    plan = FaultPlan.parse("detok_stall:at=2,stall_s=3.0")
    eng = Engine(cfg, scfg, params, faults=plan)

    async def main():
        serving = ServingLoop(eng, overlap=True, collect_queue_size=1,
                              watchdog_s=0.5)
        await serving.start()
        try:
            events = await asyncio.wait_for(
                stream_request(serving, _prompts(cfg, [6], seed=9)[0], 16,
                               timeout_s=60.0),
                timeout=60.0)
        finally:
            await serving.stop()
        return events

    events = asyncio.run(main())
    assert plan.unfired() == []
    final = events[-1]
    assert final["type"] == "error" and "watchdog" in final["error"]
    assert eng.metrics.value("server.watchdog_trips") == 1
    assert eng.health.state == "degraded"


# --------------------------------------------- HTTP disconnect mid-stream

def test_http_client_disconnect_survivors_byte_exact():
    """Three streaming HTTP clients; one drops mid-stream.  The survivors'
    streamed tokens stay byte-identical to the static baseline, the
    abandoned request's pages are freed, and the trace stays well-formed."""
    from repro.launch.serve_http import HttpFrontend, _sse_client

    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=48)
    eng = Engine(cfg, scfg, params)
    prompts = _prompts(cfg, [6, 13, 9], seed=10)
    budgets = [6, 24, 8]                              # rid 1 drops early

    async def main():
        serving = ServingLoop(eng, overlap=True)
        frontend = HttpFrontend(serving)
        await serving.start()
        server = await asyncio.start_server(frontend.handle, "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        try:
            outs = await asyncio.wait_for(asyncio.gather(
                _sse_client("127.0.0.1", port, prompts[0], budgets[0]),
                _sse_client("127.0.0.1", port, prompts[1], budgets[1],
                            disconnect_after=2),
                _sse_client("127.0.0.1", port, prompts[2], budgets[2]),
            ), timeout=300.0)
            # wait for the engine to notice the dead socket and drain
            deadline = time.monotonic() + 60.0
            while (eng.sched.has_work() or not serving._submit.empty()) \
                    and time.monotonic() < deadline:
                await asyncio.sleep(0.02)
        finally:
            server.close()
            await server.wait_closed()
            await serving.stop()
        return outs

    outs = asyncio.run(main())
    ref = _baseline(cfg, params, prompts, budgets, scfg)
    for i in (0, 2):
        assert outs[i]["final"]["type"] == "done"
        assert outs[i]["streamed"] == ref[i], f"survivor {i} diverged"
    # the dropped client saw a clean prefix before walking away
    assert outs[1]["streamed"] == ref[1][:len(outs[1]["streamed"])]
    assert eng.metrics.value("engine.cancelled") == 1
    assert eng.pool.num_allocated == 0 and eng.pool.conservation_ok()
    assert validate_trace(eng.tracer.to_dict()) == []
