"""Checkpoint/restore roundtrip, async save, GC, and resumable training."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import all_steps, latest_step, restore, save
from repro.runtime import LoopConfig, TrainLoop


def _tree(key):
    return {"a": jax.random.normal(key, (8, 4)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)},
            "scalar": jnp.float32(3.5)}


def test_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 7, t, extra={"cursor": 7})
    t2, step, extra = restore(str(tmp_path), t)
    assert step == 7 and extra["cursor"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    threads = [save(str(tmp_path), s, t, _async=True) for s in (1, 2, 3, 4, 5)]
    for th in threads:
        th.join()
    steps = all_steps(str(tmp_path))
    assert len(steps) <= 3 and steps[-1] == 5     # keep=3 GC
    assert latest_step(str(tmp_path)) == 5


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path / "nope"), {"a": jnp.zeros(2)})


def test_train_loop_resume(tmp_path):
    """Crash after N steps; a fresh loop resumes from the checkpoint and sees
    the identical data stream (deterministic resume contract)."""
    def data():
        step = 0
        while True:
            yield {"v": jnp.full((4,), float(step))}
            step += 1

    def step_fn(state, batch):
        # state counts the sum of seen batch values: order-sensitive
        new = state + float(batch["v"][0])
        return new, {"loss": 0.1}

    cfg = LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=5, async_save=False,
                     log_every=0)
    loop1 = TrainLoop(step_fn, jnp.float32(0.0), data(), cfg)
    loop1.run(7)   # checkpoints at 5; runs to 7 (final save at 7)

    loop2 = TrainLoop(step_fn, jnp.float32(0.0), data(), cfg)
    assert loop2.step == 7
    loop2.run(3)
    # 0+1+...+9 = 45
    assert float(loop2.state) == sum(range(10))


def test_nan_guard_skips_poisoned_steps(tmp_path):
    def data():
        step = 0
        while True:
            yield {"step": step}
            step += 1

    def step_fn(state, batch):
        bad = batch["step"] == 1
        return state + 1, {"loss": float("nan") if bad else 1.0}

    loop = TrainLoop(step_fn, 0, data(), LoopConfig(log_every=0))
    out = loop.run(4)
    assert loop.state == 3          # step 1 skipped, state not advanced
    assert loop.step == 4
