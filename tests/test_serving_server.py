"""Streaming front-end + request-lifecycle bugfix coverage.

* graceful zero-budget rejection: a too-long prompt surfaces as a failed
  RequestResult mid-batch (counted under ``sched.rejections``) while the
  rest of the batch drains token-exact; only a rid collision raises
* preemption does not reset TTFT: the legacy ``ttft`` agrees with the
  tracer-sourced ``ttft_s`` even for preempted-and-replayed requests
* the decode-stall accumulator is flushed on drain and reset between runs
* the overlapped pipeline (``Engine.pump`` / ``run_offline(overlap=True)``)
  is token-exact with staged plans actually consumed
* ``ServingLoop`` streams every token exactly once, in order, token-exact
  vs the static baseline; rejection and cancellation surface as terminal
  error events; traces with rejected requests validate clean
"""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, ServeConfig, reduced
from repro.models.registry import init_params
from repro.serving import (Engine, ServingLoop, generate_static,
                           stream_request, validate_trace)


def _cfg(name="qwen2-0.5b"):
    return dataclasses.replace(reduced(ARCHS[name]), remat="none")


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, size=n).tolist() for n in lens]


# ----------------------------------------------- request-lifecycle bugfixes

def test_zero_budget_rejected_mid_batch_others_drain():
    """One hopeless prompt in a batch must not strand the others: it comes
    back failed, they come back token-exact."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=16)
    good = _prompts(cfg, [5, 9, 4], seed=1)
    too_long = list(range(1, 17))            # len == max_len: zero budget
    eng = Engine(cfg, scfg, params)
    results, metrics = eng.run_offline(
        [good[0], too_long, good[1], good[2]], [4, 4, 4, 4])

    bad = [r for r in results if r.failed]
    ok = [r for r in results if not r.failed]
    assert len(bad) == 1 and bad[0].rid == 1
    assert "no_budget" in bad[0].error and bad[0].tokens == []
    assert metrics["rejected_requests"] == 1
    reject = eng.metrics.get("sched.rejections").labels(reason="no_budget")
    assert reject.value == 1

    ref, _ = generate_static(cfg, params, good, 4, scfg, batch_size=1)
    assert [r.tokens for r in ok] == ref


def test_rid_collision_is_the_only_add_request_raise():
    cfg = _cfg()
    eng = Engine(cfg, ServeConfig(page_size=8, max_slots=2, max_len=32),
                 init_params(cfg, jax.random.PRNGKey(0)))
    p = _prompts(cfg, [6], seed=2)[0]
    eng.add_request(p, 4, rid=7)
    with pytest.raises(ValueError, match="collides"):
        eng.add_request(p, 4, rid=7)
    # a fresh rid is fine, and a rejected rid is still in flight (it holds
    # a pending failed result) until collected
    eng.add_request(list(range(1, 40)), 4, rid=8)     # zero budget: rejected
    with pytest.raises(ValueError, match="collides"):
        eng.add_request(p, 4, rid=8)
    eng.collect()
    eng.add_request(p, 4, rid=8)                      # collectable again


def test_preemption_does_not_reset_ttft():
    """TTFT is the time to the first token *ever* produced: a preemption
    replay regenerates the same prefix and must not move it.  The legacy
    wall-clock ``ttft`` and the tracer-sourced ``ttft_s`` must agree."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(8))
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=32, num_pages=7)
    prompts = _prompts(cfg, [7, 15, 9, 12], seed=9)
    budgets = [9, 8, 10, 7]
    eng = Engine(cfg, scfg, params)
    results, _ = eng.run_offline(prompts, budgets)
    assert sum(r.n_preemptions for r in results) > 0   # pressure was real
    for r in results:
        assert r.ttft == pytest.approx(r.ttft_s, rel=1e-6, abs=1e-9), r.rid
        assert r.ttft <= r.latency


def test_stall_accumulator_flushed_on_drain_and_reset_between_runs():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(3))
    eng = Engine(cfg, ServeConfig(page_size=8, max_slots=2, max_len=32),
                 params)
    # drain flush: trailing stall behind the last non-decode step must land
    # in the histogram when the engine goes idle, not evaporate
    eng._stall_accum = 0.5
    assert eng.step() is False                 # idle -> flush
    assert eng._stall_accum == 0.0
    assert 0.5 in eng._h_stall.values
    # reset between runs: a stale accumulator must not leak into the next
    # run's stall accounting
    eng._stall_accum = 123.0
    results, metrics = eng.run_offline(_prompts(cfg, [5, 9, 14], seed=4), 4)
    assert eng._stall_accum == 0.0
    assert all(v < 123.0 for v in eng._h_stall.values)


# ------------------------------------------------------- overlapped pipeline

def test_overlap_run_offline_token_exact_and_staging_used():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(5))
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48)
    prompts = _prompts(cfg, [3, 30, 11, 7, 22, 15], seed=6)
    budgets = [6, 4, 8, 5, 7, 3]
    eng = Engine(cfg, scfg, params)
    results, _ = eng.run_offline(prompts, budgets, overlap=True)
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    assert [r.tokens for r in results] == ref
    staged = eng.metrics.value("engine.overlap_staged")
    used = eng.metrics.value("engine.overlap_used")
    dropped = eng.metrics.value("engine.overlap_dropped")
    assert staged > 0 and used > 0             # the pipeline actually staged
    assert used + dropped == staged            # every plan is accounted for
    # host-pipeline spans made it into the trace (dispatch every step,
    # stage only on staged steps)
    trace = eng.tracer.to_dict()
    from repro.serving.telemetry import ENGINE_PID, HOST_TID
    host = [e for e in trace["traceEvents"]
            if e.get("pid") == ENGINE_PID and e.get("tid") == HOST_TID
            and e.get("ph") == "X"]
    names = {e["name"] for e in host}
    assert {"dispatch", "stage", "collect"} <= names
    assert validate_trace(trace) == []


def test_preemption_under_pressure_overlap_still_exact():
    """Staged plans must be invalidated by preemption/admission churn, not
    replayed stale: the pressure workload stays exact under pump()."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(8))
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=32, num_pages=7)
    prompts = _prompts(cfg, [7, 15, 9, 12], seed=9)
    budgets = [9, 8, 10, 7]
    eng = Engine(cfg, scfg, params)
    results, _ = eng.run_offline(prompts, budgets, overlap=True)
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    assert [r.tokens for r in results] == ref
    assert sum(r.n_preemptions for r in results) > 0


# --------------------------------------------------------- streaming server

def _serving_engine(seed=0, max_len=48, slots=4, num_pages=None):
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    kw = {"num_pages": num_pages} if num_pages else {}
    scfg = ServeConfig(page_size=8, max_slots=slots, max_len=max_len, **kw)
    return cfg, params, scfg, Engine(cfg, scfg, params)


def test_serving_loop_streams_token_exact():
    cfg, params, scfg, eng = _serving_engine(seed=11)
    prompts = _prompts(cfg, [4, 18, 9, 13, 6], seed=12)
    budgets = [5, 7, 4, 6, 8]

    async def main():
        serving = ServingLoop(eng, overlap=True, collect_queue_size=4)
        await serving.start()
        try:
            streams = await asyncio.gather(*[
                stream_request(serving, p, g, timeout_s=300.0)
                for p, g in zip(prompts, budgets)])
        finally:
            await serving.stop()
        return streams

    streams = asyncio.run(main())
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    for events, want in zip(streams, ref):
        toks = [e for e in events if e["type"] == "token"]
        done = events[-1]
        assert done["type"] == "done"
        # every token exactly once, in order, each matching the baseline
        assert [e["index"] for e in toks] == list(range(len(want)))
        assert [e["token"] for e in toks] == want
        assert done["tokens"] == want
        assert done["text"] == "".join(f"<{t}>" for t in want)
        assert [e["text"] for e in toks] == [f"<{t}>" for t in want]
        assert done["ttft_s"] <= done["finish_s"]


def test_serving_loop_rejection_and_cancel_events():
    cfg, params, scfg, eng = _serving_engine(seed=13, max_len=16, slots=2)

    async def main():
        serving = ServingLoop(eng, overlap=True)
        await serving.start()
        try:
            # zero-budget prompt -> terminal error event, no tokens
            rejected = await stream_request(
                serving, list(range(1, 17)), 4, timeout_s=300.0)
            # live cancel: wait for the first token, then disconnect
            rid, q = serving.submit(_prompts(cfg, [5], seed=14)[0],
                                    max_new_tokens=12)
            first = await asyncio.wait_for(q.get(), timeout=300.0)
            serving.cancel(rid)
            while True:
                last = await asyncio.wait_for(q.get(), timeout=300.0)
                if last["type"] in ("done", "error"):
                    break
            serving.forget(rid)
        finally:
            await serving.stop()
        return rejected, first, last

    rejected, first, last = asyncio.run(main())
    assert len(rejected) == 1
    assert rejected[0]["type"] == "error"
    assert "no_budget" in rejected[0]["error"]
    assert first["type"] == "token" and first["index"] == 0
    assert last["type"] == "error" and "cancelled" in last["error"]
    # the cancelled request released its slot and pages
    assert eng.pool.num_allocated == 0


def test_trace_with_rejection_validates_clean():
    """A rejected rid reaches a terminal event ("rejected"), so the
    well-formedness checker must accept traces containing them."""
    cfg, params, scfg, eng = _serving_engine(seed=15, max_len=16, slots=2)
    eng.add_request(list(range(1, 17)), 4)            # rejected
    eng.run_offline(_prompts(cfg, [5, 9], seed=16), 4)
    trace = eng.tracer.to_dict()
    assert validate_trace(trace) == []
    rejected = [e for e in trace["traceEvents"]
                if e.get("ph") == "i" and e.get("name") == "rejected"]
    assert len(rejected) == 1
