"""Telemetry subsystem: metrics registry, conservation invariants, tracing.

* registry primitives — counters / gauges / histograms / labeled families,
  idempotent registration, snapshot shape
* conservation after every run_offline drain (plain, prefix-cache,
  mid-prefill preemption): ``pool.pages_allocated == pool.pages_released +
  pool.pages_live`` and ``radix.hit_tokens + radix.miss_tokens ==
  radix.lookup_tokens``
* trace well-formedness (validate_trace finds nothing on real runs, and
  does find planted defects), per-request result fields sourced from the
  tracer, trace_report's per-phase sums covering wall clock
* token-exactness with tracing on: telemetry must never change a token
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import ServeConfig, get_arch, reduced
from repro.launch.trace_report import phase_breakdown, report, request_rows
from repro.models.registry import init_params
from repro.serving import Engine, generate_static
from repro.serving.telemetry import (
    ENGINE_PID, REQUEST_PID, SHARED_METRIC_KEYS, MetricsRegistry, Tracer,
    percentile, shared_metrics, validate_trace)

jax.config.update("jax_platform_name", "cpu")


def _cfg(name="qwen2-0.5b"):
    return dataclasses.replace(reduced(get_arch(name)), remat="none")


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, size=n).tolist() for n in lens]


# ----------------------------------------------------------- registry basics

def test_registry_primitives():
    m = MetricsRegistry()
    c = m.counter("c", "a counter")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(AssertionError):
        c.inc(-1)                          # counters are monotonic

    g = m.gauge("g", "a gauge")
    g.set(7)
    g.dec(3)
    g.inc()
    assert g.value == 5

    h = m.histogram("h", "a histogram")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count == 4 and h.total == 10.0 and h.max == 4.0
    assert h.percentile(50) == pytest.approx(2.5)

    lab = m.counter("admits", "by kind", labels=("kind",))
    lab.labels(kind="fresh").inc(2)
    lab.labels(kind="restore").inc()
    assert lab.labels(kind="fresh").value == 2

    snap = m.snapshot()
    assert snap["counters"]["c"] == 5
    assert snap["gauges"]["g"] == 5
    assert snap["histograms"]["h"]["count"] == 4
    assert snap["counters"]['admits{kind=fresh}'] == 2
    json.dumps(snap)                       # snapshot is JSON-serializable


def test_registry_idempotent_and_type_checked():
    m = MetricsRegistry()
    c1 = m.counter("x", "first")
    c2 = m.counter("x", "second registration returns the same object")
    assert c1 is c2
    with pytest.raises(AssertionError):
        m.gauge("x", "same name, different kind")


def test_percentile_edge_cases():
    assert percentile([], 50) == 0.0
    assert percentile([3.0], 50) == 3.0
    assert percentile([1.0, 2.0, 3.0], 0) == 1.0
    assert percentile([1.0, 2.0, 3.0], 100) == 3.0


def test_shared_metrics_schema_is_closed():
    out = shared_metrics(2, 10, [0.1, 0.2], 0.5)
    assert set(out) == set(SHARED_METRIC_KEYS)


# ------------------------------------------------- conservation invariants

def _assert_conserved(eng):
    snap = eng.metrics_snapshot()
    c, g = snap["counters"], snap["gauges"]
    assert c["pool.pages_allocated"] == \
        c["pool.pages_released"] + g["pool.pages_live"]
    if "radix.lookup_tokens" in c:
        assert c["radix.hit_tokens"] + c["radix.miss_tokens"] == \
            c["radix.lookup_tokens"]
        assert c["radix.partial_hit_tokens"] <= c["radix.hit_tokens"]
    return snap


def test_conservation_plain_drain():
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=48)
    eng = Engine(cfg, scfg, seed=0)
    eng.run_offline(_prompts(cfg, [5, 21, 12, 9]), 6)
    snap = _assert_conserved(eng)
    # no radix cache: every allocated page was released at retirement
    assert snap["gauges"]["pool.pages_live"] == 0
    assert snap["gauges"]["sched.slots_live"] == 0
    assert snap["gauges"]["sched.queue_depth"] == 0
    assert snap["counters"]["pool.pages_allocated"] > 0


def test_conservation_prefix_cache_drain():
    """With the radix cache the tree legitimately keeps pages live after the
    drain; conservation must hold with those counted, and reset() must bring
    live back to zero."""
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=48,
                       prefix_cache=True)
    eng = Engine(cfg, scfg, seed=0)
    shared = _prompts(cfg, [24], seed=1)[0]
    prompts = [shared + p for p in _prompts(cfg, [6, 3, 9, 5], seed=2)]
    results, _ = eng.run_offline(prompts, 5)
    snap = _assert_conserved(eng)
    assert snap["counters"]["radix.hit_tokens"] > 0
    assert snap["gauges"]["pool.pages_live"] > 0        # the tree's pages
    assert snap["gauges"]["radix.cached_pages"] == \
        len(eng.sched.radix.cached_pages)
    eng.sched.radix.reset()
    snap = _assert_conserved(eng)
    assert snap["gauges"]["pool.pages_live"] == 0
    assert snap["gauges"]["radix.cached_pages"] == 0


def test_conservation_mid_prefill_preemption():
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=64, num_pages=10,
                       prefill_chunk_tokens=8)
    eng = Engine(cfg, scfg, seed=0)
    results, _ = eng.run_offline(_prompts(cfg, [40, 35, 22, 17], seed=7),
                                 [20, 18, 12, 9])
    assert sum(r.n_preemptions for r in results) > 0    # pressure was real
    snap = _assert_conserved(eng)
    assert snap["gauges"]["pool.pages_live"] == 0
    pre = [v for k, v in snap["counters"].items()
           if k.startswith("sched.preemptions")]
    assert sum(pre) == sum(r.n_preemptions for r in results)


def test_admission_counters_label_kinds():
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48,
                       prefix_cache=True)
    eng = Engine(cfg, scfg, seed=0)
    shared = _prompts(cfg, [16], seed=3)[0]
    prompts = [shared + p for p in _prompts(cfg, [4, 6, 8], seed=4)]
    eng.run_offline(prompts, 4)
    c = eng.metrics_snapshot()["counters"]
    admits = sum(v for k, v in c.items() if k.startswith("sched.admissions"))
    assert admits >= len(prompts)
    assert c.get("sched.admissions{kind=cache_hit}", 0) > 0
    assert c["sched.queued"] == len(prompts)


# --------------------------------------------------------- tracing / report

def test_trace_well_formed_and_request_fields():
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=64,
                       prefill_chunk_tokens=16)
    eng = Engine(cfg, scfg, seed=0)
    prompts = _prompts(cfg, [40, 7, 23, 11], seed=5)
    results, metrics = eng.run_offline(prompts, 6)
    trace = eng.tracer.to_dict()
    assert validate_trace(trace) == []

    # per-request result fields are tracer-sourced and consistent
    for r in results:
        assert 0 < r.ttft_s <= r.finish_s
        assert r.n_prefill_chunks >= 1
        assert r.preempted == (r.n_preemptions > 0)
    long_rid = max(range(len(prompts)), key=lambda i: len(prompts[i]))
    assert results[long_rid].n_prefill_chunks > 1       # 40 toks / 16 budget

    rows = request_rows(trace)
    assert [row["rid"] for row in rows] == sorted(r.rid for r in results)
    by_rid = {row["rid"]: row for row in rows}
    for r in results:
        assert by_rid[r.rid]["ttft_s"] == pytest.approx(r.ttft_s)
        assert by_rid[r.rid]["n_tokens"] == len(r.tokens)

    # every engine step produced exactly one engine-track span
    # (chunked_prefill_steps is a subset of prefill_steps, not additive)
    steps = [e for e in trace["traceEvents"]
             if e.get("ph") == "X" and e.get("pid") == ENGINE_PID]
    assert len(steps) == metrics["prefill_steps"] \
        + metrics["decode_steps"] + metrics["state_restores"]
    assert metrics["chunked_prefill_steps"] > 0         # 40 toks / 16 budget


def test_trace_phase_sums_cover_wall_clock():
    """Acceptance bar: per-phase durations + host gap reconstruct the wall
    clock within 10%."""
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=48)
    eng = Engine(cfg, scfg, seed=0)
    _, metrics = eng.run_offline(_prompts(cfg, [9, 25, 14, 6], seed=6), 5)
    bd = phase_breakdown(eng.tracer.to_dict())
    covered = sum(bd["per_phase_s"].values()) + bd["other_s"] + bd["host_s"]
    assert covered == pytest.approx(bd["wall_s"], rel=1e-6)
    assert bd["wall_s"] <= metrics["wall_s"] * 1.10
    assert bd["wall_s"] >= metrics["wall_s"] * 0.50     # spans are real
    text = report(eng.tracer.to_dict())
    assert "time in phase" in text and "decode" in text


def test_tracing_is_token_invariant():
    """Telemetry on (default) vs tracer disabled: identical tokens."""
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48)
    params = init_params(cfg, jax.random.PRNGKey(2))
    prompts = _prompts(cfg, [5, 17, 9], seed=8)
    on, _ = Engine(cfg, scfg, params).run_offline(prompts, 5)
    off_eng = Engine(cfg, scfg, params, tracer=Tracer(enabled=False))
    off, _ = off_eng.run_offline(prompts, 5)
    assert [r.tokens for r in on] == [r.tokens for r in off]
    assert off_eng.tracer.events == []                  # truly off
    ref, _ = generate_static(cfg, params, prompts, 5, scfg, batch_size=1)
    assert [r.tokens for r in on] == ref


def test_validate_trace_catches_planted_defects():
    def ev(**kw):
        base = {"ph": "X", "pid": ENGINE_PID, "tid": 0, "name": "s",
                "ts": 0.0, "dur": 10.0, "args": {}}
        base.update(kw)
        return base

    assert validate_trace({"traceEvents": [ev()]}) == []
    assert validate_trace({"traceEvents": [ev(ts=-5.0)]})       # negative ts
    assert validate_trace({"traceEvents": [ev(dur=-1.0)]})      # negative dur
    assert validate_trace({"traceEvents": [ev(ts=float("nan"))]})
    # partial overlap on one track: [0, 10] vs [5, 15]
    assert validate_trace({"traceEvents": [ev(), ev(ts=5.0, dur=10.0)]})
    # admitted request that never finishes
    orphan = ev(pid=REQUEST_PID, tid=3, name="queued")
    assert any("never reached" in p
               for p in validate_trace({"traceEvents": [orphan]}))
    # proper nesting [0, 10] containing [2, 6] is fine
    assert validate_trace(
        {"traceEvents": [ev(), ev(ts=2.0, dur=4.0)]}) == []


def test_generate_static_emits_shared_schema():
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48)
    params = init_params(cfg, jax.random.PRNGKey(3))
    prompts = _prompts(cfg, [6, 11, 9, 4], seed=9)
    _, sm = generate_static(cfg, params, prompts, 5, scfg, batch_size=2)
    assert set(sm) == set(SHARED_METRIC_KEYS)
    assert sm["ttft_p50_s"] > 0
    assert sm["prefill_steps"] == 2                     # 4 prompts / batch 2
    assert sm["decode_steps"] > 0
    assert sm["prefill_padded_tokens"] >= sm["prefill_actual_tokens"]
    # engine metrics are a superset of the shared schema
    eng = Engine(cfg, scfg, params)
    _, em = eng.run_offline(prompts, 5)
    assert set(SHARED_METRIC_KEYS) <= set(em)
