"""Property-based serving invariants (hypothesis).

Random request mixes — shared-prefix families, mixed prompt/suffix lengths,
mixed token budgets, varying slot counts and pool sizes (including pools
tight enough to force preemption), prefix cache on and off — must all:

* produce token-for-token the greedy output of the static single-request
  baseline (``generate_static(batch_size=1)``),
* report per-request ``cached_tokens`` consistent with the cache setting,
* leave the pool leak-free after ``run_offline`` (+ a cache ``reset``):
  ``num_free`` restored, no allocated pages, every refcount zero.

One fixed ArchConfig keeps the jitted steps (cached per config) shared
across examples, so hypothesis explores scheduling/caching state spaces, not
XLA compile times.  A non-hypothesis fixed-case twin of this suite lives in
``test_radix_cache.py::test_shared_prefix_workload_exact_and_leak_free``.
"""
import dataclasses

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCHS, ServeConfig, reduced  # noqa: E402
from repro.models.registry import init_params  # noqa: E402
from repro.serving import Engine, generate_static  # noqa: E402

settings.register_profile("serving", max_examples=10, deadline=None)
settings.load_profile("serving")

PS = 8
MAX_LEN = 48          # 6 pages/request
CFG = dataclasses.replace(reduced(ARCHS["qwen2-0.5b"]), remat="none")
_PARAMS = None


def _params():
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = init_params(CFG, jax.random.PRNGKey(0))
    return _PARAMS


@st.composite
def workloads(draw):
    rng = np.random.RandomState(draw(st.integers(0, 2**31 - 1)))
    n_requests = draw(st.integers(1, 6))
    n_families = draw(st.integers(1, 3))
    prefix_len = draw(st.integers(0, 20))
    fams = [rng.randint(1, CFG.vocab, size=prefix_len).tolist()
            for _ in range(n_families)]
    prompts, budgets = [], []
    for i in range(n_requests):
        suffix = int(rng.randint(1, 11))
        prompts.append(fams[i % n_families]
                       + rng.randint(1, CFG.vocab, size=suffix).tolist())
        budgets.append(draw(st.integers(1, 6)))
    max_slots = draw(st.sampled_from([2, 4]))
    # 0 = ample pool; 13 = 2 requests' worth (+null page) -> page pressure
    num_pages = draw(st.sampled_from([0, 13]))
    prefix_cache = draw(st.booleans())
    return prompts, budgets, max_slots, num_pages, prefix_cache


def run_case(prompts, budgets, max_slots, num_pages, prefix_cache):
    scfg = ServeConfig(page_size=PS, max_slots=max_slots, max_len=MAX_LEN,
                       num_pages=num_pages, prefix_cache=prefix_cache)
    # the baseline clamps budgets the same way Engine.add_request does
    budgets = [min(b, MAX_LEN - len(p)) for p, b in zip(prompts, budgets)]
    eng = Engine(CFG, scfg, _params())
    results, metrics = eng.run_offline(prompts, budgets)
    got = [r.tokens for r in results]
    ref, _ = generate_static(CFG, _params(), prompts, budgets, scfg,
                             batch_size=1)
    assert got == ref, f"engine tokens diverge from static baseline: {got} != {ref}"

    assert metrics["n_requests"] == len(prompts)
    for r in results:
        if prefix_cache:
            assert 0 <= r.cached_tokens <= len(r.prompt) - 1
        else:
            assert r.cached_tokens == 0
    assert metrics["cached_tokens"] == sum(r.cached_tokens for r in results)
    assert metrics["prefill_tokens"] + metrics["cached_tokens"] \
        == sum(len(p) for p in prompts)

    # leak-free: every page reference unwinds once the cache lets go
    if eng.radix is not None:
        eng.radix.reset()
    assert all(s is None for s in eng.sched.slots)
    assert eng.pool.num_allocated == 0
    assert eng.pool.refcounts == {}
    assert eng.pool.num_free == scfg.total_pages - 1
    return results


@given(workloads())
def test_random_mix_matches_baseline_and_is_leak_free(wl):
    run_case(*wl)


@given(workloads())
def test_cache_on_off_agree(wl):
    """The prefix cache must be output-invisible: the same workload served
    with and without it yields identical greedy tokens."""
    prompts, budgets, max_slots, num_pages, _ = wl
    a = run_case(prompts, budgets, max_slots, num_pages, False)
    b = run_case(prompts, budgets, max_slots, num_pages, True)
    assert [r.tokens for r in a] == [r.tokens for r in b]
    # identical prompts admitted later must hit the cache (when cacheable:
    # at least one full page of prefix and room to have been published)
    for ra, rb in zip(a, b):
        assert ra.rid == rb.rid


@given(st.integers(0, 2**31 - 1), st.integers(2, 5))
def test_identical_prompts_hit_the_cache(seed, n_requests):
    """After the first request publishes its prompt pages, every identical
    follower reuses all full prompt pages (no pool pressure here)."""
    rng = np.random.RandomState(seed)
    prompt = rng.randint(1, CFG.vocab, size=2 * PS + 3).tolist()
    scfg = ServeConfig(page_size=PS, max_slots=1, max_len=MAX_LEN,
                       prefix_cache=True)
    eng = Engine(CFG, scfg, _params())
    results, metrics = eng.run_offline([prompt] * n_requests, 3)
    # max_slots=1 serializes admissions, so every follower sees the cache
    assert [r.cached_tokens for r in results] == [0] + [2 * PS] * (n_requests - 1)
    assert metrics["cache_hit_rate"] > 0
    eng.radix.reset()
    assert eng.pool.num_allocated == 0 and eng.pool.refcounts == {}
