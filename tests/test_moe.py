"""MoE routing invariants + equivalence with a dense (no-capacity) reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models.moe import capacity, moe_apply, moe_defs
from repro.models.params import init_tree


def _cfg(**kw):
    base = reduced(ARCHS["dbrx-132b"])
    return dataclasses.replace(base, **kw)


def _dense_ref(cfg, p, x):
    """Every token through its top-k experts, no capacity limit."""
    G, S, D = x.shape
    logits = (x.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    out = jnp.zeros(x.shape, jnp.float32)
    act = jax.nn.silu
    for e in range(cfg.n_experts):
        h = act(x @ p["gate"][e]) * (x @ p["up"][e])
        ye = (h @ p["down"][e]).astype(jnp.float32)
        w = jnp.sum(jnp.where(top_i == e, top_p, 0.0), -1)
        out = out + ye * w[..., None]
    return out


def test_moe_matches_dense_when_capacity_ample():
    cfg = _cfg(capacity_factor=8.0)   # capacity >> load: nothing dropped
    key = jax.random.PRNGKey(0)
    p = init_tree(moe_defs(cfg), key)
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    out, aux = moe_apply(cfg, p, x)
    ref = _dense_ref(cfg, p, x)
    if cfg.n_shared_experts:
        pytest.skip("reference covers routed experts only")
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=2e-3, rtol=2e-2)


def test_capacity_drops_lowest_gate_tokens():
    cfg = _cfg(capacity_factor=0.25)
    key = jax.random.PRNGKey(1)
    p = init_tree(moe_defs(cfg), key)
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, cfg.d_model),
                          jnp.float32)
    out, aux = moe_apply(cfg, p, x)
    assert out.shape == x.shape
    assert jnp.all(jnp.isfinite(out))
    # with tight capacity some tokens must receive zero routed output
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert float(jnp.min(norms)) < float(jnp.max(norms))


def test_capacity_formula():
    cfg = _cfg(capacity_factor=1.0)
    c = capacity(cfg, 4096)
    # top_k * tokens / n_experts, rounded up to 8
    assert c >= 4096 * cfg.top_k / cfg.n_experts
    assert c % 8 == 0 or c == 4096


def test_aux_loss_balanced_router_is_minimal():
    """A perfectly uniform router gives aux ~= top_k; an unbalanced one more."""
    cfg = _cfg()
    key = jax.random.PRNGKey(3)
    p = init_tree(moe_defs(cfg), key)
    # uniform logits -> balanced: aux == top_k exactly
    p["router"] = jnp.zeros_like(p["router"])
    x = jnp.ones((2, 64, cfg.d_model), jnp.float32)
    _, aux_bal = moe_apply(cfg, p, x)
    assert abs(float(aux_bal) - cfg.top_k) < 0.5
    # heavily biased router (x constant positive -> expert 0 always wins)
    p["router"] = p["router"].at[:, 0].set(1.0)
    _, aux_skew = moe_apply(cfg, p, x)
    assert float(aux_skew) > float(aux_bal)


def test_deepseek_shared_experts_path():
    cfg = reduced(ARCHS["deepseek-v2-236b"])
    key = jax.random.PRNGKey(4)
    p = init_tree(moe_defs(cfg), key)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.bfloat16)
    out, aux = moe_apply(cfg, p, x)
    assert out.shape == x.shape
    assert jnp.all(jnp.isfinite(out.astype(jnp.float32)))
