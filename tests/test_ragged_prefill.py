"""Fused ragged paged-prefill kernel parity (interpret mode on CPU).

Same three rungs as the decode-kernel suite (``test_attn_backend``):

1. *Attend-core* parity — the ``pallas`` backend's ragged prefill against
   the ``reference`` gather+attend oracle, swept across page sizes, GQA
   ratios (incl. MQA and MHA), chunk offsets (``start > 0``), ragged live
   lengths, sliding-window rings, softcap, dtypes, and the MLA
   materialized-K form.
2. *Block* parity — one full paged prefill block (QKV + RoPE + scatter +
   attend + out-proj) per family through both backends from identical pool
   contents.
3. *Engine* parity — chunked-prefill serving (``prefill_chunk_tokens``)
   with the pallas backend, exact greedy-token match against the reference
   backend for all three paged cache families.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ServeConfig, get_arch, reduced
from repro.models.attn_backend import get_backend, prefill_meta

jax.config.update("jax_platform_name", "cpu")


def _pool(rng, P, ps, K, D, dtype):
    k = jnp.asarray(rng.randn(P, ps, K, D), dtype)
    v = jnp.asarray(rng.randn(P, ps, K, D), dtype)
    return k, v


def _tables(rng, B, maxp, P):
    perm = rng.permutation(np.arange(1, P))[:B * maxp]
    return jnp.asarray(perm.reshape(B, maxp), jnp.int32)


def _assert_close(out, ref, dtype):
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


# --------------------------------------------------------------- attend cores

VANILLA_CASES = [
    # (B, H, K, D, ps, maxp, T)
    (3, 4, 2, 32, 8, 5, 16),         # GQA 2:1, multi-page chunk
    (2, 4, 4, 16, 4, 7, 12),         # MHA, T not a page multiple
    (2, 6, 1, 64, 16, 3, 16),        # MQA
    (1, 4, 2, 32, 8, 6, 40),         # one long chunk spanning many pages
]


@pytest.mark.parametrize("B,H,K,D,ps,maxp,T", VANILLA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefill_attend_matches_reference(B, H, K, D, ps, maxp, T, dtype):
    """Vanilla GQA: chunk K/V already resident (post-write pool); per-row
    offsets exercise first chunks (start 0), continuations, and COW-style
    unaligned starts."""
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, T, H, D), dtype)
    kp, vp = _pool(rng, 4 * maxp, ps, K, D, dtype)
    tables = _tables(rng, B, maxp, 4 * maxp)
    starts = [0, ps + 1, 2 * ps]                     # aligned and unaligned
    start = jnp.asarray([starts[b % len(starts)] for b in range(B)],
                        jnp.int32)
    n_live = jnp.asarray(
        np.concatenate([[T], rng.randint(1, T + 1, size=B - 1)]), jnp.int32)
    ref = get_backend("reference").prefill_attend(
        q, None, None, kp, vp, tables, start, n_live)
    out = get_backend("pallas").prefill_attend(
        q, q[:, :, :K], q[:, :, :K], kp, vp, tables, start, n_live)
    _assert_close(out, ref, dtype)


def test_prefill_attend_softcap():
    rng = np.random.RandomState(1)
    B, H, K, D, ps, maxp, T = 2, 4, 2, 32, 8, 4, 16
    q = jnp.asarray(rng.randn(B, T, H, D), jnp.float32)
    kp, vp = _pool(rng, 16, ps, K, D, jnp.float32)
    tables = _tables(rng, B, maxp, 16)
    start = jnp.asarray([0, ps], jnp.int32)
    n_live = jnp.asarray([T, T - 3], jnp.int32)
    ref = get_backend("reference").prefill_attend(
        q, None, None, kp, vp, tables, start, n_live, softcap=30.0)
    out = get_backend("pallas").prefill_attend(
        q, q[:, :, :K], q[:, :, :K], kp, vp, tables, start, n_live,
        softcap=30.0)
    _assert_close(out, ref, jnp.float32)


WINDOW_CASES = [
    # (B, H, K, D, ps, n_ring, T, window)
    (2, 4, 2, 32, 8, 4, 16, 20),     # chunk crosses the window
    (2, 4, 1, 16, 4, 5, 8, 16),      # MQA ring
    (1, 4, 2, 32, 8, 3, 24, 17),     # unaligned window, chunk > ring span
]


@pytest.mark.parametrize("B,H,K,D,ps,n_ring,T,window", WINDOW_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_windowed_prefill_attend_matches_reference(B, H, K, D, ps, n_ring, T,
                                                   window, dtype):
    """Sliding-window ring: fresh chunk K/V + the pre-write page ring, at
    offsets that exercise both the no-history (start 0) and ring-history
    paths."""
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(B, T, H, D), dtype)
    kn = jnp.asarray(rng.randn(B, T, K, D), dtype)
    vn = jnp.asarray(rng.randn(B, T, K, D), dtype)
    kp, vp = _pool(rng, 4 * n_ring, ps, K, D, dtype)
    tables = _tables(rng, B, n_ring, 4 * n_ring)
    start = jnp.asarray(
        [0] + [int(rng.randint(1, 3 * n_ring * ps)) for _ in range(B - 1)],
        jnp.int32)
    n_live = jnp.asarray(
        np.concatenate([[T], rng.randint(1, T + 1, size=B - 1)]), jnp.int32)
    ref = get_backend("reference").prefill_attend(
        q, kn, vn, kp, vp, tables, start, n_live, window=window)
    out = get_backend("pallas").prefill_attend(
        q, kn, vn, kp, vp, tables, start, n_live, window=window)
    _assert_close(out, ref, dtype)


@pytest.mark.parametrize("B,H,L,R,nope,vd,ps,maxp,T", [
    (2, 4, 16, 8, 32, 32, 8, 5, 16),
    (1, 2, 8, 4, 16, 16, 4, 6, 12),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mla_prefill_attend_matches_reference(B, H, L, R, nope, vd, ps, maxp,
                                              T, dtype):
    """MLA materialized-K: per-head K/V rebuilt from latent pages inside the
    kernel, at the reference einsum's rounding point."""
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(B, T, H, nope + R), dtype)
    P = 4 * maxp
    cc = jnp.asarray(rng.randn(P, ps, L), dtype)
    cr = jnp.asarray(rng.randn(P, ps, R), dtype)
    wkv_b = jnp.asarray(rng.randn(L, H, nope + vd) * 0.3, dtype)
    tables = _tables(rng, B, maxp, P)
    start = jnp.asarray([0, ps + 3][:B], jnp.int32)
    n_live = jnp.asarray([T, max(T - 5, 1)][:B], jnp.int32)
    ref = get_backend("reference").mla_prefill_attend(
        q, cc, cr, wkv_b, tables, start, n_live, nope=nope)
    out = get_backend("pallas").mla_prefill_attend(
        q, cc, cr, wkv_b, tables, start, n_live, nope=nope)
    _assert_close(out, ref, dtype)


# ---------------------------------------------------------------- block level

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "starcoder2-7b",
                                  "deepseek-v2-236b"])
def test_paged_prefill_block_parity(arch):
    """One full chunk-prefill step (QKV + RoPE + scatter + attend +
    out-proj, all layers) through both backends from identical pool
    contents, at a mid-prompt chunk offset."""
    from repro.models.params import init_tree
    from repro.models.registry import build_model, init_params

    cfg = dataclasses.replace(reduced(get_arch(arch)), remat="none")
    model_ref = build_model(cfg, "reference")
    model_pal = build_model(cfg, "pallas")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(4)
    B, ps, maxp, T = 2, 8, 4, 8
    P = B * maxp + 1
    kv = jax.tree.map(
        lambda a: jnp.asarray(rng.randn(*a.shape).astype(np.float32) * 0.3,
                              a.dtype),
        init_tree(model_ref.paged_cache_defs(P, ps), jax.random.PRNGKey(0)))
    tables = np.asarray(
        rng.permutation(np.arange(1, P))[:B * maxp].reshape(B, maxp),
        np.int32)
    start = np.asarray([0, ps], np.int32)            # first + second chunk
    n_tail = np.asarray([T, T - 2], np.int32)
    slots = np.asarray([0, 1], np.int32)
    tokens = jnp.asarray(rng.randint(1, cfg.vocab, size=(B, T)), jnp.int32)
    meta = {k: jnp.asarray(v) for k, v in prefill_meta(
        cfg, ps, tables, slots, start, n_tail, T).items()}
    lr, kr, _ = model_ref.prefill_paged(params, kv, {}, meta, tokens)
    lp, kp, _ = model_pal.prefill_paged(params, kv, {}, meta, tokens)
    np.testing.assert_allclose(np.asarray(lr, np.float32),
                               np.asarray(lp, np.float32), atol=3e-2,
                               rtol=3e-2)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=3e-2, rtol=3e-2), kr, kp)
    assert [int(t) for t in jnp.argmax(lr, -1)] \
        == [int(t) for t in jnp.argmax(lp, -1)]


# -------------------------------------------------------------------- engine

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "starcoder2-7b",
                                  "deepseek-v2-236b"])
def test_engine_chunked_pallas_exact_token_match(arch):
    """Chunked prefill through the ragged kernel produces exactly the
    reference backend's greedy tokens for all three paged cache families."""
    from repro.serving import Engine

    cfg = dataclasses.replace(reduced(get_arch(arch)), remat="none")
    rng = np.random.RandomState(5)
    prompts = [rng.randint(1, cfg.vocab,
                           size=int(rng.randint(4, 40))).tolist()
               for _ in range(6)]
    budgets = [int(rng.randint(3, 10)) for _ in range(6)]
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=56,
                       prefill_chunk_tokens=16, attn_backend="reference")
    eng = Engine(cfg, scfg, seed=0)
    ref, ref_m = eng.run_offline(prompts, budgets)
    pal, pal_m = Engine(
        cfg, dataclasses.replace(scfg, attn_backend="pallas"),
        eng.params, seed=0).run_offline(prompts, budgets)
    assert ref_m["chunked_prefill_steps"] > 0      # long prompts did chunk
    assert pal_m["chunked_prefill_steps"] > 0
    assert [r.tokens for r in ref] == [p.tokens for p in pal]
