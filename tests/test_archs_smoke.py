"""Per-arch smoke tests: reduced same-family config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model, init_cache, init_params
from repro.models.steps import make_train_step
from repro.optim import OptConfig, init_opt_state

ALL_ARCHS = sorted(ARCHS)


def tiny_batch(cfg, key, B=2, S=32):
    if cfg.enc_dec:
        return {"frames": jax.random.normal(key, (B, S, cfg.frontend_dim),
                                            jnp.bfloat16),
                "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.n_image_tokens:
        return {"tokens": jax.random.randint(key, (B, S - cfg.n_image_tokens),
                                             0, cfg.vocab),
                "image_embeds": jax.random.normal(
                    key, (B, cfg.n_image_tokens, cfg.frontend_dim), jnp.bfloat16)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_no_nans(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = tiny_batch(cfg, key)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss {loss}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch):
    cfg = reduced(ARCHS[arch])
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt_cfg = OptConfig(lr=1e-3)
    opt_state = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, None, opt_cfg))
    batch = tiny_batch(cfg, key)
    p2, o2, m = step(params, opt_state, batch)
    assert jnp.isfinite(m["loss"])
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2)
    assert max(jax.tree.leaves(moved)) > 0
    # second step decreases nothing pathological (finite again)
    p3, o3, m2 = step(p2, o2, batch)
    assert jnp.isfinite(m2["loss"])


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B = 2
    cache = init_cache(cfg, B, 64)
    logits, cache2 = jax.jit(lambda p, c, t: model.decode(p, c, t))(
        params, cache, jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.vocab_padded)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    assert int(cache2["pos"][0]) == 1
