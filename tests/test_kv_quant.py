"""int8 quantized paged-KV cache: round-trip, parity, COW, and dual gate.

Five rungs of the quantization contract (``ServeConfig.kv_dtype="int8"``):

1. *Round-trip* — ``quantize_int8``/``dequant_int8`` obey the universal
   error bound ``|back - x| <= 0.5*s + max(0, amax - 127*s)`` per slice
   (half a quantization step plus the clip slack from the bf16-rounded
   scale), with adversarial inputs: all-zero pages, denormal magnitudes,
   single-outlier heads.  Property-tested under hypothesis when installed,
   deterministic sweeps always.
2. *Attend-core parity* — every Pallas kernel's in-register dequant
   (vanilla GQA decode, windowed ring decode, MLA decode, ragged prefill,
   windowed ragged prefill, MLA ragged prefill) against the ``reference``
   backend's XLA gather+dequant oracle, which is itself checked exact
   against attending a pre-dequantized fp32 pool.
3. *Pool accounting* — int8 pools carry bf16 scale leaves on the same page
   axis; ``page_nbytes``/``kv_bytes_per_token`` count both, the int8/bf16
   byte ratio meets the <= 0.55x acceptance bar, and alloc/release
   conservation holds unchanged (one page id owns payload + scales).
4. *COW with scales* — the radix prefix cache under int8 stays token-exact
   against the uncached int8 engine: a partial-page fork that copied
   payload but not scales would diverge immediately.
5. *Dual gate* — the serving parity contract for quantized mode (bounded
   max-abs logit error vs a bf16 replay + exact greedy match at
   high-margin positions, ``serving.quant_verify``) passes for the three
   paged families on both backends.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ServeConfig, get_arch, reduced
from repro.models.attention import dequant_int8, quantize_int8
from repro.models.attn_backend import get_backend
from repro.serving import Engine, PagedKVPool, dual_gate_verify

jax.config.update("jax_platform_name", "cpu")

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ----------------------------------------------------------------- round-trip

def _assert_roundtrip_bound(x: np.ndarray):
    """The contract's exact error bound, checked slice-wise in float64.

    Rounding contributes <= 0.5*s; the clip at +-127 contributes at most
    ``amax - 127*s`` when the bf16-rounded scale lands below ``amax/127``;
    a zero scale (all-zero or underflowing slice) stores q = 0, where the
    bound degenerates to ``amax`` itself."""
    q, s = quantize_int8(jnp.asarray(x, jnp.float32))
    assert q.dtype == jnp.int8 and s.dtype == jnp.bfloat16
    assert s.shape == x.shape[:-1]
    back = np.asarray(dequant_int8(q, s), np.float64)
    xf = np.asarray(x, np.float64)
    sf = np.asarray(s, np.float64)[..., None]
    amax = np.max(np.abs(xf), axis=-1, keepdims=True)
    bound = 0.5 * sf + np.maximum(0.0, amax - 127.0 * sf)
    assert np.all(np.abs(back - xf) <= bound + 1e-30)
    # zero-scale slices must store exact zeros (no garbage payload)
    zero = np.broadcast_to(sf == 0.0, q.shape)
    assert np.all(np.asarray(q)[zero] == 0)
    return q, s, back


@pytest.mark.parametrize("ps", [4, 8, 16])
@pytest.mark.parametrize("K,D", [(1, 64), (2, 32), (4, 16), (6, 8)])
def test_roundtrip_bounded_error(ps, K, D):
    """Page sizes x GQA ratios (MQA through MHA-ish head counts)."""
    rng = np.random.RandomState(ps * 100 + K)
    x = rng.randn(5, ps, K, D).astype(np.float32) * 3.0
    _assert_roundtrip_bound(x)


def test_roundtrip_all_zero_page_is_exact():
    q, s, back = _assert_roundtrip_bound(np.zeros((2, 8, 2, 16), np.float32))
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(s, np.float32) == 0.0)
    assert np.all(back == 0.0)


def test_roundtrip_denormal_magnitudes():
    """Scales that underflow bf16 (absmax/127 below the smallest bf16
    subnormal) must collapse the slice to exact zeros, not NaN/Inf; scales
    that survive as bf16 subnormals must still satisfy the bound."""
    rng = np.random.RandomState(7)
    signs = np.where(rng.rand(3, 8, 2, 8) < 0.5, -1.0, 1.0).astype(np.float32)
    for mag in (1e-39, 1e-38, 1e-30):
        x = signs * mag * (0.5 + rng.rand(3, 8, 2, 8).astype(np.float32))
        q, s, back = _assert_roundtrip_bound(x)
        assert np.all(np.isfinite(back))
    # deep underflow: absmax/127 ~ 8e-42 is below bf16's smallest subnormal
    x = signs * 1e-39
    q, s, _ = _assert_roundtrip_bound(x)
    assert np.all(np.asarray(s, np.float32) == 0.0)
    assert np.all(np.asarray(q) == 0)


def test_roundtrip_single_outlier_head_is_isolated():
    """The scale is per-(token-slot, kv-head): a 1e4 outlier in head 0 must
    not coarsen any other head's quantization grid."""
    rng = np.random.RandomState(8)
    base = rng.randn(1, 8, 4, 16).astype(np.float32)
    spiked = base.copy()
    spiked[..., 0, :] *= 1e4
    qb, sb = quantize_int8(jnp.asarray(base))
    qs, ss = quantize_int8(jnp.asarray(spiked))
    np.testing.assert_array_equal(np.asarray(qb)[..., 1:, :],
                                  np.asarray(qs)[..., 1:, :])
    np.testing.assert_array_equal(np.asarray(sb, np.float32)[..., 1:],
                                  np.asarray(ss, np.float32)[..., 1:])
    _assert_roundtrip_bound(spiked)


if HAVE_HYPOTHESIS:
    settings.register_profile("kv_quant_ci", max_examples=25, deadline=None)
    settings.load_profile("kv_quant_ci")

    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8, 16]),
           st.sampled_from([(1, 64), (2, 32), (4, 16)]),
           st.integers(-35, 30))
    def test_roundtrip_property(seed, ps, KD, exp):
        """Random pages over ~65 orders of magnitude hold the exact bound."""
        K, D = KD
        rng = np.random.RandomState(seed)
        x = rng.randn(2, ps, K, D).astype(np.float32) * (10.0 ** exp)
        _assert_roundtrip_bound(x)

    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6))
    def test_roundtrip_property_flat_latent(seed, L):
        """MLA-shaped slices ([..., L] latent rows, scale per token-slot)."""
        rng = np.random.RandomState(seed)
        x = rng.randn(3, 8, 2 * L).astype(np.float32)
        _assert_roundtrip_bound(x)


# ------------------------------------------------------- attend-core parity

def _tables(rng, B, maxp, P):
    perm = rng.permutation(np.arange(1, P))[:B * maxp]
    return jnp.asarray(perm.reshape(B, maxp), jnp.int32)


def _quant_pool(rng, P, ps, K, D):
    kf = rng.randn(P, ps, K, D).astype(np.float32)
    vf = rng.randn(P, ps, K, D).astype(np.float32)
    kq, ks = quantize_int8(jnp.asarray(kf))
    vq, vs = quantize_int8(jnp.asarray(vf))
    return kq, ks, vq, vs


DECODE_CASES = [
    # (B, H, K, D, ps, maxp, window)
    (3, 4, 2, 32, 8, 5, 0),          # GQA 2:1
    (2, 6, 1, 64, 16, 3, 0),         # MQA
    (3, 4, 2, 32, 8, 5, 20),         # sliding-window ring
]


@pytest.mark.parametrize("B,H,K,D,ps,maxp,window", DECODE_CASES)
def test_int8_decode_attend_matches_reference(B, H, K, D, ps, maxp, window):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, H, D), jnp.float32)
    kq, ks, vq, vs = _quant_pool(rng, 4 * maxp, ps, K, D)
    tables = _tables(rng, B, maxp, 4 * maxp)
    pos = jnp.asarray(np.concatenate(
        [[0], rng.randint(1, maxp * ps, size=B - 1)]), jnp.int32)
    scale = 1.0 / math.sqrt(D)
    ref = get_backend("reference").decode_attend(
        q, kq, vq, tables, pos, scale=scale, window=window,
        k_scale=ks, v_scale=vs)
    out = get_backend("pallas").decode_attend(
        q, kq, vq, tables, pos, scale=scale, window=window,
        k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)
    # the reference dequant path must equal attending a pre-dequantized
    # fp32 pool — the scale gather can hide no rounding of its own
    kf = jnp.asarray(dequant_int8(kq, ks))
    vf = jnp.asarray(dequant_int8(vq, vs))
    oracle = get_backend("reference").decode_attend(
        q, kf, vf, tables, pos, scale=scale, window=window)
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(oracle, np.float32),
                               atol=1e-6, rtol=1e-6)


def test_int8_mla_decode_attend_matches_reference():
    rng = np.random.RandomState(1)
    B, H, L, R, ps, maxp = 3, 4, 16, 8, 8, 5
    P = 4 * maxp
    q_eff = jnp.asarray(rng.randn(B, H, L), jnp.float32)
    q_rope = jnp.asarray(rng.randn(B, H, R), jnp.float32)
    cq, cs = quantize_int8(jnp.asarray(rng.randn(P, ps, L), jnp.float32))
    rq, rs = quantize_int8(jnp.asarray(rng.randn(P, ps, R), jnp.float32))
    tables = _tables(rng, B, maxp, P)
    pos = jnp.asarray(np.concatenate(
        [[0], rng.randint(1, maxp * ps, size=B - 1)]), jnp.int32)
    scale = 1.0 / math.sqrt(L + R)
    ref = get_backend("reference").mla_decode_attend(
        q_eff, q_rope, cq, rq, tables, pos, scale=scale,
        ckv_scale=cs, krope_scale=rs)
    out = get_backend("pallas").mla_decode_attend(
        q_eff, q_rope, cq, rq, tables, pos, scale=scale,
        ckv_scale=cs, krope_scale=rs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)


def test_int8_prefill_attend_matches_reference():
    """Vanilla ragged prefill: the chunk's K/V already quantized into the
    post-write pool, read back dequantized inside the kernel."""
    rng = np.random.RandomState(2)
    B, H, K, D, ps, maxp, T = 2, 4, 2, 32, 8, 5, 16
    q = jnp.asarray(rng.randn(B, T, H, D), jnp.float32)
    kq, ks, vq, vs = _quant_pool(rng, 4 * maxp, ps, K, D)
    tables = _tables(rng, B, maxp, 4 * maxp)
    start = jnp.asarray([0, ps + 1], jnp.int32)
    n_live = jnp.asarray([T, T - 3], jnp.int32)
    ref = get_backend("reference").prefill_attend(
        q, None, None, kq, vq, tables, start, n_live,
        k_scale=ks, v_scale=vs)
    out = get_backend("pallas").prefill_attend(
        q, q[:, :, :K], q[:, :, :K], kq, vq, tables, start, n_live,
        k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)


def test_int8_windowed_prefill_attend_matches_reference():
    """Sliding-window ragged prefill: int8 resident ring + *unquantized*
    fresh chunk (fresh K/V only hit the pool after the attend)."""
    rng = np.random.RandomState(3)
    B, H, K, D, ps, n_ring, T, window = 2, 4, 2, 32, 8, 4, 16, 20
    q = jnp.asarray(rng.randn(B, T, H, D), jnp.float32)
    kn = jnp.asarray(rng.randn(B, T, K, D), jnp.float32)
    vn = jnp.asarray(rng.randn(B, T, K, D), jnp.float32)
    kq, ks, vq, vs = _quant_pool(rng, 4 * n_ring, ps, K, D)
    tables = _tables(rng, B, n_ring, 4 * n_ring)
    start = jnp.asarray([0, 2 * ps + 3], jnp.int32)
    n_live = jnp.asarray([T, T - 5], jnp.int32)
    ref = get_backend("reference").prefill_attend(
        q, kn, vn, kq, vq, tables, start, n_live, window=window,
        k_scale=ks, v_scale=vs)
    out = get_backend("pallas").prefill_attend(
        q, kn, vn, kq, vq, tables, start, n_live, window=window,
        k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)


def test_int8_mla_prefill_attend_matches_reference():
    rng = np.random.RandomState(4)
    B, H, L, R, nope, vd, ps, maxp, T = 2, 4, 16, 8, 32, 32, 8, 5, 16
    P = 4 * maxp
    q = jnp.asarray(rng.randn(B, T, H, nope + R), jnp.float32)
    cq, cs = quantize_int8(jnp.asarray(rng.randn(P, ps, L), jnp.float32))
    rq, rs = quantize_int8(jnp.asarray(rng.randn(P, ps, R), jnp.float32))
    wkv_b = jnp.asarray(rng.randn(L, H, nope + vd) * 0.3, jnp.float32)
    tables = _tables(rng, B, maxp, P)
    start = jnp.asarray([0, ps + 3], jnp.int32)
    n_live = jnp.asarray([T, T - 5], jnp.int32)
    ref = get_backend("reference").mla_prefill_attend(
        q, cq, rq, wkv_b, tables, start, n_live, nope=nope,
        ckv_scale=cs, krope_scale=rs)
    out = get_backend("pallas").mla_prefill_attend(
        q, cq, rq, wkv_b, tables, start, n_live, nope=nope,
        ckv_scale=cs, krope_scale=rs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ pool accounting

def _cfg(name="qwen2-0.5b"):
    return dataclasses.replace(reduced(get_arch(name)), remat="none")


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "deepseek-v2-236b"])
def test_pool_scale_leaves_and_byte_accounting(arch):
    """int8 pools grow bf16 scale leaves on the shared page axis and the
    byte accounting counts them; the int8/bf16 bytes-per-token ratio meets
    the acceptance bar (<= 0.55x) for both GQA and MLA layouts."""
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=32)
    pool_b = PagedKVPool(_cfg(arch), scfg)
    pool_i = PagedKVPool(_cfg(arch),
                         dataclasses.replace(scfg, kv_dtype="int8"))
    scale_keys = {k for k in pool_i.kv if k.endswith("_scale")}
    assert scale_keys and not {k for k in pool_b.kv if k.endswith("_scale")}
    for k in scale_keys:
        assert pool_i.kv[k].dtype == jnp.bfloat16
        assert pool_i.kv[k].shape[1] == pool_i.total_pages
    # same page geometry either way — only the bytes per page shrink
    assert pool_i.total_pages == pool_b.total_pages
    assert pool_i.table_width == pool_b.table_width
    assert pool_i.pages_for(20) == pool_b.pages_for(20)
    ratio = pool_i.kv_bytes_per_token / pool_b.kv_bytes_per_token
    assert ratio <= 0.55, f"{arch}: int8/bf16 bytes ratio {ratio:.3f}"
    assert pool_i.page_nbytes == pool_i.kv_bytes_per_token * scfg.page_size


def test_pool_conservation_under_int8():
    """alloc/share/release reconcile identically under int8: one page id
    owns payload and scales, so the counters never split."""
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=32, kv_dtype="int8")
    pool = PagedKVPool(_cfg(), scfg)
    free0 = pool.num_free
    pages = pool.alloc(3)
    pool.share(pages[:2])
    assert pool.metrics.value("pool.pages_allocated") == 3
    assert pool.metrics.value("pool.refs_shared") == 2
    assert pool.metrics.value("pool.ref_total") == 5
    pool.release(pages[:2])            # shared pages survive one release
    assert pool.num_free == free0 - 3
    pool.release(pages)
    assert pool.num_free == free0
    assert pool.metrics.value("pool.pages_released") == 3
    assert pool.metrics.value("pool.pages_live") == 0
    assert pool.refcounts == {}


# -------------------------------------------------------- COW / prefix cache

@pytest.mark.parametrize("attn_backend", ["reference", "pallas"])
def test_int8_prefix_cache_token_identity(attn_backend):
    """Radix sharing + partial-page COW forks under int8 stay token-exact
    against the uncached int8 engine: the fork copies payload AND scale
    rows of the source page, so re-reads dequantize identically."""
    cfg = _cfg()
    rng = np.random.RandomState(5)
    fam = rng.randint(1, cfg.vocab, size=18).tolist()
    # same family prefix, diverging mid-page: forces COW forks, not shares
    prompts = [fam + rng.randint(1, cfg.vocab, size=6).tolist()
               for _ in range(4)]
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48,
                       kv_dtype="int8", prefix_cache=True,
                       attn_backend=attn_backend)
    eng = Engine(cfg, scfg, seed=0)
    res, m = eng.run_offline(prompts, 6)
    assert m["cached_tokens"] > 0
    # conservation holds through int8 COW forks: every page the run handed
    # out was either released or is still held (by the radix tree)
    assert (eng.pool.metrics.value("pool.pages_allocated")
            - eng.pool.metrics.value("pool.pages_released")
            == eng.pool.metrics.value("pool.pages_live"))
    ref, _ = Engine(cfg, dataclasses.replace(scfg, prefix_cache=False),
                    eng.params, seed=0).run_offline(prompts, 6)
    assert [r.tokens for r in res] == [r.tokens for r in ref]


# ------------------------------------------------------------------ dual gate

@pytest.mark.parametrize("arch,attn_backend", [
    ("qwen2-0.5b", "reference"),
    ("qwen2-0.5b", "pallas"),
    ("starcoder2-7b", "reference"),
    ("deepseek-v2-236b", "reference"),
])
def test_dual_gate_passes(arch, attn_backend):
    """The quantized serving contract end to end: int8 engine tokens pass
    bounded-logit-error + high-margin-greedy + replay fidelity."""
    cfg = _cfg(arch)
    rng = np.random.RandomState(6)
    prompts = [rng.randint(1, cfg.vocab,
                           size=int(rng.randint(4, 20))).tolist()
               for _ in range(3)]
    budgets = [4] * len(prompts)
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=32,
                       kv_dtype="int8", attn_backend=attn_backend)
    eng = Engine(cfg, scfg, seed=0)
    res, _ = eng.run_offline(prompts, budgets)
    report = dual_gate_verify(cfg, scfg, eng.params, prompts,
                              [r.tokens for r in res],
                              attn_backend=attn_backend)
    assert report["ok"], report
    assert report["max_logit_err"] <= report["tol"]
    assert report["replay_failures"] == 0
    assert report["high_margin_mismatches"] == 0
    assert report["high_margin_tokens"] > 0    # the gate actually gated


def test_dual_gate_catches_planted_divergence():
    """A token the engine could not have produced (wrong at a high-margin
    position) must fail the gate — the gate is falsifiable, not vacuous."""
    cfg = _cfg()
    rng = np.random.RandomState(9)
    prompts = [rng.randint(1, cfg.vocab, size=12).tolist()]
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=32,
                       kv_dtype="int8", attn_backend="reference")
    eng = Engine(cfg, scfg, seed=0)
    res, _ = eng.run_offline(prompts, 4)
    bad = list(res[0].tokens)
    bad[0] = (bad[0] + 1) % cfg.vocab
    report = dual_gate_verify(cfg, scfg, eng.params, prompts, [bad])
    assert not report["ok"]
