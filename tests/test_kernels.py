"""Per-kernel allclose sweeps: shapes x dtypes against the pure-jnp oracles,
executed with interpret=True on CPU (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rbm_cd import gemm_sigmoid, gemm_sigmoid_ref

FLASH_SHAPES = [
    # (B, S, H, K, D, block)
    (2, 128, 4, 2, 64, 64),
    (1, 256, 8, 8, 32, 128),
    (2, 64, 6, 1, 64, 64),      # MQA
    (1, 128, 2, 2, 128, 64),
]


@pytest.mark.parametrize("B,S,H,K,D,blk", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, H, K, D, blk, dtype, causal):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, S, H, D), dtype)
    k = jax.random.normal(keys[1], (B, S, K, D), dtype)
    v = jax.random.normal(keys[2], (B, S, K, D), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=blk, block_k=blk,
                          interpret=True)
    ref = jnp.swapaxes(
        attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                      jnp.swapaxes(v, 1, 2), causal=causal), 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


GEMM_SHAPES = [(100, 784, 1000), (128, 128, 128), (37, 200, 61), (1, 30, 10)]


@pytest.mark.parametrize("M,K,N", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sigmoid_matches_ref(M, K, N, dtype):
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    x = (jax.random.normal(keys[0], (M, K), dtype) * 0.1).astype(dtype)
    w = (jax.random.normal(keys[1], (K, N), dtype) * 0.1).astype(dtype)
    b = (jax.random.normal(keys[2], (N,), dtype) * 0.1).astype(dtype)
    out = gemm_sigmoid(x, w, b, interpret=True)
    ref = gemm_sigmoid_ref(x, w, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_blocks_sweep():
    """Block-shape invariance: different VMEM tilings give identical results."""
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(keys[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(keys[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(keys[2], (1, 256, 2, 64), jnp.float32)
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)
