"""Serving correctness: prefill + stepwise decode must agree with the full
forward pass (teacher forcing).  Exercises KV caches, ring buffers, SSM/LRU
states, and MLA's absorbed decode path against the materialized train path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model, init_cache, init_params

# cover every cache mechanism: plain KV, GQA, MLA absorbed, SSM state,
# RG-LRU + ring-buffer window, enc-dec cross attention
CASES = ["qwen2-0.5b", "deepseek-v2-236b", "mamba2-780m", "recurrentgemma-2b",
         "seamless-m4t-large-v2"]


@pytest.mark.parametrize("arch", CASES)
def test_prefill_then_decode_matches_forward(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    key = jax.random.PRNGKey(42)
    params = init_params(cfg, key)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    if cfg.enc_dec:
        frames = jax.random.normal(key, (B, 16, cfg.frontend_dim), jnp.bfloat16)
        batch_s = {"frames": frames, "tokens": toks[:, :S]}
        batch_s1 = {"frames": frames, "tokens": toks}
    else:
        batch_s = {"tokens": toks[:, :S]}
        batch_s1 = {"tokens": toks}

    # prefill on S tokens, then decode token S -> compare with prefill on S+1
    logits_s, cache = jax.jit(lambda p, b: model.prefill(p, b))(params, batch_s)
    # grow cache to S+8
    fresh = init_cache(cfg, B, S + 8)
    if cfg.enc_dec:
        fresh = model.cache_defs(B, S + 8, enc_len=16)
        from repro.models.params import init_tree
        fresh = init_tree(fresh, jax.random.PRNGKey(0))
    cache = jax.tree.map(
        lambda f, c: c if f.shape == c.shape else jnp.pad(
            c, [(0, fs - cs) for fs, cs in zip(f.shape, c.shape)]),
        fresh, cache)
    logits_dec, _ = jax.jit(lambda p, c, t: model.decode(p, c, t))(
        params, cache, toks[:, S])

    logits_ref, _ = jax.jit(lambda p, b: model.prefill(p, b))(params, batch_s1)

    a = np.asarray(logits_dec, np.float32)
    b = np.asarray(logits_ref, np.float32)
    # compare softmax-normalized logits (bf16 accumulation differences)
    a = a - a.max(-1, keepdims=True)
    b = b - b.max(-1, keepdims=True)
    np.testing.assert_allclose(a, b, atol=0.35, rtol=0.1)
    # argmax agreement on most rows
    agree = (a.argmax(-1) == b.argmax(-1)).mean()
    assert agree >= 0.5, f"{arch}: argmax agreement {agree}"


def test_window_ring_buffer_matches_full_attention():
    """Hybrid local attention: decode past the window must equal a reference
    computed with an explicit window mask."""
    cfg = reduced(ARCHS["recurrentgemma-2b"])
    model = build_model(cfg)
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    B, S = 1, 48  # window is 32 in the reduced config -> decode exceeds it
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    logits_s, cache = jax.jit(lambda p, b: model.prefill(p, b))(
        params, {"tokens": toks[:, :S]})
    logits_dec, _ = jax.jit(lambda p, c, t: model.decode(p, c, t))(
        params, cache, toks[:, S])
    logits_ref, _ = jax.jit(lambda p, b: model.prefill(p, b))(
        params, {"tokens": toks})
    a = np.asarray(logits_dec, np.float32)
    b = np.asarray(logits_ref, np.float32)
    a = a - a.max(-1, keepdims=True)
    b = b - b.max(-1, keepdims=True)
    np.testing.assert_allclose(a, b, atol=0.35, rtol=0.1)
