"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.attention import chunked_attention
from repro.models.ssm import ssd_chunked
from repro.models.rglru import _gates
from repro.optim.compression import dequantize_int8, ef_compress, quantize_int8
from repro.data.dedup import dedup

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------- compression

@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 300))
def test_quantize_roundtrip_bounded_error(seed, rows, cols):
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)))
    q, s = quantize_int8(jnp.asarray(x))
    back = np.asarray(dequantize_int8(q, s, x.shape, jnp.float32))
    # error bounded by half a quantization step per block
    step = np.asarray(s).max()
    assert np.max(np.abs(back - x)) <= step * 0.51 + 1e-7


@given(st.integers(0, 2**31 - 1))
def test_error_feedback_residual_is_exact(seed):
    """g_deq + err_new == g + err_old (EF bookkeeping conserves mass)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (64,))
    err = jax.random.normal(jax.random.fold_in(key, 1), (64,)) * 0.1
    deq, new_err, _ = ef_compress(g, err)
    lhs = np.asarray(deq, np.float64) + np.asarray(new_err, np.float64)
    rhs = np.asarray(g, np.float64) + np.asarray(err, np.float64)
    np.testing.assert_allclose(lhs, rhs, atol=1e-5)


# ----------------------------------------------------------- attention blocks

@given(st.sampled_from([1, 2]), st.sampled_from([16, 32, 48]),
       st.sampled_from([(2, 1), (4, 2), (4, 4)]), st.sampled_from([8, 16]))
def test_chunked_attention_block_invariance(B, S, HK, D):
    H, K = HK
    key = jax.random.PRNGKey(B * 1000 + S)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
    full = chunked_attention(q, k, v, q_block=S)
    blocked = chunked_attention(q, k, v, q_block=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked),
                               atol=1e-5, rtol=1e-4)


# ----------------------------------------------------------------- SSD (ssm)

def _ssd_naive(xd, dtA, B, C):
    b, s, h, p = xd.shape
    n = B.shape[-1]
    st_ = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    xd, dtA, B, C = map(lambda a: np.asarray(a, np.float64), (xd, dtA, B, C))
    for t in range(s):
        decay = np.exp(dtA[:, t])                       # [b,h]
        st_ = st_ * decay[..., None, None] + np.einsum(
            "bhp,bn->bhpn", xd[:, t], B[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", st_, C[:, t])
    return ys


@given(st.sampled_from([8, 16, 32]), st.sampled_from([4, 8]),
       st.integers(0, 10**6))
def test_ssd_chunked_equals_naive_recurrence(S, chunk, seed):
    key = jax.random.PRNGKey(seed)
    b, h, p, n = 1, 2, 4, 3
    xd = jax.random.normal(key, (b, S, h, p))
    dtA = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (b, S, h)))
    B = jax.random.normal(jax.random.fold_in(key, 2), (b, S, n))
    C = jax.random.normal(jax.random.fold_in(key, 3), (b, S, n))
    y, _ = ssd_chunked(xd, dtA, B, C, chunk)
    ref = _ssd_naive(xd, dtA, B, C)
    np.testing.assert_allclose(np.asarray(y, np.float64), ref,
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------- misc

@given(st.integers(0, 100))
def test_rglru_decay_in_unit_interval(seed):
    key = jax.random.PRNGKey(seed)
    p = {"w_a": jax.random.normal(key, (8, 8)) * 0.2,
         "b_a": jnp.zeros(8), "w_i": jax.random.normal(key, (8, 8)) * 0.2,
         "b_i": jnp.zeros(8), "lam": jnp.full((8,), 2.0)}
    u = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 8))
    a, b = _gates(p, u)
    assert np.all(np.asarray(a) > 0) and np.all(np.asarray(a) < 1)
    assert np.all(np.isfinite(np.asarray(b)))


@given(st.integers(1, 4))
def test_dedup_idempotent(max_dup):
    X, y = __import__("repro.data", fromlist=["dataset"]).dataset(
        100, seed=1, duplicate_frac=0.4)
    X1, y1 = dedup(X, y, max_dup=max_dup)
    X2, y2 = dedup(X1, y1, max_dup=max_dup)
    assert len(X1) == len(X2)
