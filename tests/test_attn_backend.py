"""Attention-backend registry + fused paged-attention decode kernel parity.

Three rungs of the same contract, all on CPU with the Pallas kernels in
interpret mode:

1. *Attend-core* parity — the ``pallas`` backend's fused decode against the
   ``reference`` gather+attend oracle, swept across page sizes, GQA ratios
   (incl. MQA and MHA), partially-filled pages, sliding-window rings, softcap,
   dtypes, and the MLA absorbed-latent form.
2. *Block* parity — one full paged decode block (QKV + RoPE + scatter +
   attend + out-proj) per family through both backends.
3. *Engine* parity — ``ServeConfig(attn_backend="pallas")`` serving the three
   acceptance families (qwen2 paged_kv, starcoder2 windowed_kv, deepseek-v2
   paged_mla) with exact greedy-token match against the reference backend,
   which is itself verified against ``generate_static(batch_size=1)`` by
   ``tests/test_serving_families.py`` — the same check
   ``launch/serve.py --attn-backend pallas --verify`` runs.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ServeConfig, get_arch, reduced
from repro.models.attn_backend import (available_backends, decode_meta,
                                       get_backend, resolve_backend)

jax.config.update("jax_platform_name", "cpu")


def _pool(rng, P, ps, K, D, dtype):
    k = jnp.asarray(rng.randn(P, ps, K, D), dtype)
    v = jnp.asarray(rng.randn(P, ps, K, D), dtype)
    return k, v


def _tables(rng, B, maxp, P):
    """Disjoint per-row physical pages, never the reserved null page 0."""
    perm = rng.permutation(np.arange(1, P))[:B * maxp]
    return jnp.asarray(perm.reshape(B, maxp), jnp.int32)


# --------------------------------------------------------------- attend cores

CORE_CASES = [
    # (B, H, K, D, ps, maxp, window)
    (3, 4, 2, 32, 8, 5, 0),          # GQA 2:1
    (2, 4, 4, 16, 4, 7, 0),          # MHA
    (2, 6, 1, 64, 16, 3, 0),         # MQA
    (3, 4, 2, 32, 8, 5, 20),         # sliding-window ring, window < ring
    (2, 4, 2, 16, 4, 4, 16),         # window == ring (every slot in window)
]


@pytest.mark.parametrize("B,H,K,D,ps,maxp,window", CORE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attend_matches_reference(B, H, K, D, ps, maxp, window, dtype):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, H, D), dtype)
    kp, vp = _pool(rng, 4 * maxp, ps, K, D, dtype)
    tables = _tables(rng, B, maxp, 4 * maxp)
    # positions straddle page boundaries; row 0 pins the pos == 0 edge
    pos = jnp.asarray(np.concatenate(
        [[0], rng.randint(1, maxp * ps, size=B - 1)]), jnp.int32)
    scale = 1.0 / math.sqrt(D)
    ref = get_backend("reference").decode_attend(
        q, kp, vp, tables, pos, scale=scale, window=window)
    out = get_backend("pallas").decode_attend(
        q, kp, vp, tables, pos, scale=scale, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_decode_attend_softcap():
    rng = np.random.RandomState(1)
    B, H, K, D, ps, maxp = 2, 4, 2, 32, 8, 4
    q = jnp.asarray(rng.randn(B, H, D), jnp.float32)
    kp, vp = _pool(rng, 16, ps, K, D, jnp.float32)
    tables = _tables(rng, B, maxp, 16)
    pos = jnp.asarray([7, 29], jnp.int32)
    ref = get_backend("reference").decode_attend(
        q, kp, vp, tables, pos, scale=1 / math.sqrt(D), softcap=30.0)
    out = get_backend("pallas").decode_attend(
        q, kp, vp, tables, pos, scale=1 / math.sqrt(D), softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,L,R,ps,maxp", [
    (3, 4, 16, 8, 8, 5),
    (2, 8, 32, 16, 4, 6),
    (1, 2, 8, 4, 16, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mla_decode_attend_matches_reference(B, H, L, R, ps, maxp, dtype):
    rng = np.random.RandomState(2)
    q_eff = jnp.asarray(rng.randn(B, H, L), dtype)
    q_rope = jnp.asarray(rng.randn(B, H, R), dtype)
    P = 4 * maxp
    cc = jnp.asarray(rng.randn(P, ps, L), dtype)
    cr = jnp.asarray(rng.randn(P, ps, R), dtype)
    tables = _tables(rng, B, maxp, P)
    pos = jnp.asarray(np.concatenate([[0], rng.randint(
        1, maxp * ps, size=B - 1)]) if B > 1 else [0], jnp.int32)
    scale = 1.0 / math.sqrt(L + R)
    ref = get_backend("reference").mla_decode_attend(
        q_eff, q_rope, cc, cr, tables, pos, scale=scale)
    out = get_backend("pallas").mla_decode_attend(
        q_eff, q_rope, cc, cr, tables, pos, scale=scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ------------------------------------------------------------------- registry

def test_registry_contract():
    assert set(available_backends()) >= {"reference", "pallas"}
    assert resolve_backend("reference") == "reference"
    assert resolve_backend("pallas") == "pallas"
    # auto resolves to the XLA reference path off-TPU
    assert resolve_backend("auto") == "reference"
    with pytest.raises(ValueError):
        resolve_backend("cuda")
    assert get_backend("pallas").name == "pallas"
    # pallas fuses all four cores now: decode (PR 4) and ragged prefill
    assert type(get_backend("pallas")).prefill_attend \
        is not type(get_backend("reference")).prefill_attend
    assert type(get_backend("pallas")).mla_prefill_attend \
        is not type(get_backend("reference")).mla_prefill_attend


def test_decode_meta_write_targets():
    cfg = reduced(get_arch("qwen2-0.5b"))
    tables = np.asarray([[3, 4, 5], [6, 7, 8]], np.int32)
    pos = np.asarray([0, 17], np.int32)
    m = decode_meta(cfg, 8, tables, pos)
    assert m["write_page"].tolist() == [3, 8]      # pages 0//8=0, 17//8=2
    assert m["write_off"].tolist() == [0, 1]
    # sliding-window: the column wraps at the ring horizon (window_pages
    # gives 32 // 8 + 1 == 5 pages so the page being written never evicts an
    # in-window token)
    wcfg = reduced(get_arch("starcoder2-7b"))
    assert wcfg.sliding_window == 32
    tables = np.asarray([[3, 4, 5, 6, 7, 9]], np.int32)
    m = decode_meta(wcfg, 8, tables, np.asarray([33], np.int32))
    assert m["write_page"].tolist() == [7]         # col (33//8) % 5 == 4
    assert m["write_off"].tolist() == [1]


# ---------------------------------------------------------------- block level

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "starcoder2-7b",
                                  "deepseek-v2-236b"])
def test_paged_decode_block_parity(arch):
    """One full decode block (QKV + scatter + attend + out-proj) through both
    backends, from identical pool contents."""
    from repro.models.registry import build_model, init_params

    cfg = dataclasses.replace(reduced(get_arch(arch)), remat="none")
    model_ref = build_model(cfg, "reference")
    model_pal = build_model(cfg, "pallas")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    B, ps, maxp = 2, 8, 4
    P = B * maxp + 1
    # a pool pre-filled with plausible values: entries past pos are masked by
    # both backends, so random stale data is part of the contract under test
    kv = jax.tree.map(
        lambda a: jnp.asarray(rng.randn(*a.shape).astype(np.float32) * 0.3,
                              a.dtype),
        _abstract(model_ref.paged_cache_defs(P, ps)))
    tables = np.asarray(
        rng.permutation(np.arange(1, P))[:B * maxp].reshape(B, maxp),
        np.int32)
    pos = np.asarray([5, 19], np.int32)
    tokens = jnp.asarray(rng.randint(1, cfg.vocab, size=B), jnp.int32)
    meta = {k: jnp.asarray(v)
            for k, v in decode_meta(cfg, ps, tables, pos).items()}
    lr, kr, _ = model_ref.decode_paged(params, kv, {}, meta, tokens)
    lp, kp, _ = model_pal.decode_paged(params, kv, {}, meta, tokens)
    np.testing.assert_allclose(np.asarray(lr, np.float32),
                               np.asarray(lp, np.float32), atol=3e-2,
                               rtol=3e-2)
    # both backends write the new token to the same physical slots; deeper
    # layers' writes inherit the residual stream, so bf16-ulp drift from the
    # layer-0 attend is allowed but nothing structural may differ
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=3e-2, rtol=3e-2), kr, kp)
    assert [int(t) for t in jnp.argmax(lr, -1)] \
        == [int(t) for t in jnp.argmax(lp, -1)]


def _abstract(defs):
    from repro.models.params import init_tree
    return init_tree(defs, jax.random.PRNGKey(0))


# -------------------------------------------------------------------- engine

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "starcoder2-7b",
                                  "deepseek-v2-236b"])
def test_engine_pallas_exact_token_match(arch):
    """The acceptance contract: pallas-backend serving produces exactly the
    reference backend's greedy tokens for all three paged cache families."""
    from repro.serving import Engine

    cfg = dataclasses.replace(reduced(get_arch(arch)), remat="none")
    rng = np.random.RandomState(4)
    prompts = [rng.randint(1, cfg.vocab,
                           size=int(rng.randint(4, 28))).tolist()
               for _ in range(6)]
    budgets = [int(rng.randint(3, 10)) for _ in range(6)]
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48,
                       attn_backend="reference")
    eng = Engine(cfg, scfg, seed=0)
    ref, ref_m = eng.run_offline(prompts, budgets)
    pal, pal_m = Engine(
        cfg, dataclasses.replace(scfg, attn_backend="pallas"),
        eng.params, seed=0).run_offline(prompts, budgets)
    assert ref_m["attn_backend"] == "reference"
    assert pal_m["attn_backend"] == "pallas"
    assert pal_m["decode_steps"] > 0 and pal_m["decode_step_ms_p50"] > 0
    assert [r.tokens for r in ref] == [p.tokens for p in pal]


def test_engine_pallas_with_prefix_cache():
    """Backend choice composes with the radix prefix cache: cached-prefix
    pages written by one request are read back through the fused kernel."""
    from repro.serving import Engine

    cfg = dataclasses.replace(reduced(get_arch("qwen2-0.5b")), remat="none")
    rng = np.random.RandomState(5)
    fam = rng.randint(1, cfg.vocab, size=18).tolist()
    prompts = [fam + rng.randint(1, cfg.vocab, size=6).tolist()
               for _ in range(4)]
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48,
                       prefix_cache=True, attn_backend="pallas")
    eng = Engine(cfg, scfg, seed=0)
    res, m = eng.run_offline(prompts, 6)
    assert m["cached_tokens"] > 0          # later requests hit the cache
    ref_eng = Engine(
        cfg, dataclasses.replace(scfg, prefix_cache=False,
                                 attn_backend="reference"),
        eng.params, seed=0)
    ref, _ = ref_eng.run_offline(prompts, 6)
    assert [r.tokens for r in res] == [r.tokens for r in ref]
