"""Validate the loop-aware HLO cost model against XLA's own cost analysis on a
fully-unrolled program (where XLA's numbers are trustworthy), and check the
trip-count multiplication against it on the scanned version of the same fn."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def _xla_flops(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0]
    return ca["flops"]


def _mlp_scan(unroll):
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=6, unroll=unroll)
        return jnp.sum(y)
    return f


def test_matches_xla_on_unrolled():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    c = jax.jit(_mlp_scan(True)).lower(w, x).compile()
    ref = _xla_flops(c)
    mine = hlo_cost.module_cost(c.as_text())
    assert 0.8 <= mine.flops / ref <= 1.3, (mine.flops, ref)


def test_scan_trip_count_accounted():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    unrolled = jax.jit(_mlp_scan(True)).lower(w, x).compile()
    scanned = jax.jit(_mlp_scan(False)).lower(w, x).compile()
    ref = _xla_flops(unrolled)
    mine = hlo_cost.module_cost(scanned.as_text())
    # XLA's own analysis of the scanned program is ~6x off; ours must not be
    assert 0.8 <= mine.flops / ref <= 1.3, (mine.flops, ref)
    blind = _xla_flops(scanned)
    assert blind < 0.5 * ref     # documents why the custom walker exists


def test_grad_scan_counted():
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=4)
        return jnp.sum(y * y)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    g_scan = jax.jit(jax.grad(f)).lower(w, x).compile()
    def f_u(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=4, unroll=True)
        return jnp.sum(y * y)
    g_unr = jax.jit(jax.grad(f_u)).lower(w, x).compile()
    ref = _xla_flops(g_unr)
    mine = hlo_cost.module_cost(g_scan.as_text())
    assert 0.7 <= mine.flops / ref <= 1.5, (mine.flops, ref)
