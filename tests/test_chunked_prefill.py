"""Chunked-prefill scheduling invariants and chunk-boundary exactness.

The contract under test: for *any* chunk budget — one page, unaligned
budgets (rounded down to whole pages), budgets larger than every prompt —
composed with the prefix cache on/off and preemption mid-prefill, the
engine's greedy tokens are token-exact against the single-request static
baseline, and the scheduler actually interleaves decode steps between a
long prompt's chunks instead of head-of-line-blocking the decode batch.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ServeConfig, get_arch, reduced
from repro.models.registry import init_params
from repro.serving import Engine, generate_static

jax.config.update("jax_platform_name", "cpu")


def _cfg(name="qwen2-0.5b"):
    return dataclasses.replace(reduced(get_arch(name)), remat="none")


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, size=n).tolist() for n in lens]


def test_chunk_tokens_rounds_to_pages():
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=32,
                       prefill_chunk_tokens=12)
    assert scfg.chunk_tokens == 8          # rounded down to whole pages
    assert dataclasses.replace(scfg, prefill_chunk_tokens=3).chunk_tokens == 8
    assert dataclasses.replace(scfg, prefill_chunk_tokens=0).chunk_tokens == 0
    assert dataclasses.replace(scfg, prefill_chunk_tokens=24).chunk_tokens \
        == 24


@pytest.mark.parametrize("chunk", [8, 12, 24, 1000])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_chunked_exact_vs_static(chunk, prefix_cache):
    """One page, unaligned, multi-page, and larger-than-every-prompt budgets
    all yield token-exact output, cache on or off."""
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=64,
                       prefill_chunk_tokens=chunk, prefix_cache=prefix_cache)
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = _prompts(cfg, [50, 7, 33, 18, 26, 41])
    budgets = [5, 8, 4, 7, 6, 3]
    eng = Engine(cfg, scfg, params)
    results, metrics = eng.run_offline(prompts, budgets)
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    assert [r.tokens for r in results] == ref
    if scfg.chunk_tokens and scfg.chunk_tokens < max(len(p) for p in prompts):
        assert metrics["chunked_prefill_steps"] > 0
    assert metrics["prefill_padded_tokens"] >= metrics[
        "prefill_actual_tokens"] > 0
    assert eng.pool.num_allocated == (
        len(eng.radix.cached_pages) if eng.radix is not None else 0)


@pytest.mark.parametrize("arch", ["starcoder2-7b", "deepseek-v2-236b",
                                  "seamless-m4t-large-v2"])
def test_chunked_families_exact_vs_static(arch):
    """Chunk cursors thread through the windowed page ring, the MLA latent
    pages, and the enc-dec decoder self-KV (continuation chunks skip the
    encoder and cross-attend the pinned slot K/V)."""
    cfg = _cfg(arch)
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=56,
                       prefill_chunk_tokens=16)
    params = init_params(cfg, jax.random.PRNGKey(2))
    prompts = _prompts(cfg, [40, 9, 26, 33], seed=3)
    budgets = [4, 6, 5, 3]
    eng = Engine(cfg, scfg, params, seed=0)
    results, metrics = eng.run_offline(prompts, budgets)
    assert metrics["chunked_prefill_steps"] > 0
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1, seed=0)
    assert [r.tokens for r in results] == ref


def test_state_slot_families_ignore_chunk_budget():
    """Recurrent state must absorb a whole prompt in one call: the budget is
    a no-op for pure state-slot families, and serving stays exact."""
    cfg = _cfg("mamba2-780m")
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48,
                       prefill_chunk_tokens=8)
    params = init_params(cfg, jax.random.PRNGKey(3))
    prompts = _prompts(cfg, [30, 11, 22], seed=4)
    eng = Engine(cfg, scfg, params, seed=0)
    assert eng.sched.chunk == 0
    results, metrics = eng.run_offline(prompts, 5)
    assert metrics["chunked_prefill_steps"] == 0
    ref, _ = generate_static(cfg, params, prompts, 5, scfg, batch_size=1,
                             seed=0)
    assert [r.tokens for r in results] == ref


def test_decode_interleaves_between_chunks():
    """Sarathi-style mixed steps: while short requests hold decode slots, a
    long prompt's continuation chunks must alternate with decode steps —
    never two consecutive prefill steps while a slot sat decode-ready."""
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=96,
                       prefill_chunk_tokens=16)
    params = init_params(cfg, jax.random.PRNGKey(4))
    prompts = _prompts(cfg, [10, 12, 9, 64], seed=5)   # long prompt last
    budgets = [30, 30, 30, 4]
    eng = Engine(cfg, scfg, params)
    acts = []
    orig = eng.sched.next_action

    def wrapped():
        a = orig()
        if a is not None:
            acts.append((a[0], bool(eng.sched.decode_ready())))
        return a

    eng.sched.next_action = wrapped
    results, metrics = eng.run_offline(prompts, budgets)
    assert metrics["chunked_prefill_steps"] > 0
    for (kind_a, _), (kind_b, ready_b) in zip(acts, acts[1:]):
        if kind_a != "decode" and kind_b != "decode":
            assert not ready_b, (
                "two consecutive prefill steps while decode-ready: "
                f"{[k for k, _ in acts]}")
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    assert [r.tokens for r in results] == ref


def test_per_chunk_publish_feeds_prefix_cache():
    """Completed pages publish after every chunk: an identical prompt queued
    behind a long one (more requests than slots, so it admits later) hits
    pages the first request published mid-prefill."""
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=1, max_len=64,
                       prefill_chunk_tokens=8, prefix_cache=True)
    params = init_params(cfg, jax.random.PRNGKey(5))
    long = _prompts(cfg, [48], seed=6)[0]
    prompts = [long, list(long)]
    eng = Engine(cfg, scfg, params)
    results, metrics = eng.run_offline(prompts, 4)
    assert results[1].cached_tokens > 0
    assert metrics["cached_tokens"] == results[1].cached_tokens
    ref, _ = generate_static(cfg, params, prompts, 4, scfg, batch_size=1)
    assert [r.tokens for r in results] == ref


def test_preemption_mid_prefill_still_exact():
    """A pool too small for every admitted request can preempt a slot that
    is still mid-prefill; the replay must stay token-exact."""
    cfg = _cfg()
    # 2 slots x 8 pages/request worst case; give 9 pages (+ null)
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=64, num_pages=10,
                       prefill_chunk_tokens=8)
    params = init_params(cfg, jax.random.PRNGKey(6))
    prompts = _prompts(cfg, [40, 35, 22, 17], seed=7)
    budgets = [20, 18, 12, 9]
    eng = Engine(cfg, scfg, params)
    results, _ = eng.run_offline(prompts, budgets)
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                             batch_size=1)
    assert [r.tokens for r in results] == ref
    assert sum(r.n_preemptions for r in results) > 0
    assert eng.pool.num_allocated == 0


def test_decode_stall_metrics_present():
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=48)
    params = init_params(cfg, jax.random.PRNGKey(7))
    _, metrics = Engine(cfg, scfg, params).run_offline(
        _prompts(cfg, [9, 30, 12], seed=8), 4)
    for key in ("decode_stall_ms_p50", "decode_stall_ms_p95",
                "decode_stall_ms_max", "prefill_padding_waste"):
        assert key in metrics and metrics[key] >= 0


# ------------------------------------------------------- property (hypothesis)

def test_chunk_boundary_property():
    """Any (prompt mix, chunk budget, cache flag) combination is token-exact
    vs the static single-request baseline, including budgets of exactly one
    page, unaligned budgets, and chunk == prompt."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(8))

    @settings(max_examples=12, deadline=None)
    @given(
        lens=st.lists(st.integers(min_value=1, max_value=44), min_size=1,
                      max_size=5),
        chunk=st.integers(min_value=1, max_value=48),
        prefix_cache=st.booleans(),
        seed=st.integers(min_value=0, max_value=3),
    )
    def check(lens, chunk, prefix_cache, seed):
        scfg = ServeConfig(page_size=8, max_slots=3, max_len=56,
                           prefill_chunk_tokens=chunk,
                           prefix_cache=prefix_cache)
        prompts = _prompts(cfg, lens, seed=seed)
        eng = Engine(cfg, scfg, params)
        results, _ = eng.run_offline(prompts, 4)
        ref, _ = generate_static(cfg, params, prompts, 4, scfg, batch_size=1)
        assert [r.tokens for r in results] == ref
        # no leaked pages: only the radix tree may still hold references
        assert eng.pool.num_allocated == (
            len(eng.radix.cached_pages) if eng.radix is not None else 0)

    check()
