"""Continuous-batching engine invariants.

* page pool alloc/free bookkeeping (free-list, null page, double-free guard)
* mixed-length concurrent batches produce exactly the greedy tokens of the
  one-request-at-a-time static baseline
* retirement (EOS / max-len) and preemption return every page to the pool
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, ServeConfig, reduced
from repro.models.registry import init_params
from repro.serving import Engine, NULL_PAGE, PagedKVPool, generate_static


def _cfg(name="qwen2-0.5b"):
    return dataclasses.replace(reduced(ARCHS[name]), remat="none")


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, size=n).tolist() for n in lens]


# ------------------------------------------------------------------ kv pool

def test_pool_alloc_free_invariants():
    scfg = ServeConfig(page_size=16, max_slots=2, max_len=64)
    pool = PagedKVPool(_cfg(), scfg)
    total = scfg.total_pages - 1            # page 0 reserved
    assert pool.num_free == total

    a = pool.alloc(3)
    b = pool.alloc(2)
    assert len(a) == 3 and len(b) == 2
    assert NULL_PAGE not in a + b           # null page never handed out
    assert len(set(a + b)) == 5             # no page handed out twice
    assert pool.num_free == total - 5
    assert pool.num_allocated == 5

    assert pool.alloc(pool.num_free + 1) is None   # no partial grabs
    assert pool.num_free == total - 5              # failed alloc took nothing

    pool.free(b)
    assert pool.num_free == total - 3
    with pytest.raises(AssertionError):
        pool.free(b)                        # double free
    pool.free(a)
    assert pool.num_free == total and pool.num_allocated == 0


def test_pool_pages_needed_and_geometry():
    scfg = ServeConfig(page_size=16, max_slots=4, max_len=96)
    pool = PagedKVPool(_cfg(), scfg)
    assert pool.pages_needed(1) == 1
    assert pool.pages_needed(16) == 1
    assert pool.pages_needed(17) == 2
    assert scfg.pages_per_request == 6
    assert pool.kv["k"].shape[1] == scfg.total_pages
    assert pool.kv["k"].shape[2] == scfg.page_size


# ------------------------------------------------- correctness vs baseline

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "dbrx-132b"])
def test_mixed_batch_matches_single_request_baseline(arch):
    cfg = _cfg(arch)
    scfg = ServeConfig(page_size=8, max_slots=4, max_len=48)
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = _prompts(cfg, [3, 30, 11, 7, 22, 15])
    budgets = [6, 4, 8, 5, 7, 3]

    eng = Engine(cfg, scfg, params)
    results, metrics = eng.run_offline(prompts, budgets)
    got = [r.tokens for r in results]
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg, batch_size=1)
    assert got == ref
    assert metrics["n_requests"] == len(prompts)
    assert metrics["new_tokens"] == sum(budgets)
    assert all(r.ttft <= r.latency for r in results)


def test_incremental_api_and_slot_reuse():
    """add_request/step/collect with more requests than slots: retired slots
    must be refilled from the queue and results stay per-request correct."""
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=2, max_len=32)
    params = init_params(cfg, jax.random.PRNGKey(2))
    prompts = _prompts(cfg, [5, 9, 14, 4, 20], seed=3)
    eng = Engine(cfg, scfg, params)
    for p in prompts:
        eng.add_request(p, max_new_tokens=5)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 1000
    results = sorted(eng.collect(), key=lambda r: r.rid)
    assert [r.rid for r in results] == list(range(5))
    ref, _ = generate_static(cfg, params, prompts, 5, scfg, batch_size=1)
    assert [r.tokens for r in results] == ref


# ------------------------------------------------------ eviction / preempt

def test_eviction_frees_all_pages():
    cfg = _cfg()
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=32)
    params = init_params(cfg, jax.random.PRNGKey(4))
    eng = Engine(cfg, scfg, params)
    prompts = _prompts(cfg, [10, 17, 6, 21, 9, 13], seed=5)
    eng.run_offline(prompts, [7, 3, 6, 4, 8, 5])
    assert eng.pool.num_allocated == 0
    assert eng.pool.num_free == scfg.total_pages - 1
    assert all(s is None for s in eng.sched.slots)


def test_eos_retires_early_and_frees_pages():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(6))
    prompts = _prompts(cfg, [12, 8], seed=7)
    # discover what the model greedily emits, then declare one of those
    # tokens EOS and re-run: generation must stop at (and include) it
    free_scfg = ServeConfig(page_size=8, max_slots=2, max_len=64)
    eng = Engine(cfg, free_scfg, params)
    results, _ = eng.run_offline(prompts, 12)
    eos = results[0].tokens[3]
    scfg = dataclasses.replace(free_scfg, eos_id=eos)
    eng2 = Engine(cfg, scfg, params)
    results2, _ = eng2.run_offline(prompts, 12)
    r0 = results2[0].tokens
    assert r0[-1] == eos and len(r0) <= 12
    assert eos not in r0[:-1]
    assert r0 == results[0].tokens[:len(r0)]
    assert eng2.pool.num_allocated == 0


def test_preemption_under_page_pressure_still_exact():
    """A pool too small for all admitted requests forces preemption +
    deterministic replay; final tokens must still match the baseline."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(8))
    # 3 slots x 4 pages/request = 12 pages worst-case; give 6 (+null page)
    scfg = ServeConfig(page_size=8, max_slots=3, max_len=32, num_pages=7)
    prompts = _prompts(cfg, [7, 15, 9, 12], seed=9)
    budgets = [9, 8, 10, 7]
    eng = Engine(cfg, scfg, params)
    results, _ = eng.run_offline(prompts, budgets)
    ref, _ = generate_static(cfg, params, prompts, budgets, scfg, batch_size=1)
    assert [r.tokens for r in results] == ref
    assert sum(r.n_preemptions for r in results) > 0   # pressure was real
    assert eng.pool.num_allocated == 0


# ------------------------------------------------------------ engine guards

def test_every_family_reports_pageable():
    """supports_paged_decode is a capability report now, not a gate: every
    registered non-DBN arch serves under the continuous engine."""
    from repro.models import build_model
    for name, cfg in ARCHS.items():
        ok, desc = build_model(reduced(cfg)).supports_paged_decode()
        assert ok, f"{name}: {desc}"
        assert desc, name
    # the one-time NotImplementedError arch constructs fine these days
    eng = Engine(_cfg("mamba2-780m"), ServeConfig(page_size=8, max_slots=2,
                                                  max_len=32))
    assert eng.states is not None and eng.pool.table_width == 0


def test_prompt_too_long_rejected():
    """A zero-budget prompt no longer raises mid-batch: it surfaces as a
    failed RequestResult and is counted under sched.rejections, while the
    rest of the batch drains normally."""
    cfg = _cfg()
    eng = Engine(cfg, ServeConfig(page_size=8, max_slots=2, max_len=16),
                 init_params(cfg, jax.random.PRNGKey(0)))
    rid = eng.add_request(list(range(1, 17)), max_new_tokens=4)
    results = eng.collect()
    assert len(results) == 1 and results[0].rid == rid
    assert results[0].failed and "no_budget" in results[0].error
    assert results[0].tokens == []
    reject = eng.metrics.get("sched.rejections").labels(reason="no_budget")
    assert reject.value == 1
