"""Perf hillclimb driver: hypothesis -> change -> re-lower -> compare.

Runs dryrun_cell with config overrides and prints a before/after table of the
three roofline terms.  Each named experiment below corresponds to a §Perf
iteration in EXPERIMENTS.md.

  PYTHONPATH=src:. python experiments/hillclimb.py --cell qwen_train
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402

from repro.launch.dryrun import dryrun_cell   # noqa: E402

# (arch, shape, list of (label, overrides/kwargs))
EXPERIMENTS = {
    # worst useful-FLOPs ratio: 14 heads not divisible by model=16 ->
    # attention fully replicated across the TP axis
    "qwen_train": ("qwen2-0.5b", "train_4k", [
        ("baseline (paper-faithful DP+TP)", {}),
        ("pad heads 14->16 for TP", {"overrides": {"pad_heads_to": 16}}),
        ("+ remat dots", {"overrides": {"pad_heads_to": 16}, "remat": "dots"}),
        ("+ bigger loss chunk (1024)", {"overrides": {
            "pad_heads_to": 16, "loss_chunk": 1024}}),
    ]),
    # most collective-bound hybrid: RG-LRU gates resharded every block
    "rg_train": ("recurrentgemma-2b", "train_4k", [
        ("baseline", {}),
        ("pad heads 10->16 for TP", {"overrides": {"pad_heads_to": 16}}),
        ("+ remat dots", {"overrides": {"pad_heads_to": 16}, "remat": "dots"}),
    ]),
    # worst roofline fraction: 56 heads % 16 != 0 -> attention replicated
    # across the whole TP axis (memory term 4x compute)
    "llava_train": ("llava-next-34b", "train_4k", [
        ("baseline (replicated attention)", {}),
        ("pad heads 56->64 for TP", {"overrides": {"pad_heads_to": 64}}),
        ("+ remat dots", {"overrides": {"pad_heads_to": 64}, "remat": "dots"}),
        ("+ q_block 1024", {"overrides": {"pad_heads_to": 64,
                                          "attn_q_block": 1024}}),
    ]),
    # most representative of the paper's technique (pure DP gradient
    # aggregation dominates): the 104B dense model
    "commandr_train": ("command-r-plus-104b", "train_4k", [
        ("baseline (pjit engine)", {}),
        ("paper-faithful mapreduce engine", {"engine": "mapreduce"}),
        ("remat dots (cut recompute ARs)", {"remat": "dots"}),
        ("q_block 1024", {"overrides": {"attn_q_block": 1024}}),
        ("loss_chunk 2048 (fewer CE psums)", {"overrides": {
            "loss_chunk": 2048}}),
        ("seq-parallel residuals (SP)", {"overrides": {"seq_parallel": True}}),
        ("SP + remat dots", {"remat": "dots", "overrides": {
            "seq_parallel": True}}),
        ("SP + dots + loss_chunk 2048", {"remat": "dots", "overrides": {
            "seq_parallel": True, "loss_chunk": 2048}}),
    ]),
    # MoE EP dispatch
    "deepseek_train": ("deepseek-v2-236b", "train_4k", [
        ("baseline", {}),
        ("capacity factor 1.0", {"overrides": {"capacity_factor": 1.0}}),
    ]),
}


def fmt(rec):
    r = rec["roofline"]
    rf = rec.get("roofline_flash", {})
    return (f"compute={r['compute_s']:.3f}s memory={r['memory_s']:.3f}s "
            f"coll={r['collective_s']:.3f}s dom={r['dominant']} "
            f"useful={rec['useful_flops_ratio']:.3f} "
            f"| flash-mem={rf.get('memory_s', float('nan')):.3f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(EXPERIMENTS))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()

    arch, shape, variants = EXPERIMENTS[args.cell]
    os.makedirs(args.out, exist_ok=True)
    results = []
    for label, kw in variants:
        rec = dryrun_cell(arch, shape, multi_pod=args.multipod, verbose=False,
                          **kw)
        rec["label"] = label
        results.append(rec)
        print(f"[{args.cell}] {label:42s} {fmt(rec)}", flush=True)
        with open(os.path.join(args.out, f"{args.cell}.json"), "w") as f:
            json.dump(results, f, indent=1)
    base = results[0]["roofline"]["step_lower_bound_s"]
    best = min(r["roofline"]["step_lower_bound_s"] for r in results)
    print(f"[{args.cell}] step lower bound: {base:.3f}s -> {best:.3f}s "
          f"({base / best:.2f}x)")


if __name__ == "__main__":
    main()
