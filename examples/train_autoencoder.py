"""The paper's unsupervised demo (Figs. 10/12): 784-1000-500-250-30 deep
autoencoder — RBM pre-training, unroll, MapReduce BP fine-tuning, then
encode/decode a digit through the 30-dim code (compress rate 30/784 = 0.038,
the paper quotes the same pipeline).

  PYTHONPATH=src python examples/train_autoencoder.py [--small]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DBNConfig, autoencoder, train_dbn
from repro.data import train_test


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="reduced stack for a fast CPU run")
    ap.add_argument("--epochs", type=int, default=6)
    args = ap.parse_args()

    stack = (784, 256, 64, 30) if args.small else (784, 1000, 500, 250, 30)
    n_train = 2048 if args.small else 6000

    Xtr, _, Xte, _ = train_test(n_train=n_train, n_test=512)
    cfg = DBNConfig(stack=stack, max_epoch=3, batch_size=128, log_every=1)
    rbm_stack = train_dbn(Xtr, cfg, jax.random.PRNGKey(0))

    params = autoencoder.unroll(rbm_stack)
    print("pre-train recon err:",
          autoencoder.reconstruction_error(params, Xte))

    step = autoencoder.make_finetune_step(None, lr=0.02)
    vel = jax.tree.map(jnp.zeros_like, params)
    for epoch in range(args.epochs):
        for b in range(0, n_train - 128, 128):
            params, vel, loss, aux = step(
                params, vel, {"x": jnp.asarray(Xtr[b:b + 128])})
        err = autoencoder.reconstruction_error(params, Xte)
        print(f"epoch {epoch}: finetune recon err {err:.3f}")

    # the Fig. 10 demo: encode -> 30 dims -> decode
    x = jnp.asarray(Xte[:1])
    code = autoencoder.encode(params, x)
    recon = autoencoder.decode(params, code)
    print(f"encode/decode demo: 784 pixels -> code{code.shape[-1]} -> 784")
    print("code:", np.round(np.asarray(code[0][:10]), 2), "...")
    print(f"recon L2: {float(jnp.sum((x - recon) ** 2)):.2f}")


if __name__ == "__main__":
    main()
