"""Quickstart: the paper's pipeline in ~40 lines.

Trains a small deep-belief network on synthetic MNIST with MapReduce RBM jobs,
fine-tunes a digit classifier, and recognizes a few test digits — the Fig. 9
demo, minus the Matlab GUI.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DBNConfig, finetune, train_dbn
from repro.data import dedup, train_test

# 1. data (+ the paper's diversity-based dedup, §III-A)
Xtr, ytr, Xte, yte = train_test(n_train=2048, n_test=512, duplicate_frac=0.1)
Xtr, ytr = dedup(Xtr, ytr)
print(f"data: {len(Xtr)} train / {len(Xte)} test after dedup")

# 2. greedy layer-wise RBM pre-training (Algorithm 1)
cfg = DBNConfig(stack=(784, 256, 64), max_epoch=3, batch_size=128, log_every=1)
stack = train_dbn(Xtr, cfg, jax.random.PRNGKey(0))

# 3. supervised MapReduce back-propagation fine-tuning (§IV-B)
params = finetune.classifier_init(stack, 10, jax.random.PRNGKey(1))
step = finetune.make_classifier_step(None, lr=1.0)
vel = jax.tree.map(jnp.zeros_like, params)
for epoch in range(15):
    for b in range(0, len(Xtr) - 128, 128):
        params, vel, loss, aux = step(params, vel,
                                      {"x": jnp.asarray(Xtr[b:b + 128]),
                                       "y": jnp.asarray(ytr[b:b + 128])})
    if epoch % 3 == 0:
        print(f"epoch {epoch}: loss {float(loss):.3f} "
              f"train_acc {float(aux['acc']):.2f}")

# 4. recognize (the Fig. 9 demo step)
err = finetune.error_rate(params, Xte, yte)
pred = np.asarray(jnp.argmax(finetune.logits_fn(params, jnp.asarray(Xte[:8])), -1))
print(f"test error rate: {err:.3f}")
print(f"sample digits:   true={yte[:8].tolist()} pred={pred.tolist()}")
