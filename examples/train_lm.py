"""End-to-end LM training driver: trains a ~100M-param qwen2-family model with
the MapReduce engine on synthetic token data, with checkpointing + resume.

Default runs a reduced geometry for CPU; ``--full-100m`` selects the ~100M
configuration (24 layers x 512 d_model) and a few hundred steps, as the
deliverable specifies — expect hours on a 1-core container, minutes on a pod.

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--engine", default="mapreduce")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.full_100m:
        # ~100M params: 24L x 512d x 8H, qwen2 family, vocab 16k-padded
        argv = ["--arch", "qwen2-0.5b", "--layers", "24", "--d-model", "512",
                "--steps", str(args.steps or 300), "--global-batch", "8",
                "--seq-len", "512", "--engine", args.engine,
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
    else:
        argv = ["--arch", "qwen2-0.5b", "--reduced",
                "--steps", str(args.steps or 60), "--global-batch", "8",
                "--seq-len", "128", "--lr", "1e-3", "--engine", args.engine,
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25"]
    out = train_main(argv)
    print(f"train_lm done: loss {out['history'][0]:.3f} -> "
          f"{out['final_loss']:.3f} over {out['steps']} steps")


if __name__ == "__main__":
    main()
