"""The paper's supervised pipeline (Figs. 7/9/11): DBN pre-training + MapReduce
BP fine-tuning + AdaBoost(SAMME) precision refinement (§IV-C), reporting the
train/test misclassification curve with its over-fitting signature.

  PYTHONPATH=src python examples/train_classifier.py
"""
import jax
import jax.numpy as jnp

from repro.core import DBNConfig, adaboost, finetune, train_dbn
from repro.data import dedup, train_test


def main():
    Xtr, ytr, Xte, yte = train_test(n_train=2048, n_test=512, seed=0,
                                    duplicate_frac=0.1)
    Xtr, ytr = dedup(Xtr, ytr)

    # pre-train (Algorithm 1)
    cfg = DBNConfig(stack=(784, 256, 64), max_epoch=3, batch_size=128)
    stack = train_dbn(Xtr, cfg, jax.random.PRNGKey(0))

    # fine-tune (§IV-B) — note train error -> 0 while test error plateaus
    params = finetune.classifier_init(stack, 10, jax.random.PRNGKey(1))
    step = finetune.make_classifier_step(None, lr=1.0)
    vel = jax.tree.map(jnp.zeros_like, params)
    for epoch in range(15):
        for b in range(0, len(Xtr) - 128, 128):
            params, vel, loss, aux = step(
                params, vel, {"x": jnp.asarray(Xtr[b:b + 128]),
                              "y": jnp.asarray(ytr[b:b + 128])})
        tr = finetune.error_rate(params, Xtr, ytr)
        te = finetune.error_rate(params, Xte, yte)
        print(f"epoch {epoch:2d}: train_err {tr:.3f}  test_err {te:.3f}")

    # precision refinement (§IV-C)
    learners, alphas = adaboost.fit(
        Xtr, ytr, adaboost.BoostConfig(n_rounds=5, epochs=3),
        jax.random.PRNGKey(2))
    err = adaboost.error_rate(learners, alphas, Xte, yte)
    print(f"adaboost ({len(learners)} weak learners): test_err {err:.3f}")


if __name__ == "__main__":
    main()
