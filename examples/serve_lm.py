"""Batched serving example: prefill + greedy decode for any assigned arch.

  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m
  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b --gen 32
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--reduced",
                "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len),
                "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
