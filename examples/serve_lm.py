"""Serving example: continuous batching for attention LMs, static for the
recurrent families (``--engine auto`` picks per arch).

  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b --mixed
  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m
  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b --gen 32
  # shared-prefix traffic served through the radix prefix cache
  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b \
      --requests 8 --shared-prefix 2 --prefix-cache
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mixed", action="store_true")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="number of shared prompt-prefix families")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prefix KV pages via the radix cache")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--reduced",
                "--requests", str(args.requests),
                "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len),
                "--gen", str(args.gen)]
               + (["--mixed"] if args.mixed else [])
               + (["--shared-prefix", str(args.shared_prefix)]
                  if args.shared_prefix else [])
               + (["--prefix-cache"] if args.prefix_cache else []))


if __name__ == "__main__":
    main()
