"""Dual-gate parity verification for the int8 paged-KV mode.

With ``ServeConfig.kv_dtype == "int8"`` the serving stack is no longer
token-exact against the bf16 static baseline — quantizing the KV pages
perturbs every attention read — so the parity contract becomes a *dual
gate*, checked per request by replaying the engine's exact token sequence
through teacher-forced single-request paged steps twice (an int8 pool and a
bf16 pool, same params, same backend) and comparing full logit vectors:

1. **bounded logit error** — ``max |logits_int8 - logits_bf16|`` over every
   generated position must stay under a per-arch threshold
   (``LOGIT_TOL``).  This bounds how far quantization can move *any*
   decision, not just the argmax.
2. **exact greedy match at high-margin tokens** — wherever the bf16
   reference's top-1/top-2 logit margin exceeds ``2x`` the observed max
   error, the engine's emitted token must equal the bf16 greedy token.  A
   margin above twice the error bound means quantization provably cannot
   have flipped the argmax, so a mismatch there is a real bug (wrong scale
   gathered, stale page, backend divergence), never quantization noise.
   Low-margin positions — where bf16 itself was nearly undecided — are
   where int8 may legitimately pick the runner-up, and are excluded.

The replay harness doubles as a fidelity check: the int8 replay's greedy
argmax must reproduce the engine's tokens position-for-position (same
quantized compute, so exact), which catches teacher-forcing/meta bugs
independently of quantization error.

Used by ``serve --verify --kv-dtype int8`` and the quantization section of
``benchmarks/serve_throughput.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from ..configs.base import ArchConfig, ServeConfig
from ..models.attn_backend import decode_meta, get_backend, prefill_meta
from ..models.params import init_tree
from ..models.registry import build_model
from .kv_pool import PagedKVPool

# per-arch max-abs-logit-error thresholds (reduced configs, random-init
# params).  Measured headroom: observed errors sit well under half of these
# across backends and seeds; a regression that doubles the error trips the
# gate.  MLA gets a wider bound — the latent is quantized once but feeds
# both K and V materialization, so the error compounds through ``wkv_b``.
LOGIT_TOL: Dict[str, float] = {
    "deepseek-v2-236b": 0.5,
}
DEFAULT_LOGIT_TOL = 0.25


def logit_tol(cfg: ArchConfig) -> float:
    return LOGIT_TOL.get(cfg.name, DEFAULT_LOGIT_TOL)


def replay_logits(cfg: ArchConfig, scfg: ServeConfig, params, prompt:
                  Sequence[int], gen: Sequence[int], *, kv_dtype: str,
                  attn_backend: str = "reference") -> np.ndarray:
    """Teacher-force one request through single-request paged steps.

    Prefills ``prompt`` into a fresh one-request pool of ``kv_dtype`` pages,
    then decodes feeding the engine's own tokens ``gen[:-1]``, collecting
    the logits that predicted each ``gen[i]``.  Returns fp32
    [len(gen), vocab].  The pool geometry (page size, table width, max_len)
    matches the engine's, so the attend shapes — and therefore the
    reductions — are identical to the serving run."""
    if not gen:
        return np.zeros((0, cfg.vocab), np.float32)
    sub = dataclasses.replace(scfg, kv_dtype=kv_dtype, max_slots=1,
                              num_pages=0)
    model = build_model(cfg, attn_backend=attn_backend)
    pool = PagedKVPool(cfg, sub)
    assert pool.spec.paged, (
        f"{cfg.name}: kv_dtype only applies to paged attention families")
    need = pool.pages_for(len(prompt) + len(gen))
    pages = pool.alloc(need)
    assert pages is not None, "single-request replay pool sized too small"
    table = pool.new_table()
    table[:len(pages)] = pages
    tables = table[None, :]                                   # [1, width]
    state = init_tree(model.state_slot_defs(1, sub.max_len,
                                            enc_len=sub.enc_len),
                      jax.random.PRNGKey(0))

    # pad the prefill to a page multiple like the engine's buckets do (the
    # windowed kernel requires it); padding rows are masked, logits are read
    # at the last *live* token, so the width is numerically invisible
    T = len(prompt)
    Tp = -(-T // sub.page_size) * sub.page_size
    meta = prefill_meta(cfg, sub.page_size, tables, np.array([0]),
                        np.array([0], np.int32), np.array([T], np.int32), Tp)
    tokens = np.zeros((1, Tp), np.int32)
    tokens[0, :T] = prompt
    logits, kv, state = model.prefill_paged(params, pool.kv, state, meta,
                                            tokens)
    out = [np.asarray(logits[0], np.float32)]
    for i, tok in enumerate(gen[:-1]):
        pos = np.array([T + i], np.int32)
        meta_d = decode_meta(cfg, sub.page_size, tables, pos)
        logits, kv, state = model.decode_paged(
            params, kv, state, meta_d, np.array([tok], np.int32))
        out.append(np.asarray(logits[0], np.float32))
    return np.stack(out)


def dual_gate_verify(cfg: ArchConfig, scfg: ServeConfig, params,
                     prompts: Sequence[Sequence[int]],
                     engine_tokens: Sequence[Sequence[int]], *,
                     attn_backend: str = "reference",
                     tol: Optional[float] = None) -> Dict:
    """Run the dual gate over every request of an int8 engine run.

    ``engine_tokens`` are the greedy tokens the int8 engine emitted.
    Returns a report dict; ``report["ok"]`` aggregates all three checks
    (replay fidelity, bounded error, high-margin greedy match)."""
    tol = logit_tol(cfg) if tol is None else tol
    backend = get_backend(attn_backend).name
    per_request: List[Dict] = []
    max_err_all = 0.0
    for prompt, gen in zip(prompts, engine_tokens):
        li = replay_logits(cfg, scfg, params, prompt, gen,
                           kv_dtype="int8", attn_backend=backend)
        lb = replay_logits(cfg, scfg, params, prompt, gen,
                           kv_dtype="bf16", attn_backend=backend)
        err = (np.max(np.abs(li - lb)) if len(gen) else 0.0)
        max_err_all = max(max_err_all, float(err))
        per_request.append({"gen": list(gen), "int8": li, "bf16": lb,
                            "max_err": float(err)})

    n_high = n_mismatch = n_replay_bad = 0
    for r in per_request:
        li, lb, gen = r.pop("int8"), r.pop("bf16"), r["gen"]
        if not gen:
            r.update(high_margin=0, mismatches=0, replay_ok=True)
            continue
        # fidelity: the int8 replay is the engine's own arithmetic
        replay_ok = bool(np.array_equal(np.argmax(li, axis=-1), gen))
        n_replay_bad += not replay_ok
        # high-margin gate against the *globally* observed error bound: a
        # single error figure makes "provably cannot flip" uniform across
        # the run instead of per-request lucky
        top2 = np.sort(lb, axis=-1)[:, -2:]
        margin = top2[:, 1] - top2[:, 0]
        high = margin > 2.0 * max_err_all
        bf16_greedy = np.argmax(lb, axis=-1)
        mism = int(np.sum(high & (bf16_greedy != np.asarray(gen))))
        n_high += int(np.sum(high))
        n_mismatch += mism
        r.update(high_margin=int(np.sum(high)), mismatches=mism,
                 replay_ok=replay_ok)

    report = {
        "arch": cfg.name, "attn_backend": backend, "tol": tol,
        "max_logit_err": max_err_all,
        "n_requests": len(per_request),
        "n_tokens": sum(len(r["gen"]) for r in per_request),
        "high_margin_tokens": n_high,
        "high_margin_mismatches": n_mismatch,
        "replay_failures": n_replay_bad,
        "per_request": per_request,
    }
    report["ok"] = (max_err_all <= tol and n_mismatch == 0
                    and n_replay_bad == 0)
    return report


def format_report(report: Dict) -> str:
    """One human-readable line per gate, for serve --verify output."""
    lines = [
        f"[quant-verify] {report['arch']} backend={report['attn_backend']}: "
        f"{report['n_requests']} requests, {report['n_tokens']} tokens",
        f"[quant-verify] gate 1 (bounded error): max |dlogit| = "
        f"{report['max_logit_err']:.4f} vs tol {report['tol']:.4f} -> "
        f"{'OK' if report['max_logit_err'] <= report['tol'] else 'FAIL'}",
        f"[quant-verify] gate 2 (high-margin greedy): "
        f"{report['high_margin_mismatches']} mismatches over "
        f"{report['high_margin_tokens']} tokens with margin > 2x err -> "
        f"{'OK' if report['high_margin_mismatches'] == 0 else 'FAIL'}",
        f"[quant-verify] replay fidelity: "
        f"{report['replay_failures']} requests diverged from the engine -> "
        f"{'OK' if report['replay_failures'] == 0 else 'FAIL'}",
    ]
    return "\n".join(lines)
