"""Async streaming front-end over the continuous-batching ``Engine``.

The paper's end goal is an online recognition *service* — traffic arrives
open-loop, responses stream back as they decode.  This module is the bridge
between that world (an asyncio event loop speaking HTTP/SSE, see
``launch.serve_http``) and the engine's single-threaded hot loop:

``ServingLoop``
    Owns the engine on a dedicated **engine thread** driving
    ``Engine.pump()`` (the overlapped host/device pipeline; ``overlap=False``
    falls back to the synchronous ``step()``).  The event loop talks to it
    through two queues:

    * a **submit queue** of control messages (``submit`` / ``cancel``)
      drained at the top of every iteration, so admission happens between —
      never inside — engine steps;
    * a bounded **collect queue** carrying per-token events from the
      engine's ``on_token`` hook to the **detokenize worker thread**.  The
      bound is the backpressure contract: when the detokenizer falls behind,
      the engine thread blocks on ``put`` and stops decoding — the device
      never races ahead of what the host can deliver.  Per-stream asyncio
      queues downstream of the worker are unbounded; a single slow *client*
      buffers there without stalling the engine for everyone else.

    The detokenize worker turns token ids into text fragments off the hot
    loop and hands finished events into each request's ``asyncio.Queue`` via
    ``loop.call_soon_threadsafe`` — the only thread-crossing primitive used.

    Preemption replays re-fire early token indexes (greedy decode
    regenerates the identical prefix); ``ServingLoop`` dedups by index so a
    stream sees every token exactly once, in order — streamed output is
    token-exact against ``generate_static`` by construction.

``detokenize``
    Stand-in tokenizer: the repo serves synthetic token-id traffic, so a
    token renders as ``<id>``.  The seam is where a real tokenizer's
    incremental decode would plug in.

Events delivered into a stream's queue are plain dicts (JSON-ready):

    {"type": "token", "index": i, "token": t, "text": "<t>"}
    {"type": "done", "tokens": [...], "ttft_s": ..., "tpot_s": ...,
     "finish_s": ..., "n_preemptions": ...}
    {"type": "error", "error": "..."}     # rejected / cancelled / shed / fatal

``done``/``error`` are terminal: the loop forgets the stream afterwards.

**Fault tolerance.**  The loop owns three server-side recovery pieces (the
engine owns quarantine and deadlines, see ``serving/{faults,admission}``):

* the engine's :class:`~repro.serving.admission.HealthState` is advanced
  here — ``healthy`` once the engine thread is driving, ``degraded`` on a
  fatal engine error or watchdog trip, ``draining``/``drained`` around
  :meth:`drain` (new submissions shed with reason ``draining``; ``drained``
  once the engine has no work left);
* an optional **watchdog** (``watchdog_s > 0``): a monitor thread that trips
  when the engine thread makes no progress for ``watchdog_s`` seconds while
  streams are pending, fails every pending stream with a clean terminal
  error (delivered directly, bypassing the possibly-wedged event queue),
  and marks the server degraded — clients never hang on a dead engine;
* :meth:`admission_check`, the advisory front-door used by the HTTP layer
  to turn a predicted deadline miss into an immediate 503 + Retry-After
  *before* the SSE stream opens.
"""
from __future__ import annotations

import asyncio
import functools
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .engine import Engine, RequestResult


def detokenize(token: int) -> str:
    """Token id -> text fragment (stand-in for an incremental tokenizer)."""
    return f"<{token}>"


class ServingLoop:
    """Drives an ``Engine`` from its own thread and streams tokens into
    per-request ``asyncio.Queue``s on the event loop that called
    ``start()``."""

    def __init__(self, engine: Engine, *, overlap: bool = True,
                 collect_queue_size: int = 256, poll_s: float = 0.001,
                 watchdog_s: float = 0.0):
        self.engine = engine
        self.overlap = overlap
        self._poll_s = poll_s
        self._watchdog_s = watchdog_s
        self._submit: "queue.Queue[Tuple]" = queue.Queue()
        # bounded: the engine thread blocks here when the detokenizer falls
        # behind — backpressure instead of unbounded buffering
        self._events: "queue.Queue[Optional[Tuple]]" = queue.Queue(
            maxsize=collect_queue_size)
        self._streams: Dict[int, asyncio.Queue] = {}
        self._streamed: Dict[int, int] = {}    # rid -> tokens already emitted
        self._results: Dict[int, RequestResult] = {}
        self._next_rid = 0
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop = threading.Event()
        self._fatal: Optional[str] = None
        self._t_progress = time.monotonic()    # engine-thread liveness stamp
        self._engine_thread = threading.Thread(
            target=self._engine_main, name="engine", daemon=True)
        self._detok_thread = threading.Thread(
            target=self._detok_main, name="detokenize", daemon=True)
        self._watchdog_thread = threading.Thread(
            target=self._watchdog_main, name="watchdog", daemon=True) \
            if watchdog_s > 0 else None
        self._m_watchdog = engine.metrics.counter(
            "server.watchdog_trips", "hung-engine detections: no engine "
            "progress for watchdog_s with streams pending")
        engine.on_token = self._on_token

    # ----------------------------------------------------- event-loop side

    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._engine_thread.start()
        self._detok_thread.start()
        if self._watchdog_thread is not None:
            self._watchdog_thread.start()

    async def stop(self) -> None:
        self._stop.set()
        loop = asyncio.get_running_loop()
        # a healthy engine thread exits promptly on the stop flag; a hung
        # one (the watchdog case) is a daemon we abandon after a bounded
        # join — but its detok worker must still be unstuck
        join_s = 10.0 if self._fatal is not None else None
        await loop.run_in_executor(
            None, functools.partial(self._engine_thread.join, join_s))
        if self._engine_thread.is_alive():
            try:
                self._events.put_nowait(None)   # detok shutdown sentinel
            except queue.Full:
                pass
        await loop.run_in_executor(
            None, functools.partial(self._detok_thread.join, join_s))

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               deadline_s: Optional[float] = None,
               ttft_deadline_s: Optional[float] = None
               ) -> Tuple[int, asyncio.Queue]:
        """Queue a request; returns (rid, stream queue).  Call from the
        event loop thread only.  The queue yields token events followed by
        one terminal ``done``/``error`` event.  Deadlines are relative
        seconds passed through to ``Engine.add_request`` (inert unless
        admission control is on)."""
        if self._fatal is not None:
            raise RuntimeError(f"serving loop dead: {self._fatal}")
        rid = self._next_rid
        self._next_rid += 1
        q: asyncio.Queue = asyncio.Queue()
        self._streams[rid] = q
        self._submit.put(("submit", rid, [int(t) for t in prompt],
                          int(max_new_tokens), deadline_s, ttft_deadline_s))
        return rid, q

    def drain(self) -> None:
        """Begin a graceful drain: new submissions are shed with reason
        ``draining``; in-flight requests run to completion.  The health
        state reaches ``drained`` once the engine has no work left."""
        self.engine.health.begin_drain()

    def admission_check(self, deadline_s: Optional[float] = None,
                        ttft_deadline_s: Optional[float] = None
                        ) -> Optional[Tuple[str, float]]:
        """Advisory front-door check (event-loop thread): returns
        ``(reason, retry_after_s)`` if the request should be refused before
        its stream opens, else None.  Advisory only — the engine-side check
        in ``add_request`` is authoritative; this one exists so the HTTP
        layer can answer 503 instead of opening an SSE stream that
        immediately errors."""
        adm = self.engine.admission
        # queued work the engine knows about, plus submissions still in
        # flight to it (open streams beyond slot capacity) — the gauge alone
        # lags a burst, which would wave the whole burst through
        depth = max(int(self.engine.metrics.value("sched.queue_depth")),
                    len(self._streams) - self.engine.scfg.max_slots)
        if self.engine.health.draining:
            retry = adm.retry_after_s(depth) if adm is not None else 1.0
            self.engine._m_shed.labels(reason="draining").inc()
            return ("draining", retry)
        if adm is None:
            return None
        reason = adm.check(depth, deadline_s, ttft_deadline_s)
        if reason is None:
            return None
        self.engine._m_shed.labels(reason=reason).inc()
        return (reason, adm.retry_after_s(depth))

    def cancel(self, rid: int) -> None:
        """Abort a request (client disconnect).  The engine releases its
        slot/pages at the next loop iteration."""
        self._submit.put(("cancel", rid))

    def forget(self, rid: int) -> None:
        """Drop a stream's delivery queue (after its terminal event)."""
        self._streams.pop(rid, None)

    # -------------------------------------------------- engine-thread side

    def _on_token(self, rid: int, index: int, token: int, t: float) -> None:
        self._t_progress = time.monotonic()
        n = self._streamed.get(rid, 0)
        if index < n:
            return          # preemption replay: identical prefix, already out
        self._streamed[rid] = index + 1
        self._events.put(("token", rid, index, token, t))   # blocks when full

    def _engine_main(self) -> None:
        drive = self.engine.pump if self.overlap else self.engine.step
        health = self.engine.health
        health.mark_healthy()
        try:
            while not self._stop.is_set():
                self._t_progress = time.monotonic()
                busy = False
                while True:
                    try:
                        msg = self._submit.get_nowait()
                    except queue.Empty:
                        break
                    busy = True
                    if msg[0] == "submit":
                        _, rid, prompt, max_new, dl, ttft_dl = msg
                        try:
                            self.engine.add_request(
                                prompt, max_new, rid=rid, deadline_s=dl,
                                ttft_deadline_s=ttft_dl)
                        except ValueError as e:   # rid collision (loop bug)
                            self._events.put(("error", rid, str(e)))
                    else:
                        self.engine.cancel(msg[1])
                if drive():
                    busy = True
                for res in self.engine.collect():
                    busy = True
                    self._events.put(("done", res.rid, res))
                if not busy:
                    if (health.draining and not self.engine.sched.has_work()
                            and self._submit.empty()):
                        health.mark_drained()
                    self._stop.wait(self._poll_s)
        except Exception as e:              # scheduler deadlock, OOM, ...
            self._fatal = f"{type(e).__name__}: {e}"
            health.mark_degraded(self._fatal)
            for rid in list(self._streams):
                self._events.put(("error", rid, self._fatal))
        finally:
            self._events.put(None)          # detok worker shutdown sentinel

    # ----------------------------------------------------- watchdog thread

    def _watchdog_main(self) -> None:
        """Trip when the engine thread stalls: no progress stamp for
        ``watchdog_s`` while streams are pending.  Fails every pending
        stream directly (``_deliver`` bypasses the possibly-wedged event
        queue) so clients see a terminal error instead of hanging."""
        period = max(self._watchdog_s / 4, 0.01)
        while not self._stop.wait(period):
            if not self._streams and self._submit.empty():
                self._t_progress = time.monotonic()   # idle: nothing to watch
                continue
            stale = time.monotonic() - self._t_progress
            if stale < self._watchdog_s:
                continue
            self._fatal = (f"watchdog: engine made no progress for "
                           f"{stale:.1f}s with requests pending")
            self._m_watchdog.inc()
            self.engine.health.mark_degraded("watchdog_timeout")
            for rid in list(self._streams):
                self._deliver(rid, {"type": "error", "error": self._fatal})
            return

    # --------------------------------------------------- detok-worker side

    def _detok_main(self) -> None:
        injector = getattr(self.engine, "injector", None)
        while True:
            ev = self._events.get()
            if ev is None:
                return
            if ev[0] == "token":
                if injector is not None:
                    injector.on_detok(time.sleep)   # detok_stall fault seam
                _, rid, index, token, t = ev
                self._deliver(rid, {"type": "token", "index": index,
                                    "token": token,
                                    "text": detokenize(token)})
            elif ev[0] == "done":
                _, rid, res = ev
                self._streamed.pop(rid, None)
                self._results[rid] = res
                if res.failed:
                    self._deliver(rid, {"type": "error", "error": res.error,
                                        "tokens": res.tokens,
                                        "retry_after_s": res.retry_after_s})
                else:
                    self._deliver(rid, {
                        "type": "done", "tokens": res.tokens,
                        "text": "".join(detokenize(t) for t in res.tokens),
                        "ttft_s": res.ttft_s, "tpot_s": res.tpot_s,
                        "finish_s": res.finish_s,
                        "n_preemptions": res.n_preemptions,
                        "cached_tokens": res.cached_tokens})
            else:                           # ("error", rid, msg)
                _, rid, msg = ev
                self._streamed.pop(rid, None)
                self._deliver(rid, {"type": "error", "error": msg})

    def _deliver(self, rid: int, payload: Dict[str, Any]) -> None:
        q = self._streams.get(rid)
        loop = self._loop
        if q is None or loop is None or loop.is_closed():
            return
        try:
            loop.call_soon_threadsafe(q.put_nowait, payload)
        except RuntimeError:
            pass                            # loop shut down mid-delivery


async def stream_request(serving: ServingLoop, prompt: Sequence[int],
                         max_new_tokens: int = 16,
                         timeout_s: float = 120.0) -> List[Dict[str, Any]]:
    """Submit one request and await its full event stream (tokens + the
    terminal event) — the in-process client used by tests and the Poisson
    benchmark."""
    rid, q = serving.submit(prompt, max_new_tokens)
    events: List[Dict[str, Any]] = []
    deadline = time.monotonic() + timeout_s
    while True:
        ev = await asyncio.wait_for(q.get(),
                                    timeout=max(deadline - time.monotonic(),
                                                0.001))
        events.append(ev)
        if ev["type"] in ("done", "error"):
            serving.forget(rid)
            return events
