"""repro.serving — continuous-batching inference engine with a paged KV cache.

The paper's end goal is an online recognition *service*: a MapReduce-trained
network absorbing live traffic.  This package is the serving half of that
story, built from the three standard pieces of a modern LLM-serving stack:

``kv_pool``
    Paged KV cache pool.  KV for every live request lives in one
    ``[L, num_pages, page_size, K, D]`` array pair; requests reference page
    sets tracked by an int32 page table, allocation is an O(1) host-side
    free list, and physical page 0 is a reserved write sink for idle slots.
    Ownership is refcounted (``alloc``/``share``/``release``) so the radix
    prefix cache and any number of slots can co-own a page — it returns to
    the free list only when the last owner releases it.

``radix_cache``
    SGLang-style radix-tree prefix cache with page-quantized edges: every
    node is one full KV page keyed by its token tuple.  Admission matches
    each prompt against the tree, shares the matched full pages, forks a
    partially-matched page copy-on-write, and prefills only the uncached
    tail.  Unlocked leaves are LRU-evicted when the free list runs dry.

``scheduler``
    Continuous-batching policy: an admission queue with all-or-nothing,
    cache-aware admission, prefill/decode interleaving (prefill has
    priority — keeping slots full is the throughput lever), page-granular
    growth with LRU cache eviction then youngest-first preemption when the
    pool runs dry, and slot eviction on EOS or max-len.

``engine``
    Synchronous driver: ``Engine.add_request() / step() / collect()`` plus
    the ``run_offline(prompts)`` batch front-end with per-request latency
    (TTFT, total), cached-token counts, and aggregate tokens/s / hit-rate
    metrics.  Exactly ``len(buckets) + 2`` programs are compiled — one
    single-request tail prefill per length bucket, one fixed-shape
    ``[max_slots]`` paged decode step, and one COW page-copy — so the
    traffic mix never causes recompilation (and the steps are cached per
    ``ArchConfig``, shared by every Engine instance).
    ``generate_static`` is the static-batching baseline (contiguous caches,
    batch padded together, slowest member gates the batch) kept for
    verification and benchmark comparison.

``speculate``
    Weight-free speculative decoding: an n-gram prompt-lookup proposer
    drafts K tokens per decode-ready slot from the request's own history;
    the engine verifies draft + next token in one fixed-shape small-q step
    (``DecoderLM.verify_paged``) and accepts the longest draft prefix the
    verify argmax reproduces — emitted tokens stay token-for-token
    identical to non-speculative greedy decode
    (``ServeConfig.speculate_tokens``).

``server``
    Async streaming front-end: ``ServingLoop`` drives the engine's
    overlapped pipeline (``Engine.pump()`` — host plan for step N+1 staged
    while step N runs on device) from a dedicated thread and streams each
    token into per-request asyncio queues through a bounded collect queue
    plus a detokenize worker (backpressure: a slow detokenizer throttles
    the engine; a slow *client* only buffers its own stream).  The HTTP/SSE
    layer over it lives in ``launch.serve_http``.

``faults`` / ``admission``
    Fault tolerance.  ``faults`` is a deterministic fault-injection harness
    (seeded ``FaultPlan`` parsed from ``kind:k=v,...`` specs) wired into the
    engine's seams — poisoned logits, raised step errors, page-pool
    pressure, client disconnects, detokenizer stalls — with the
    **exact-survivor contract**: the engine quarantines only the offending
    request (terminal error, pages scrubbed then released) and every
    survivor's tokens stay byte-identical to a fault-free run
    (``launch.serve --inject ... --verify``).  ``admission`` adds
    deadline-aware admission control (EWMA-calibrated queue-wait estimate,
    shed with jittered Retry-After hints), mid-flight deadline eviction,
    and the ``starting → healthy → degraded/draining → drained`` health
    state machine behind ``GET /health``.

``telemetry``
    Observability layer threaded through all of the above: a typed metrics
    registry (counters / gauges / histograms, optional labels) shared by
    pool, radix cache, scheduler and engine, plus a request-lifecycle
    tracer emitting Chrome-trace-event JSON (``queued -> admitted ->
    prefill_chunk[i] -> decode -> preempted/restored -> finished`` per
    request, one span per engine step) viewable in Perfetto.  Both are
    pure host-side bookkeeping: with telemetry on, ``--verify`` stays
    token-exact.  See ``launch.trace_report`` for the offline analyzer and
    ``serving/README.md`` for the metrics catalogue.

Model-side support lives behind the attention-backend registry
(``models.attn_backend``: XLA ``reference`` gather+attend or the fused
``pallas`` paged-attention decode kernel) reached via
``models.transformer.DecoderLM.decode_paged``; knobs (page size, slot count,
length caps, buckets, EOS, ``attn_backend``) in ``configs.base.ServeConfig``.

Quick start::

    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine

    cfg = reduced(get_arch("qwen2-0.5b"))
    eng = Engine(cfg, ServeConfig(max_slots=8))
    results, metrics = eng.run_offline([[1, 2, 3], [4, 5]], max_new_tokens=16)

or from the CLI::

    python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --engine continuous --requests 16 --mixed --verify

Covered: every registered non-DBN arch, through the cache-family taxonomy
of ``models.cache_spec`` — token-addressable KV pages (dense / GQA / MQA /
MoE), MLA absorbed-latent pages, sliding-window page rings (O(window) pages
per request, recycled in place), SSM / RG-LRU state slots (one per request,
checkpoint-on-preempt), and the enc-dec pinned cross cache.  The radix
prefix cache is scoped to prefix-cacheable families (immutable
token-addressable prompt pages: plain KV and MLA); elsewhere
``prefix_cache=True`` logs a warning and serves uncached.
"""
from __future__ import annotations

from .admission import AdmissionController, HealthState  # noqa: F401
from .engine import Engine, RequestResult, generate_static  # noqa: F401
from .faults import (  # noqa: F401
    FAULT_KINDS, Fault, FaultInjector, FaultPlan, RequestFault)
from .kv_pool import NULL_PAGE, PagedKVPool, StateSlotPool  # noqa: F401
from .quant_verify import (  # noqa: F401
    dual_gate_verify, format_report, logit_tol, replay_logits)
from .radix_cache import MatchResult, RadixCache  # noqa: F401
from .scheduler import Admission, Request, Scheduler  # noqa: F401
from .server import ServingLoop, detokenize, stream_request  # noqa: F401
from .speculate import (  # noqa: F401
    NgramProposer, accept_length, speculation_k)
from .telemetry import (  # noqa: F401
    MetricsRegistry, Tracer, percentile, shared_metrics, validate_trace)
