"""repro.serving — continuous-batching inference engine with a paged KV cache.

The paper's end goal is an online recognition *service*: a MapReduce-trained
network absorbing live traffic.  This package is the serving half of that
story, built from the three standard pieces of a modern LLM-serving stack:

``kv_pool``
    Paged KV cache pool.  KV for every live request lives in one
    ``[L, num_pages, page_size, K, D]`` array pair; requests own disjoint
    page sets tracked by an int32 page table, allocation is an O(1)
    host-side free list, and physical page 0 is a reserved write sink for
    idle slots.  Replaces the old ``pad_cache_to`` whole-cache zero-pad copy
    — admitting or retiring a request no longer touches device memory.

``scheduler``
    Continuous-batching policy: an admission queue, prefill/decode
    interleaving (prefill has priority — keeping slots full is the
    throughput lever), page-granular growth with youngest-first preemption
    when the pool runs dry, and slot eviction on EOS or max-len.

``engine``
    Synchronous driver: ``Engine.add_request() / step() / collect()`` plus
    the ``run_offline(prompts)`` batch front-end with per-request latency
    (TTFT, total) and aggregate tokens/s / requests/s metrics.  Exactly
    ``len(buckets) + 1`` programs are compiled — one single-request prefill
    per prompt-length bucket and one fixed-shape ``[max_slots]`` paged
    decode step — so the traffic mix never causes recompilation.
    ``generate_static`` is the static-batching baseline (contiguous caches,
    batch padded together, slowest member gates the batch) kept for
    verification and benchmark comparison.

Model-side support lives in ``models.attention.paged_decode_attention_block``
(slot-indexed paged reads/writes) and ``models.transformer.DecoderLM
.decode_paged``; knobs (page size, slot count, length caps, buckets, EOS) in
``configs.base.ServeConfig``.

Quick start::

    from repro.configs import ServeConfig, get_arch, reduced
    from repro.serving import Engine

    cfg = reduced(get_arch("qwen2-0.5b"))
    eng = Engine(cfg, ServeConfig(max_slots=8))
    results, metrics = eng.run_offline([[1, 2, 3], [4, 5]], max_new_tokens=16)

or from the CLI::

    python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --engine continuous --requests 16 --mixed --verify

Covered: dense / GQA / MQA and MoE decoder LMs.  Not yet paged: MLA's
absorbed cache, sliding-window ring buffers, SSM/RG-LRU state, enc-dec
cross-attention (the engine raises NotImplementedError for those).
"""
from __future__ import annotations

from .engine import Engine, RequestResult, generate_static  # noqa: F401
from .kv_pool import NULL_PAGE, PagedKVPool  # noqa: F401
from .scheduler import Request, Scheduler  # noqa: F401
