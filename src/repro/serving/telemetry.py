"""Serving telemetry: metrics registry + request-lifecycle tracer.

The paper's central claim is an *efficiency* claim — parallelization
"accelerates computation" — and the serving stack can only defend (or
optimize) that claim if a step's time is attributable.  This module is the
measurement layer every serving component reports into:

``MetricsRegistry``
    Typed counters / gauges / histograms with optional labels, registered by
    dotted name (``pool.pages_allocated``, ``sched.admissions{kind=...}``).
    Registration is idempotent — ``registry.counter("x")`` returns the
    existing metric on a second call — so each component declares what it
    emits without coordination.  ``snapshot()`` renders everything to plain
    JSON (histograms as count/sum/percentiles), the shape ``--metrics-json``
    dumps and the benchmark embeds.  All operations are O(1) host-side dict
    and list work: the decode hot loop can afford them (<2% of a step).

``Tracer``
    Request-lifecycle + engine-phase tracing in Chrome trace-event JSON
    (the ``{"traceEvents": [...]}`` format Perfetto / ``chrome://tracing``
    load directly).  Two tracks:

    * **engine** (pid 1) — one complete ("X") event per ``Engine.step``:
      ``prefill`` / ``prefill_chunk`` / ``restore`` / ``decode``, with args
      recording the rows served and whether decode-ready slots sat parked
      behind the step (``decode_waiting`` — stall attribution).
    * **requests** (pid 2, tid = rid) — per-request spans
      ``queued → prefill_chunk[i]... → decode`` plus ``preempted`` /
      ``restored`` instants and a terminal ``finished`` instant whose args
      carry the request's summary (ttft, tpot, chunk count, preemptions).

    The tracer also keeps a per-rid lifecycle record (arrival, admission,
    first token, finish, chunk count, preemptions) that the engine reads
    back into each ``RequestResult`` — per-request timing comes from one
    place.  ``annotate(name)`` optionally wraps the jitted steps in
    ``jax.profiler.TraceAnnotation`` so these host spans line up with
    device timelines when a jax profiler trace is being captured.

``shared_metrics``
    The one end-of-run metrics schema both engines emit
    (``generate_static`` and ``Engine.run_offline``), so BENCH_serve.json
    rows are comparable column-for-column; ``percentile`` is the shared
    percentile helper.

``validate_trace``
    Well-formedness checker for an emitted trace: monotonic non-negative
    timestamps, properly nested spans per track, and every admitted rid
    reaching a terminal ``finished`` event.  Used by tests and
    ``launch/trace_report.py --validate`` (and CI).

The hard contract, inherited from ``--verify``: telemetry records time, it
never participates in scheduling or math — turning it on must not change a
single emitted token.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------- helpers


def percentile(xs: Sequence[float], q: float) -> float:
    """Shared percentile helper (0.0 on empty input)."""
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs), q))


# ---------------------------------------------------------------- metrics


class Counter:
    """Monotonically increasing count (events, tokens, pages)."""
    kind = "counter"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.value = name, help, 0

    def inc(self, n: int = 1) -> None:
        assert n >= 0, f"counter {self.name} decremented"
        self.value += n


class Gauge:
    """Point-in-time level (queue depth, live pages, claimed slots)."""
    kind = "gauge"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.value = name, help, 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def dec(self, n=1) -> None:
        self.value -= n


class Histogram:
    """Distribution of observed values (step times, stall times)."""
    kind = "histogram"
    __slots__ = ("name", "help", "values")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def total(self) -> float:
        return float(sum(self.values))

    def percentile(self, q: float) -> float:
        return percentile(self.values, q)

    @property
    def max(self) -> float:
        return max(self.values) if self.values else 0.0


class LabeledFamily:
    """A metric family fanned out over label values.

    ``family.labels(reason="no_pages")`` returns (creating on first use) the
    child metric for that label combination; children appear in snapshots as
    ``name{reason=no_pages}``."""

    def __init__(self, ctor, name: str, help: str, label_names: Tuple[str, ...]):
        self._ctor = ctor
        self.name, self.help = name, help
        self.label_names = tuple(label_names)
        self.kind = ctor.kind
        self.children: Dict[Tuple[str, ...], Any] = {}

    def labels(self, **kv) -> Any:
        assert set(kv) == set(self.label_names), \
            f"{self.name}: labels {sorted(kv)} != {sorted(self.label_names)}"
        key = tuple(str(kv[k]) for k in self.label_names)
        child = self.children.get(key)
        if child is None:
            suffix = ",".join(f"{k}={v}"
                              for k, v in zip(self.label_names, key))
            child = self._ctor(f"{self.name}{{{suffix}}}", self.help)
            self.children[key] = child
        return child

    def items(self) -> Iterator[Tuple[Tuple[str, ...], Any]]:
        return iter(sorted(self.children.items()))


class MetricsRegistry:
    """Named typed metrics; each serving component registers what it emits.

    Registration is idempotent by name (the metric type must match), so the
    pool, cache, scheduler, and engine can all hold references into one
    registry without ordering constraints."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _register(self, ctor, name: str, help: str,
                  labels: Tuple[str, ...]) -> Any:
        m = self._metrics.get(name)
        if m is not None:
            assert m.kind == ctor.kind, \
                f"metric {name} re-registered as {ctor.kind}, was {m.kind}"
            return m
        m = LabeledFamily(ctor, name, help, labels) if labels \
            else ctor(name, help)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: Tuple[str, ...] = ()) -> Any:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Tuple[str, ...] = ()) -> Any:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Tuple[str, ...] = ()) -> Any:
        return self._register(Histogram, name, help, labels)

    def get(self, name: str) -> Any:
        return self._metrics.get(name)

    def value(self, name: str, default=0):
        """Scalar value of a plain counter/gauge (default if unregistered)."""
        m = self._metrics.get(name)
        return default if m is None else m.value

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Plain-JSON view: {counters: {...}, gauges: {...}, histograms:
        {name: {count, sum, p50, p95, max}}}, labeled children flattened to
        ``name{k=v}`` keys."""
        out: Dict[str, Dict[str, Any]] = {
            "counters": {}, "gauges": {}, "histograms": {}}

        def emit(m):
            if m.kind == "histogram":
                out["histograms"][m.name] = {
                    "count": m.count, "sum": m.total,
                    "p50": m.percentile(50), "p95": m.percentile(95),
                    "max": m.max}
            else:
                out[m.kind + "s"][m.name] = m.value

        for m in self._metrics.values():
            if isinstance(m, LabeledFamily):
                for _, child in m.items():
                    emit(child)
            else:
                emit(m)
        return out


# ----------------------------------------------------------------- tracer

# Chrome trace-event track layout (pid/tid are just track ids to Perfetto)
ENGINE_PID = 1
REQUEST_PID = 2
HOST_TID = 1       # engine-process track for the overlapped host pipeline:
                   # dispatch / stage / collect spans emitted by Engine.pump()
                   # sit beside the step track (tid 0) so the overlap is
                   # visible in Perfetto


@dataclasses.dataclass
class RequestRecord:
    """Per-rid lifecycle bookkeeping the engine reads back into results."""
    arrival: float = 0.0
    t_queued: float = 0.0               # last (re-)queue time (preemptions)
    t_admitted: Optional[float] = None
    t_first: Optional[float] = None
    t_finish: Optional[float] = None
    n_chunks: int = 0                   # prefill calls incl. replays
    n_preemptions: int = 0
    n_restores: int = 0
    terminal: bool = False


class Tracer:
    """Request-lifecycle + engine-phase tracer (Chrome trace-event JSON).

    All methods are host-side list/dict appends on a perf_counter clock;
    ``enabled=False`` turns every hook into a cheap early return (used by
    standalone Scheduler construction in tests).  ``jax_annotations=True``
    makes ``annotate(name)`` wrap jitted step dispatches in
    ``jax.profiler.TraceAnnotation`` so a concurrently captured device
    profile carries the same phase names."""

    def __init__(self, enabled: bool = True, jax_annotations: bool = False):
        self.enabled = enabled
        self.jax_annotations = jax_annotations
        self.t0 = time.perf_counter()       # trace epoch (ts are relative)
        self.events: List[Dict[str, Any]] = []
        self.requests: Dict[int, RequestRecord] = {}
        self._steps = 0

    # ------------------------------------------------------------- plumbing

    def now(self) -> float:
        return time.perf_counter()

    def _ts(self, t: float) -> float:
        return (t - self.t0) * 1e6          # seconds -> trace microseconds

    def span(self, pid: int, tid: int, name: str, t_start: float,
             t_end: float, **args) -> None:
        """One complete ("X") event covering [t_start, t_end] (abs seconds)."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "ph": "X", "pid": pid, "tid": tid,
            "cat": "engine" if pid == ENGINE_PID else "request",
            "ts": self._ts(t_start),
            "dur": max(self._ts(t_end) - self._ts(t_start), 0.0),
            "args": args})

    def instant(self, pid: int, tid: int, name: str, t: float,
                **args) -> None:
        if not self.enabled:
            return
        self.events.append({
            "name": name, "ph": "i", "s": "t", "pid": pid, "tid": tid,
            "cat": "engine" if pid == ENGINE_PID else "request",
            "ts": self._ts(t), "args": args})

    def annotate(self, name: str):
        """Context manager for one jitted step dispatch: a
        ``jax.profiler.TraceAnnotation`` when enabled, else a no-op."""
        if self.enabled and self.jax_annotations:
            import jax
            return jax.profiler.TraceAnnotation(name)
        return contextlib.nullcontext()

    # -------------------------------------------------------- engine phases

    def step_span(self, name: str, t_start: float, t_end: float,
                  **args) -> None:
        """One engine step (prefill / prefill_chunk / restore / decode)."""
        if not self.enabled:
            return
        args.setdefault("step", self._steps)
        self._steps += 1
        self.span(ENGINE_PID, 0, name, t_start, t_end, **args)

    def host_span(self, name: str, t_start: float, t_end: float,
                  **args) -> None:
        """One host-pipeline phase (dispatch / stage / collect) of an
        overlapped ``Engine.pump()`` step, on its own engine-process track."""
        self.span(ENGINE_PID, HOST_TID, name, t_start, t_end, **args)

    # ---------------------------------------------------- request lifecycle

    def _rec(self, rid: int) -> RequestRecord:
        rec = self.requests.get(rid)
        if rec is None:
            rec = self.requests[rid] = RequestRecord()
        return rec

    def on_queued(self, rid: int, t: float) -> None:
        if not self.enabled:
            return
        rec = self._rec(rid)
        rec.arrival = rec.arrival or t
        rec.t_queued = t

    def on_admitted(self, rid: int, t: float, cached_tokens: int = 0,
                    kind: str = "prefill") -> None:
        """Queued -> admitted transition (also re-admissions after
        preemption); closes the rid's ``queued`` span."""
        if not self.enabled:
            return
        rec = self._rec(rid)
        rec.t_admitted = t
        self.span(REQUEST_PID, rid, "queued", rec.t_queued, t,
                  cached_tokens=cached_tokens, kind=kind)

    def on_chunk(self, rid: int, t_start: float, t_end: float,
                 n_done: int, n_chunk: int) -> None:
        """One prefill chunk of this rid's prompt ran in [t_start, t_end]."""
        if not self.enabled:
            return
        rec = self._rec(rid)
        self.span(REQUEST_PID, rid, "prefill_chunk", t_start, t_end,
                  index=rec.n_chunks, n_done=n_done, n_chunk=n_chunk)
        rec.n_chunks += 1

    def on_first_token(self, rid: int, t: float) -> None:
        """Idempotent: TTFT is the first token *ever* produced, so a
        preemption replay re-earning token 0 does not move it."""
        if self.enabled:
            rec = self._rec(rid)
            if rec.t_first is None:
                rec.t_first = t

    def on_preempted(self, rid: int, t: float, checkpointed: bool) -> None:
        # note rec.t_first survives a replay: ttft_s measures the first
        # token ever produced, matching the legacy RequestResult.ttft
        if not self.enabled:
            return
        rec = self._rec(rid)
        rec.n_preemptions += 1
        rec.t_queued = t
        self.instant(REQUEST_PID, rid, "preempted", t,
                     checkpointed=checkpointed)

    def on_rejected(self, rid: int, t: float, reason: str) -> None:
        """Terminal transition for a request that never ran: a graceful
        admission rejection (no token budget) or a cancellation while still
        queued.  Emits a ``rejected`` instant, which the validator accepts
        as this rid's terminal event."""
        if not self.enabled:
            return
        rec = self._rec(rid)
        rec.arrival = rec.arrival or t
        rec.t_finish = t
        rec.terminal = True
        self.instant(REQUEST_PID, rid, "rejected", t, reason=reason)

    def on_restored(self, rid: int, t: float) -> None:
        if not self.enabled:
            return
        self._rec(rid).n_restores += 1
        self.instant(REQUEST_PID, rid, "restored", t)

    def on_finished(self, rid: int, t: float, n_tokens: int,
                    error: str = "") -> None:
        """Terminal transition: closes the rid's ``decode`` span and emits
        the ``finished`` instant with the request's summary args.  A
        nonempty ``error`` marks a mid-flight failure terminal (quarantine,
        cancel, deadline eviction) — same instant, extra ``error`` arg, so
        trace consumers see exactly one terminal per rid either way."""
        if not self.enabled:
            return
        rec = self._rec(rid)
        rec.t_finish = t
        rec.terminal = True
        t_first = rec.t_first if rec.t_first is not None else t
        self.span(REQUEST_PID, rid, "decode", t_first, t, n_tokens=n_tokens)
        extra = {"error": error} if error else {}
        self.instant(
            REQUEST_PID, rid, "finished", t,
            ttft_s=t_first - rec.arrival, finish_s=t - rec.arrival,
            tpot_s=(t - t_first) / max(n_tokens - 1, 1),
            n_tokens=n_tokens, n_prefill_chunks=rec.n_chunks,
            n_preemptions=rec.n_preemptions, **extra)

    # ------------------------------------------------------------ emission

    def to_dict(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (load in Perfetto / chrome://tracing)."""
        meta = [
            {"ph": "M", "pid": ENGINE_PID, "tid": 0, "name": "process_name",
             "args": {"name": "engine"}},
            {"ph": "M", "pid": ENGINE_PID, "tid": 0, "name": "thread_name",
             "args": {"name": "steps"}},
            {"ph": "M", "pid": REQUEST_PID, "tid": 0, "name": "process_name",
             "args": {"name": "requests"}},
        ]
        if any(e.get("pid") == ENGINE_PID and e.get("tid") == HOST_TID
               for e in self.events):
            meta.append(
                {"ph": "M", "pid": ENGINE_PID, "tid": HOST_TID,
                 "name": "thread_name", "args": {"name": "host pipeline"}})
        meta += [{"ph": "M", "pid": REQUEST_PID, "tid": rid,
                  "name": "thread_name", "args": {"name": f"request {rid}"}}
                 for rid in sorted(self.requests)]
        return {"traceEvents": meta + self.events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)


# ------------------------------------------------------- trace validation


def validate_trace(trace: Dict[str, Any]) -> List[str]:
    """Well-formedness problems of a Chrome trace dict ([] when clean).

    Checks: timestamps finite, non-negative, with non-negative durations;
    spans on each (pid, tid) track properly nested (disjoint or contained —
    no partial overlap); per-request lifecycle ordering (queued ends before
    decode starts); and every rid that was admitted (has any span) reaches a
    terminal ``finished`` instant."""
    problems: List[str] = []
    events = [e for e in trace.get("traceEvents", []) if e.get("ph") != "M"]
    tracks: Dict[Tuple[int, int], List[Dict[str, Any]]] = {}
    for e in events:
        ts = e.get("ts")
        if ts is None or not np.isfinite(ts) or ts < 0:
            problems.append(f"bad ts {ts!r} on event {e.get('name')!r}")
            continue
        if e.get("ph") == "X":
            dur = e.get("dur", 0.0)
            if not np.isfinite(dur) or dur < 0:
                problems.append(
                    f"bad dur {dur!r} on span {e.get('name')!r}")
                continue
        tracks.setdefault((e.get("pid"), e.get("tid")), []).append(e)

    eps = 1.0                               # float slack, microseconds
    for (pid, tid), evs in sorted(tracks.items()):
        spans = sorted((e for e in evs if e["ph"] == "X"),
                       key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        stack: List[Tuple[float, str]] = []  # (end ts, name)
        for e in spans:
            start, end = e["ts"], e["ts"] + e.get("dur", 0.0)
            while stack and stack[-1][0] <= start + eps:
                stack.pop()
            if stack and end > stack[-1][0] + eps:
                problems.append(
                    f"track ({pid},{tid}): span {e['name']!r} "
                    f"[{start:.0f},{end:.0f}] partially overlaps "
                    f"{stack[-1][1]!r} (ends {stack[-1][0]:.0f})")
            stack.append((end, e["name"]))

        if pid == REQUEST_PID:
            names = {e["name"] for e in evs}
            if not any(e["ph"] == "i" and e["name"] in ("finished", "rejected")
                       for e in evs):
                problems.append(
                    f"request {tid}: admitted (spans {sorted(names)}) but "
                    f"never reached a terminal 'finished'/'rejected' event")
            queued_ends = [e["ts"] + e.get("dur", 0.0) for e in evs
                          if e["ph"] == "X" and e["name"] == "queued"]
            decodes = [e["ts"] for e in evs
                       if e["ph"] == "X" and e["name"] == "decode"]
            if queued_ends and decodes \
                    and min(decodes) + eps < min(queued_ends):
                problems.append(
                    f"request {tid}: decode span starts before first "
                    f"admission")
    return problems


# ------------------------------------------------- shared metrics schema

#: Every key both serving paths emit, column-for-column.  The engine path
#: layers its extras (cache hit rate is only meaningful with a radix cache,
#: stall only with interleaved scheduling) but the *keys* are always present
#: in both, with honest zero defaults where a path cannot measure the value.
SHARED_METRIC_KEYS = (
    "n_requests", "new_tokens", "wall_s", "tokens_per_s", "requests_per_s",
    "latency_p50_s", "latency_p95_s", "ttft_p50_s", "ttft_p95_s",
    "prompt_tokens", "cached_tokens", "prefill_tokens", "cache_hit_rate",
    "prefill_steps", "prefill_padded_tokens", "prefill_actual_tokens",
    "prefill_padding_waste", "decode_steps", "decode_step_ms_p50",
    "decode_step_ms_p95", "decode_stall_ms_p50", "decode_stall_ms_p95",
    "decode_stall_ms_max",
)


def shared_metrics(n_requests: int, n_tokens: int,
                   latencies: Sequence[float], wall: float, *,
                   ttfts: Sequence[float] = (),
                   prompt_tokens: int = 0, cached_tokens: int = 0,
                   prefill_steps: int = 0,
                   prefill_padded_tokens: int = 0,
                   prefill_actual_tokens: int = 0,
                   decode_step_s: Sequence[float] = (),
                   decode_stall_s: Sequence[float] = ()) -> Dict[str, Any]:
    """The one end-of-run metrics schema both engines report."""
    stalls = list(decode_stall_s) or [0.0]
    m = {
        "n_requests": n_requests,
        "new_tokens": n_tokens,
        "wall_s": wall,
        "tokens_per_s": n_tokens / max(wall, 1e-9),
        "requests_per_s": n_requests / max(wall, 1e-9),
        "latency_p50_s": percentile(latencies, 50),
        "latency_p95_s": percentile(latencies, 95),
        "ttft_p50_s": percentile(ttfts, 50),
        "ttft_p95_s": percentile(ttfts, 95),
        "prompt_tokens": prompt_tokens,
        "cached_tokens": cached_tokens,
        "prefill_tokens": prompt_tokens - cached_tokens,
        "cache_hit_rate": cached_tokens / max(prompt_tokens, 1),
        "prefill_steps": prefill_steps,
        "prefill_padded_tokens": prefill_padded_tokens,
        "prefill_actual_tokens": prefill_actual_tokens,
        "prefill_padding_waste": 1.0 - (prefill_actual_tokens
                                        / max(prefill_padded_tokens, 1)),
        "decode_steps": len(decode_step_s),
        "decode_step_ms_p50": percentile(decode_step_s, 50) * 1e3,
        "decode_step_ms_p95": percentile(decode_step_s, 95) * 1e3,
        "decode_stall_ms_p50": percentile(stalls, 50) * 1e3,
        "decode_stall_ms_p95": percentile(stalls, 95) * 1e3,
        "decode_stall_ms_max": max(stalls) * 1e3,
    }
    assert set(m) == set(SHARED_METRIC_KEYS)
    return m
