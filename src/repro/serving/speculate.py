"""Weight-free speculative decoding: n-gram prompt-lookup drafts.

The paper's thesis — batch the sequential bottleneck into one parallel
device launch — applied to the decode loop: instead of one q_len=1 step per
token, the engine drafts K candidate tokens per decode-ready slot from the
request's *own* token history (prompt + generation so far), verifies all of
them plus the usual next token in a single fixed-shape small-q step, and
keeps the longest draft prefix the verify argmax reproduces.  Greedy
acceptance makes the exactness contract absolute: the emitted stream is
token-for-token identical to non-speculative greedy decode, the only thing
speculation changes is how many device launches it takes.

The proposer is prompt-lookup decoding (Saxena, 2023; vLLM's ``ngram``
speculator): find the most recent earlier occurrence of the trailing
n-gram and propose the tokens that followed it.  It has no weights, costs
O(history) python per step, and wins exactly where decode is most wasteful
— repetitive continuations (code, extraction, structured output) — while
degrading to accept-rate ~0 (never to wrong tokens) on adversarial text.
"""
from __future__ import annotations

from typing import List, Sequence

from ..configs.base import ArchConfig, ServeConfig
from ..models.cache_spec import CacheFamilySpec


def speculation_k(cfg: ArchConfig, spec: CacheFamilySpec,
                  scfg: ServeConfig) -> int:
    """Effective draft length for this (arch, serving-config) pair.

    Speculation needs the paged small-q verify step, so state-slot families
    (ssm / hybrid) and enc-dec serve non-speculatively even when
    ``speculate_tokens`` is set — the gate lives here so the engine and the
    scheduler agree on one rule."""
    if scfg.speculate_tokens <= 0:
        return 0
    if not spec.paged or cfg.enc_dec:
        return 0
    return scfg.speculate_tokens


class NgramProposer:
    """Prompt-lookup draft proposer.

    Matches the longest trailing n-gram (``max_ngram`` down to
    ``min_ngram``) of the token history at its most recent earlier
    occurrence and proposes up to ``k`` tokens that followed that
    occurrence.  Returns ``[]`` when no n-gram recurs — the engine then
    runs a verify step that degenerates to a plain decode step."""

    def __init__(self, k: int, max_ngram: int = 3, min_ngram: int = 1):
        assert k > 0 and 1 <= min_ngram <= max_ngram
        self.k = k
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, tokens: Sequence[int]) -> List[int]:
        toks = list(tokens)
        n = len(toks)
        for g in range(min(self.max_ngram, n - 1), self.min_ngram - 1, -1):
            suffix = toks[n - g:]
            # scan right-to-left: the most recent earlier occurrence is the
            # best predictor of the local continuation
            for s in range(n - g - 1, -1, -1):
                if toks[s:s + g] == suffix:
                    # the continuation may run into the suffix itself —
                    # that self-overlap is the periodic-text best case
                    return toks[s + g:s + g + self.k]
        return []


def accept_length(draft: Sequence[int], verified: Sequence[int]) -> int:
    """Greedy acceptance: the longest prefix of ``draft`` that the verify
    argmax ``verified`` reproduces (``verified[j]`` is the model's next
    token *after* draft position j - 1; ``draft[j]`` is accepted iff it
    equals ``verified[j]``).  The engine then emits ``verified[:a + 1]`` —
    the accepted drafts plus the bonus token — exactly the tokens a
    sequence of one-token decode steps would have produced."""
    a = 0
    for d, v in zip(draft, verified):
        if int(v) != int(d):
            break
        a += 1
    return a
