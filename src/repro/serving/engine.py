"""Synchronous continuous-batching inference engine.

``Engine`` exposes the classic three-call serving API:

    eng = Engine(cfg)                      # or Engine(cfg, scfg, params)
    eng.add_request([1, 2, 3], max_new_tokens=16)
    while eng.step():                      # one prefill OR one decode step
        pass
    results = eng.collect()                # finished RequestResults

plus ``run_offline(prompts)``, the batch driver used by ``launch/serve.py``
and the throughput benchmark.  The engine serves *every* registered cache
family (see ``models.cache_spec``): token-addressable KV and MLA latent
pages, sliding-window page rings, SSM/RG-LRU state slots, and the enc-dec
pinned cross cache.  Prefill writes straight into the pools
(``prefill_paged``): each admitted request's pages/slot are bound up front
and the prompt — or, with the radix prefix cache enabled, only its uncached
tail — is computed at a bucketed length; several same-bucket queued requests
are admitted in one batched prefill call.  With
``ServeConfig.prefill_chunk_tokens > 0`` long prompts prefill in
page-aligned *chunks* that interleave with decode steps (see
``scheduler``): a mid-prefill request keeps its pages and an ``n_filled``
cursor, completed pages publish to the radix cache after every chunk, and
the first token comes from the final chunk's logits.  The engine compiles a
bounded program set: one chunk prefill per (length bucket, pow2 admission
batch) — with chunking, shapes are keyed by the chunk budget, never by
individual prompt lengths — one fixed-shape ``[max_slots]`` paged decode
step, and one page-copy (COW fork) kernel — traffic mix never triggers
recompilation, and the jitted steps are cached per (``ArchConfig``,
attention backend) so every Engine instance (and test) reuses them.  The
paged attends route through the backend registry
(``ServeConfig.attn_backend``: ``auto|reference|pallas``, see
``models.attn_backend``), and the engine hands each step flat per-step
metadata (``decode_meta`` / ``prefill_meta``) — page-table rows, positions,
physical write targets — derived once on the host per step instead of per
layer.

Frontend inputs for enc-dec (audio frames) and vlm (image embeddings) archs
are synthesized *per request id* (``fold_in(seed key, rid)``, fixed shapes),
so the same request sees identical inputs no matter how it is batched — this
is what makes ``--verify`` meaningful for those families.  The static
baseline keys the same draw on *request index*, so an engine-vs-static
comparison for those archs assumes a fresh Engine (rids 0..N-1, as every
current caller uses); a reused engine's later runs continue the rid
sequence and draw different frontend inputs.

``generate_static`` is the static-batching baseline kept for comparison and
verification: contiguous per-request KV caches, the whole batch padded
together and decoded until its slowest member finishes.

**Overlapped host/device pipeline.**  Every step is internally split into a
*dispatch* half (scheduler decision, host-side meta build, jitted-call
launch — jax dispatch is asynchronous, so control returns while the device
works) and a *collect* half (block on the device output, token bookkeeping,
retirement).  ``step()`` runs them back-to-back (the synchronous loop every
existing caller sees); ``pump()`` additionally *stages* the host plan for
step N+1 between the two halves — while step N's jitted call runs on
device, the engine pre-builds the next decode step's page tables, positions
and ``decode_meta`` pytree, and validates the staged plan against reality
at the next dispatch (a retirement, EOS, admission, preemption or page-
boundary growth invalidates it; validation is an exact fingerprint match,
so a used staged plan is bit-identical to a replan and tokens stay exact).
``run_offline(..., overlap=True)`` and the async streaming front-end
(``serving.server``) drive ``pump()``; overlap hit rates are counted under
``engine.overlap_*`` and the dispatch/stage/collect phases appear on a
dedicated host-pipeline tracer track, visibly overlapping the step spans in
Perfetto.

Streaming hooks: ``on_token(rid, index, token, t)`` fires as each token is
collected (a preemption replay re-fires earlier indexes; stream consumers
dedup by index — greedy replay regenerates the identical prefix), and
finished requests are popped with ``collect()``.  ``cancel(rid)`` aborts a
queued or live request (client disconnect), releasing its slot and pages.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ServeConfig
from ..models.attn_backend import (
    decode_meta, prefill_meta, resolve_backend, verify_meta)
from ..models.params import init_tree
from ..models.registry import build_model, init_cache, init_params
from ..models.steps import make_serve_step
from .admission import AdmissionController, HealthState
from .faults import FaultInjector, FaultPlan, RequestFault
from .kv_pool import NULL_PAGE, PagedKVPool, StateSlotPool
from .radix_cache import RadixCache
from .scheduler import Admission, Request, Scheduler
from .speculate import NgramProposer, accept_length, speculation_k
from .telemetry import MetricsRegistry, Tracer, shared_metrics


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt: List[int]
    tokens: List[int]                 # generated tokens (greedy), incl. EOS
    latency: float                    # arrival -> finish (s)
    ttft: float                       # arrival -> first token (s).  First
                                      # token *ever* produced: a preemption
                                      # replay does not reset it, so this
                                      # agrees with tracer-sourced ttft_s
                                      # (and is what shared_metrics consumes)
    n_preemptions: int = 0
    cached_tokens: int = 0            # prompt tokens reused from the cache
    # --- per-request timing from the lifecycle tracer ---
    ttft_s: float = 0.0               # == ttft (tracer-sourced spelling)
    finish_s: float = 0.0             # == latency (tracer-sourced spelling)
    tpot_s: float = 0.0               # time per output token after the first
    n_prefill_chunks: int = 0         # prefill calls run (incl. replays)
    preempted: bool = False
    error: str = ""                   # nonempty: rejected/cancelled/shed/
                                      # quarantined; tokens hold whatever the
                                      # request produced before the terminal
    retry_after_s: float = 0.0        # backoff hint for shed requests

    @property
    def failed(self) -> bool:
        return bool(self.error)


@dataclasses.dataclass
class _Pending:
    """One dispatched-but-not-collected engine step: the device is (or may
    be) still computing ``out_dev``; ``finish`` blocks on it and runs the
    host-side bookkeeping."""
    kind: str       # prefill | prefill_chunk | restore | decode | verify
    payload: Any                      # scheduler action payload
    rows: Any                         # prefill row tuples / decode active list
    out_dev: Any                      # device logits / next-token array
    t0: float                         # dispatch start (step span start)
    t_dispatched: float               # host-side dispatch end
    waiting: bool                     # decode-ready slots parked behind this


@dataclasses.dataclass
class _StagedDecode:
    """A pre-built host plan for the *next* decode step, computed while the
    current step runs on device.  ``fp`` is the exact post-step fingerprint
    (slot, rid, pos, owned pages, draft len) the plan assumed; dispatch uses
    the plan only when reality still matches, so a used plan is bit-identical
    to a replan.  Only plain decode steps stage (a verify step's draft is
    unknowable a step ahead), so the staged draft length is always 0 — the
    field keeps the fingerprint honest if that ever changes."""
    active: Tuple[int, ...]
    fp: Tuple[Tuple[int, int, int, int, int], ...]
    meta: Dict[str, Any]              # decode_meta, already device-resident


def _copy_page_fn(kv, src, dst):
    """Fork physical page ``src`` into ``dst`` across every layer (COW)."""
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), kv)


def _zero_pages_fn(kv, pages):
    """Zero physical pages ``pages`` across every layer (quarantine scrub).
    ``pages`` is a fixed-width int32 vector padded with NULL_PAGE — zeroing
    the reserved sink page is harmless, so one compiled shape covers every
    scrub."""
    return jax.tree.map(
        lambda a: a.at[:, pages].set(jnp.zeros((), a.dtype)), kv)


def _poison_pages_fn(kv, pages):
    """NaN-fill the floating leaves of ``pages`` (fault injection only).
    int8 payload leaves can't hold NaN and are left alone — their bf16
    scale leaves carry the poison through dequant instead."""
    def poison(a):
        if not jnp.issubdtype(a.dtype, jnp.floating):
            return a
        return a.at[:, pages].set(jnp.asarray(jnp.nan, a.dtype))
    return jax.tree.map(poison, kv)


@functools.lru_cache(maxsize=None)
def _paged_steps(cfg: ArchConfig, mesh=None, attn_backend: str = "reference"):
    """Jitted (prefill_paged, decode_paged, verify_paged, copy_page,
    zero_pages, poison_pages) steps, cached per (config, attention backend)
    so every Engine instance reuses compilations.  The kv and state pool
    arguments are donated; callers always rebind them.  The verify step is
    built lazily on first use so non-speculative engines never trace it."""
    return (jax.jit(make_serve_step(cfg, mesh, "prefill_paged", attn_backend),
                    donate_argnums=(1, 2)),
            jax.jit(make_serve_step(cfg, mesh, "prefill_paged_cont",
                                    attn_backend), donate_argnums=(1, 2)),
            jax.jit(make_serve_step(cfg, mesh, "decode_paged", attn_backend),
                    donate_argnums=(1, 2)),
            jax.jit(make_serve_step(cfg, mesh, "verify_paged", attn_backend),
                    donate_argnums=(1, 2)),
            jax.jit(_copy_page_fn, donate_argnums=(0,)),
            jax.jit(_zero_pages_fn, donate_argnums=(0,)),
            jax.jit(_poison_pages_fn, donate_argnums=(0,)))


def _synthetic_frontend(cfg: ArchConfig, scfg: ServeConfig, seed: int,
                        rid: int) -> Optional[np.ndarray]:
    """Deterministic per-request frontend input (enc-dec frames / vlm image
    embeddings) — a fixed shape drawn from ``fold_in(PRNGKey(seed), rid)`` so
    every serving path (any batch shape, any engine) sees the same values."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
    if cfg.enc_dec:
        return np.asarray(jax.random.normal(
            key, (scfg.enc_len, cfg.frontend_dim), jnp.bfloat16))
    if cfg.n_image_tokens:
        return np.asarray(jax.random.normal(
            key, (cfg.n_image_tokens, cfg.frontend_dim), jnp.bfloat16))
    return None


def _pow2_pad(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class Engine:
    """Continuous-batching engine over paged + state-slot cache pools."""

    def __init__(self, cfg: ArchConfig, scfg: Optional[ServeConfig] = None,
                 params=None, *, mesh=None, seed: int = 0,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 faults: Optional[FaultPlan] = None):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.model = build_model(cfg)
        self.spec = self.model.cache_spec()
        self.seed = seed
        self.params = init_params(cfg, jax.random.PRNGKey(seed)) \
            if params is None else params
        # telemetry: one registry + one lifecycle tracer shared by every
        # layer (pool, radix cache, scheduler, engine) — all host-side
        # appends, so tracing on changes no math and no emitted token
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        self.pool = PagedKVPool(cfg, self.scfg, metrics=self.metrics)
        self.states = StateSlotPool(cfg, self.scfg, metrics=self.metrics) \
            if self.spec.state_slots else None
        if self.scfg.prefix_cache and not self.spec.prefix_cacheable:
            print(f"[engine] WARNING: prefix cache disabled for {cfg.name}: "
                  f"cache family {self.spec.describe()} is not "
                  f"token-addressable/immutable; serving uncached")
            self.radix = None
        else:
            self.radix = RadixCache(self.pool, self.scfg.page_size,
                                    self.scfg.cache_eviction,
                                    metrics=self.metrics) \
                if self.scfg.prefix_cache else None
        self.sched = Scheduler(self.scfg, self.pool, self.radix, self.states,
                               metrics=self.metrics, tracer=self.tracer)
        self._next_rid = 0
        self.attn_backend = resolve_backend(self.scfg.attn_backend)
        (self._prefill, self._prefill_cont, self._decode, self._verify,
         self._copy, self._zero, self._poison) = _paged_steps(
             cfg, mesh, self.attn_backend)
        # fault tolerance: optional chaos injector, health lifecycle, and
        # deadline-aware admission control (serving/{faults,admission})
        self.injector = FaultInjector(faults, self.metrics) \
            if faults is not None else None
        self.health = HealthState(self.metrics)
        self.admission = AdmissionController(
            self.scfg.max_slots, metrics=self.metrics, seed=seed) \
            if self.scfg.admission_control else None
        # speculative decoding: draft length after the family gate (paged
        # non-enc-dec only) and the weight-free prompt-lookup proposer
        self.spec_k = speculation_k(cfg, self.spec, self.scfg)
        self.proposer = NgramProposer(self.spec_k) if self.spec_k else None
        # engine step counters (previously ad-hoc instance fields)
        self._m_prefill_steps = self.metrics.counter(
            "engine.prefill_steps", "prefill calls (admissions + chunks)")
        self._m_multi_admit = self.metrics.counter(
            "engine.multi_admit_prefills", "prefill calls admitting >1 req")
        self._m_chunk_steps = self.metrics.counter(
            "engine.chunked_prefill_steps", "continuation-chunk calls")
        self._m_restores = self.metrics.counter(
            "engine.state_restores", "checkpoint-restore re-admissions")
        self._m_cow = self.metrics.counter(
            "engine.cow_forks", "copy-on-write page forks run")
        # prefill work accounting: padded counts what the device computed
        # (pow2 rows x bucket), actual counts real prompt tokens — the gap is
        # padding waste, the thing chunking + bucketing are trading against
        self._m_padded = self.metrics.counter(
            "engine.prefill_padded_tokens", "device-computed prefill tokens")
        self._m_actual = self.metrics.counter(
            "engine.prefill_actual_tokens", "real prompt tokens prefilled")
        self._h_decode_step = self.metrics.histogram(
            "engine.decode_step_s", "fixed-shape decode step wall time")
        # speculative-decoding accounting: drafts proposed vs accepted, plus
        # the per-step acceptance-rate distribution (accepted / proposed for
        # each slot-step with a non-empty draft)
        self._m_spec_proposed = self.metrics.counter(
            "engine.spec_proposed", "draft tokens proposed by the n-gram "
            "speculator")
        self._m_spec_accepted = self.metrics.counter(
            "engine.spec_accepted", "draft tokens accepted by the verify "
            "step (emitted without their own decode launch)")
        self._h_accept = self.metrics.histogram(
            "engine.spec_accept_rate", "per slot-step draft acceptance rate "
            "(accepted / proposed, non-empty drafts only)")
        # decode-stall bookkeeping: wall time decode-ready slots spend parked
        # behind non-decode steps (the head-of-line cost chunking bounds)
        self._h_stall = self.metrics.histogram(
            "engine.decode_stall_s", "time decode-ready slots sat parked "
            "behind non-decode steps, per decode step")
        self._stall_accum = 0.0
        # overlapped-pipeline bookkeeping (pump()): staged next-step plans
        self._staged: Optional[_StagedDecode] = None
        self._m_overlap_staged = self.metrics.counter(
            "engine.overlap_staged", "next-step plans staged while the "
            "device ran the current step")
        self._m_overlap_used = self.metrics.counter(
            "engine.overlap_used", "staged plans whose fingerprint still "
            "matched at dispatch (host work hidden behind device time)")
        self._m_overlap_dropped = self.metrics.counter(
            "engine.overlap_dropped", "staged plans invalidated by a "
            "retirement/EOS/admission/preemption before dispatch")
        # request-lifecycle admission guards
        self._inflight: set = set()   # rids queued, live, or awaiting collect
        self._m_reject_budget = self.metrics.counter(
            "sched.rejections", "admission attempts blocked, by reason",
            labels=("reason",)).labels(reason="no_budget")
        # fault-tolerance accounting: quarantines (NaN logits / step errors),
        # client cancels, deadline evictions, and admission sheds
        self._m_quarantined = self.metrics.counter(
            "engine.quarantined", "requests terminal-failed mid-flight by "
            "the per-step fault guard (nan_logits | step_error)")
        self._m_cancelled = self.metrics.counter(
            "engine.cancelled", "requests cancelled by the client "
            "(disconnects), queued or live")
        self._m_deadline_evict = self.metrics.counter(
            "engine.deadline_evictions", "requests expired by the deadline "
            "sweep (queued or mid-flight)")
        self._m_shed = self.metrics.counter(
            "admission.shed", "Requests shed at admission, by reason.",
            labels=("reason",))
        # streaming hook: called as each token is *collected* (host side).
        # A preemption replay re-fires earlier indexes with identical tokens
        # (greedy determinism); stream consumers dedup by index.
        self.on_token: Optional[Callable[[int, int, int, float], None]] = None

    # legacy spelling kept for callers/tests that read the old counter field
    @property
    def _restores(self) -> int:
        return self._m_restores.value

    # ----------------------------------------------------------- public API

    def add_request(self, prompt: Sequence[int], max_new_tokens: int = 16,
                    rid: Optional[int] = None, *,
                    deadline_s: Optional[float] = None,
                    ttft_deadline_s: Optional[float] = None) -> int:
        """Queue a prompt; returns the request id.

        A request with no token budget under ``max_len`` (prompt too long,
        or a non-positive budget after clamping) is rejected *gracefully*:
        it is counted under ``sched.rejections{reason=no_budget}`` and
        surfaces from ``collect()`` as a failed ``RequestResult`` (empty
        tokens, ``error`` set) instead of raising mid-batch and stranding
        already-admitted requests.  The only submission-time exception is a
        ``rid`` collision with an in-flight request — accepting it would
        corrupt tracer and result bookkeeping, so that raises immediately.

        ``deadline_s`` / ``ttft_deadline_s`` are relative QoS budgets
        (seconds from now; ``ServeConfig.default_*`` fill absent ones).
        With ``ServeConfig.admission_control`` on, a request whose deadline
        the calibrated queue model can't meet is *shed* at the door —
        failed result with ``error="shed: overloaded"`` and a jittered
        ``retry_after_s`` backoff hint — and admitted requests that blow
        their deadline mid-flight are evicted by the scheduler sweep.  A
        draining engine sheds every new request with reason ``draining``."""
        if rid is None:
            rid = self._next_rid
        elif rid in self._inflight:
            raise ValueError(f"request id {rid} collides with an in-flight "
                             f"request (queued, live, or awaiting collect)")
        self._next_rid = max(self._next_rid, rid) + 1
        self._inflight.add(rid)
        prompt = [int(t) for t in prompt]
        now = time.perf_counter()
        max_new = min(int(max_new_tokens), self.scfg.max_len - len(prompt))
        if max_new < 1:
            self._m_reject_budget.inc()
            req = Request(rid=rid, prompt=prompt, max_new=0, arrival=now,
                          error=f"no_budget: prompt len {len(prompt)} leaves "
                                f"no token budget under max_len="
                                f"{self.scfg.max_len}")
            req.t_finish = now
            self.sched.finished.append(req)
            self.tracer.on_rejected(rid, now, "no_budget")
            return rid
        if deadline_s is None and self.scfg.default_deadline_s > 0:
            deadline_s = self.scfg.default_deadline_s
        if ttft_deadline_s is None and self.scfg.default_ttft_deadline_s > 0:
            ttft_deadline_s = self.scfg.default_ttft_deadline_s
        if self.health.draining:
            return self._shed(rid, prompt, now, "draining")
        if self.admission is not None:
            reason = self.admission.check(len(self.sched.queue),
                                          deadline_s, ttft_deadline_s)
            if reason is not None:
                return self._shed(rid, prompt, now, reason)
        req = Request(rid=rid, prompt=prompt, max_new=max_new, arrival=now,
                      deadline=now + deadline_s if deadline_s else None,
                      ttft_deadline=(now + ttft_deadline_s
                                     if ttft_deadline_s else None))
        self.sched.add(req)
        return rid

    def _shed(self, rid: int, prompt: List[int], now: float,
              reason: str) -> int:
        """Refuse a request at the door: failed result, backoff hint, and a
        ``rejected`` tracer terminal — the engine never does work for it."""
        retry = (self.admission.retry_after_s(len(self.sched.queue))
                 if self.admission is not None else 1.0)
        self._m_shed.labels(reason=reason).inc()
        req = Request(rid=rid, prompt=prompt, max_new=0, arrival=now,
                      error=f"shed: {reason}", retry_after_s=retry)
        req.t_finish = now
        self.sched.finished.append(req)
        self.tracer.on_rejected(rid, now, reason)
        return rid

    def cancel(self, rid: int) -> bool:
        """Abort a queued or live request (e.g. a disconnected streaming
        client): its slot/pages are released immediately and it surfaces
        from ``collect()`` as a failed result carrying whatever tokens it
        had produced.  Returns False if ``rid`` is not queued or live."""
        now = time.perf_counter()
        for req in list(self.sched.queue):
            if req.rid == rid:
                self.sched.queue.remove(req)
                self.sched._m_queue.set(len(self.sched.queue))
                req.error = "cancelled"
                req.t_finish = now
                self.sched.finished.append(req)
                self._m_cancelled.inc()
                self.tracer.on_rejected(rid, now, "cancelled")
                return True
        for i, slot in enumerate(self.sched.slots):
            if slot is not None and slot.req.rid == rid:
                self._drop_staged()           # slot set is about to change
                slot.req.error = "cancelled"
                slot.req.t_finish = now
                # retire -> _unbind drops *every* page reference the slot
                # holds — including the not-yet-published tail pages of a
                # mid-chunked-prefill slot (n_filled < len(prompt)); the
                # radix cache keeps only the pages it already co-owns
                self.sched.retire(i)
                self._m_cancelled.inc()
                self.tracer.on_finished(rid, now, len(slot.req.generated),
                                        error="cancelled")
                return True
        return False

    def step(self) -> bool:
        """Run one scheduler action (a prefill, a continuation chunk, a
        restore, or a decode) synchronously. False when idle.

        A :class:`RequestFault` raised at the pre-launch seam (injected
        step error) quarantines only the offending request — the donated
        kv/state buffers were not touched yet, so the surviving slots
        simply run on the next step, token streams intact."""
        try:
            pending = self._dispatch_next()
        except RequestFault as e:
            self._quarantine_rid(e.rid, e.kind)
            return True
        if pending is None:
            return False
        self._finish_step(pending)
        return True

    def pump(self) -> bool:
        """One *overlapped* step: dispatch the next action, stage the host
        plan for the step after it while the device computes, then collect.
        Token-for-token identical to ``step()`` (a staged plan is used only
        when it fingerprints equal to a replan); the win is host time hidden
        behind device time.  False when idle."""
        try:
            pending = self._dispatch_next()
        except RequestFault as e:
            self._quarantine_rid(e.rid, e.kind)
            return True
        if pending is None:
            return False
        self.tracer.host_span("dispatch", pending.t0, pending.t_dispatched,
                              kind=pending.kind)
        t_s0 = time.perf_counter()
        if self._stage_next(pending):
            self.tracer.host_span("stage", t_s0, time.perf_counter())
        self._finish_step(pending, overlap=True)
        return True

    def collect(self) -> List[RequestResult]:
        """Pop every finished request as a RequestResult."""
        out = []
        for req in self.sched.finished:
            rec = self.tracer.requests.get(req.rid)
            latency = (req.t_finish - req.arrival
                       if req.t_finish is not None else 0.0)
            res = RequestResult(
                rid=req.rid, prompt=req.prompt, tokens=list(req.generated),
                latency=latency,
                ttft=(req.t_first - req.arrival
                      if req.t_first is not None else 0.0),
                n_preemptions=req.n_preemptions,
                cached_tokens=req.cached_tokens,
                error=req.error, retry_after_s=req.retry_after_s)
            if rec is not None and rec.t_finish is not None:
                # per-request timing from the lifecycle tracer (one source
                # of truth for spans, results, and the trace report)
                t_first = rec.t_first if rec.t_first is not None \
                    else rec.t_finish
                res.ttft_s = t_first - rec.arrival
                res.finish_s = rec.t_finish - rec.arrival
                res.tpot_s = (rec.t_finish - t_first) \
                    / max(len(req.generated) - 1, 1)
                res.n_prefill_chunks = rec.n_chunks
                res.preempted = rec.n_preemptions > 0
            if self.admission is not None and not res.failed:
                # calibrate the queue model on what actually served
                self.admission.observe_result(res.ttft, res.latency)
            self._inflight.discard(req.rid)
            out.append(res)
        self.sched.finished.clear()
        return out

    def run_offline(self, prompts: Sequence[Sequence[int]],
                    max_new_tokens=16, *,
                    overlap: bool = False) -> Tuple[List[RequestResult], Dict]:
        """Admit every prompt, drive the loop dry, return (results, metrics).

        ``max_new_tokens`` is an int or a per-prompt sequence.  With
        ``overlap=True`` the loop runs the pipelined ``pump()`` instead of
        the synchronous ``step()`` (same tokens, host work hidden behind
        device time)."""
        budgets = ([max_new_tokens] * len(prompts)
                   if isinstance(max_new_tokens, int) else list(max_new_tokens))
        # a reused engine must not leak the previous run's trailing stall
        # time (or a stale staged plan) into this run's accounting
        self._stall_accum = 0.0
        self._staged = None
        self.health.mark_healthy()
        t0 = time.perf_counter()
        for p, m in zip(prompts, budgets):
            self.add_request(p, m)
        drive = self.pump if overlap else self.step
        while drive():
            pass
        wall = time.perf_counter() - t0
        results = sorted(self.collect(), key=lambda r: r.rid)
        # latency/TTFT percentiles come from requests that actually served —
        # a rejected request has no first token and would drag p50 to zero
        ok = [r for r in results if not r.failed]
        metrics = shared_metrics(
            len(results), sum(len(r.tokens) for r in results),
            [r.latency for r in ok], wall,
            ttfts=[r.ttft for r in ok],
            prompt_tokens=sum(len(r.prompt) for r in results),
            cached_tokens=sum(r.cached_tokens for r in results),
            prefill_steps=self._m_prefill_steps.value,
            prefill_padded_tokens=self._m_padded.value,
            prefill_actual_tokens=self._m_actual.value,
            decode_step_s=self._h_decode_step.values,
            decode_stall_s=self._h_stall.values)
        metrics["rejected_requests"] = len(results) - len(ok)
        metrics["multi_admit_prefills"] = self._m_multi_admit.value
        metrics["chunked_prefill_steps"] = self._m_chunk_steps.value
        metrics["state_restores"] = self._m_restores.value
        # decode hot-loop visibility: which attention backend served this run
        metrics["attn_backend"] = self.attn_backend
        if self.spec_k:
            metrics["spec_tokens"] = self.spec_k
            metrics["spec_proposed"] = self._m_spec_proposed.value
            metrics["spec_accepted"] = self._m_spec_accepted.value
            metrics["spec_accept_rate"] = (
                self._m_spec_accepted.value
                / max(self._m_spec_proposed.value, 1))
        if self.radix is not None:
            metrics["cache_pages"] = len(self.radix.cached_pages)
            metrics["cache_evictions"] = self.radix.evictions
        return results, metrics

    def metrics_snapshot(self) -> Dict[str, Any]:
        """Full registry snapshot (counters/gauges/histograms of every
        serving layer) — the ``--metrics-json`` payload."""
        return self.metrics.snapshot()

    # --------------------------------------------------- dispatch / collect

    def _drop_staged(self) -> None:
        if self._staged is not None:
            self._m_overlap_dropped.inc()
            self._staged = None

    def _dispatch_next(self) -> Optional[_Pending]:
        """Scheduler decision + host-side meta build + jitted-call launch
        for one step; returns without blocking on the device (jax dispatch
        is asynchronous).  ``None`` on drain — trailing stall time
        accumulated behind non-decode steps is flushed there so it cannot
        leak into a later run on a reused engine."""
        if self.injector is not None:
            self.injector.on_tick(self)
        if self.admission is not None:
            self._evict_deadlines()
        try:
            action = self.sched.next_action()
        except RuntimeError:
            # injected pool pressure can manufacture a scheduler deadlock the
            # real pool would never see; give the hostage pages back and
            # retry once before treating it as genuine exhaustion
            if self.injector is None \
                    or not self.injector.release_pressure(self):
                raise
            action = self.sched.next_action()
        if action is None:
            self._drop_staged()
            if self.injector is not None:
                self.injector.on_drain(self)
            if self._stall_accum:
                self._h_stall.observe(self._stall_accum)
                self._stall_accum = 0.0
            return None
        waiting = bool(self.sched.decode_ready())
        kind, payload = action
        if kind != "decode":
            self._drop_staged()
        t0 = time.perf_counter()
        if kind == "prefill":
            rows, out = self._launch_prefill(payload, t0)
        elif kind == "prefill_chunk":
            rows, out = self._launch_chunks(payload, t0)
        elif kind == "restore":
            self._run_restore(payload, t0)
            rows, out = None, None
        elif self.spec_k:
            # speculation on: every decode-ready step runs as a small-q
            # verify step (with an empty draft it degenerates to decode)
            kind = "verify"
            if self.injector is not None:
                self.injector.before_launch(self, "verify", payload)
            rows, out = payload, self._launch_verify(payload)
        else:
            if self.injector is not None:
                self.injector.before_launch(self, "decode", payload)
            rows, out = payload, self._launch_decode(payload)
        return _Pending(kind=kind, payload=payload, rows=rows, out_dev=out,
                        t0=t0, t_dispatched=time.perf_counter(),
                        waiting=waiting)

    def _finish_step(self, pending: _Pending, overlap: bool = False) -> None:
        """Block on the pending step's device output and run the host-side
        bookkeeping: token appends, retirement, step span, stall account."""
        t_c0 = time.perf_counter()
        if pending.kind == "decode":
            self._collect_decode(pending)
        elif pending.kind == "verify":
            self._collect_verify(pending)
        elif pending.kind in ("prefill", "prefill_chunk"):
            self._collect_prefill(pending)
        t1 = time.perf_counter()
        n_rows = 1 if pending.kind == "restore" else len(pending.payload)
        self.tracer.step_span(pending.kind, pending.t0, t1, rows=n_rows,
                              decode_waiting=pending.waiting)
        if overlap:
            self.tracer.host_span("collect", t_c0, t1, kind=pending.kind)
        if pending.kind in ("decode", "verify"):
            # verify steps *serve* decode-ready slots: both flush the stall
            self._h_stall.observe(self._stall_accum)
            self._stall_accum = 0.0
        elif pending.waiting:
            # decode-ready slots sat out this step: head-of-line stall
            self._stall_accum += t1 - pending.t0

    # ---------------------------------------------- quarantine / deadlines

    def _pad_pages(self, pages: List[int], fill: int) -> jnp.ndarray:
        """Pad a page list to the fixed table width so the jitted zero /
        poison calls compile exactly once per engine config."""
        width = max(self.pool.table_width, 1)
        return jnp.asarray((list(pages) + [fill] * width)[:width], jnp.int32)

    def poison_slot(self, slot_idx: int) -> None:
        """Fault injection: NaN-fill the slot's most recent exclusively-
        owned KV page (or its state-slot row).  At the decode seam the
        newest page always holds positions past every sharer's prompt, so
        only the target row ever reads it — the poison is strictly
        per-request, which is what makes the exact-survivor contract
        testable."""
        slot = self.sched.slots[slot_idx]
        assert slot is not None
        if self.pool.spec.paged and slot.pages:
            page = next((p for p in reversed(slot.pages)
                         if self.pool.ref(p) == 1), None)
            assert page is not None, \
                f"slot {slot_idx} owns no exclusive page to poison"
            self.pool.kv = self._poison(self.pool.kv,
                                        self._pad_pages([page], fill=page))
        elif self.states is not None:
            self.states.poison(slot_idx)

    def _scrub_slot(self, slot_idx: int) -> None:
        """Zero a quarantined slot's exclusively-owned pages (and state row)
        before they return to the free list.  Mandatory, not cosmetic:
        masked attention is a zero-*weight* multiply, so a NaN in a recycled
        page would poison every future request whose table points at it
        even at softmax weight zero.  Shared (radix) pages are finite by
        construction — prompts are poisoned only past the shared region —
        and co-owned, so they are left alone."""
        slot = self.sched.slots[slot_idx]
        assert slot is not None
        if self.pool.spec.paged and slot.pages:
            excl = [p for p in slot.pages if self.pool.ref(p) == 1]
            if excl:
                self.pool.kv = self._zero(self.pool.kv,
                                          self._pad_pages(excl, NULL_PAGE))
                self.pool.note_scrubbed(len(excl))
        if self.states is not None:
            self.states.scrub(slot_idx)

    def _quarantine_slot(self, slot_idx: int, reason: str,
                         now: float) -> None:
        """Terminal-fail one live request without touching its batchmates:
        drop any staged plan (the slot set changes), scrub the pages it
        exclusively owns, release everything through the normal retire
        path, and emit the failure terminal.  Survivors replay nothing —
        their tokens were never wrong — so their streams stay byte-exact."""
        slot = self.sched.slots[slot_idx]
        assert slot is not None
        req = slot.req
        self._drop_staged()
        self._scrub_slot(slot_idx)
        req.error = reason
        req.t_finish = now
        self.sched.retire(slot_idx)
        self._m_quarantined.inc()
        self.tracer.on_finished(req.rid, now, len(req.generated),
                                error=reason)

    def _quarantine_rid(self, rid: int, reason: str) -> None:
        """Quarantine by request id (the step-error path: the fault names a
        rid, not a slot).  No-op if the rid is no longer live."""
        now = time.perf_counter()
        for i, slot in enumerate(self.sched.slots):
            if slot is not None and slot.req.rid == rid:
                self._quarantine_slot(i, reason, now)
                return

    def _evict_deadlines(self) -> None:
        """Expire queued and mid-flight requests whose deadline passed.
        Mid-flight eviction frees the slot immediately — finishing a request
        its client already gave up on is negative goodput."""
        now = time.perf_counter()
        expired_q, expired_live = self.sched.sweep_deadlines(now)
        for req in expired_q:
            req.error = "deadline_exceeded"
            req.t_finish = now
            self.sched.finished.append(req)
            self._m_deadline_evict.inc()
            self.tracer.on_rejected(req.rid, now, "deadline_exceeded")
        for i in expired_live:
            self._drop_staged()
            slot = self.sched.slots[i]
            req = slot.req
            req.error = "deadline_exceeded"
            req.t_finish = now
            self.sched.retire(i)
            self._m_deadline_evict.inc()
            self.tracer.on_finished(req.rid, now, len(req.generated),
                                    error="deadline_exceeded")

    def _stage_next(self, pending: _Pending) -> bool:
        """While the dispatched step runs on device, pre-build the host plan
        for the *next* decode step.  Staged only when the next action is
        deterministically the same decode batch one position further: the
        pending step is a decode, nothing is queued, no slot is mid-prefill,
        no slot retires on budget at this step's collect (an EOS retirement
        is caught by the dispatch fingerprint instead), and no slot crosses
        a page boundary at its next position.  True when a plan was staged."""
        if pending.kind != "decode" or self.sched.queue \
                or self.sched.prefilling_slots():
            return False
        active = list(pending.rows)
        ps = self.scfg.page_size
        cap = self.pool.table_width
        for i in active:
            slot = self.sched.slots[i]
            if len(slot.req.generated) + 1 >= slot.req.max_new:
                return False          # retires when this step collects
            p1 = slot.pos + 1
            if self.pool.spec.paged and len(slot.pages) < cap \
                    and p1 % ps == 0 and p1 // ps >= len(slot.pages):
                return False          # next decode needs page growth
        self._staged = _StagedDecode(
            active=tuple(active),
            fp=tuple((i, self.sched.slots[i].req.rid,
                      self.sched.slots[i].pos + 1,
                      len(self.sched.slots[i].pages), 0) for i in active),
            meta=self._decode_plan(active, pos_offset=1))
        self._m_overlap_staged.inc()
        return True

    # -------------------------------------------------------------- prefill

    def _extras(self, rids: List[int], B: int) -> Dict[str, Any]:
        """Frontend inputs for a padded prefill batch ({} for text-only)."""
        cfg = self.cfg
        if not (cfg.enc_dec or cfg.n_image_tokens):
            return {}
        rows = [_synthetic_frontend(cfg, self.scfg, self.seed, r)
                for r in rids]
        n = (self.scfg.enc_len if cfg.enc_dec else cfg.n_image_tokens)
        out = np.zeros((B, n, cfg.frontend_dim), rows[0].dtype)
        for i, r in enumerate(rows):
            out[i] = r
        key = "frames" if cfg.enc_dec else "image_embeds"
        return {key: jnp.asarray(out)}

    def _prefill_launch(self, rows: List[Tuple[int, Any, int, int]],
                        continuation: bool = False):
        """Launch one batched chunk-prefill call.  ``rows`` holds
        (slot_idx, req, n_done, n_chunk): each row prefills prompt tokens
        [n_done, n_done + n_chunk) into its bound pages / state slot.  The
        batch is padded to a pow2 row count and the tokens to a bucket so
        the program set stays bounded (keyed by the chunk budget, not by
        prompt lengths).  ``continuation`` marks a batch of chunks after
        the first: no frontend inputs (vlm never chunks, enc-dec reads its
        pinned cross cache instead of re-encoding).  Returns the per-row
        last-real-token logits *still on device* — the collect half blocks
        on them with ``np.asarray``."""
        bucket = self.scfg.bucket_of(max(c for _, _, _, c in rows))
        B = _pow2_pad(len(rows), self.scfg.max_slots)
        toks = np.zeros((B, bucket), np.int32)
        start = np.zeros((B,), np.int32)
        n_tail = np.zeros((B,), np.int32)
        tables = np.full((B, max(self.pool.table_width, 1)), NULL_PAGE,
                         np.int32)
        slots = np.full((B,), self.scfg.max_slots, np.int32)  # pad rows: drop
        for i, (slot_idx, req, n_done, n_chunk) in enumerate(rows):
            toks[i, :n_chunk] = req.prompt[n_done:n_done + n_chunk]
            start[i] = n_done
            n_tail[i] = n_chunk
            tables[i] = self.sched.slots[slot_idx].table
            slots[i] = slot_idx
        # token-addressable families attend only pages the batch actually
        # reaches: truncate the table view to a pow2 page count (bounded
        # program set) instead of always paying a max_len-wide gather — an
        # early chunk of a long prompt, or a short prompt under a large
        # max_len, attends O(its own length), not O(max_len)
        ps = self.scfg.page_size
        width = tables.shape[1]
        if not self.cfg.sliding_window:        # ring tables are minimal already
            need = -(-(int((start + n_tail).max())
                       + self.pool.spec.prefix_tokens) // ps)
            W = 1
            while W < need:
                W *= 2
            width = max(min(W, tables.shape[1]), 1)
        meta = {k: jnp.asarray(v) for k, v in prefill_meta(
            self.cfg, ps, tables[:, :width], slots, start, n_tail,
            bucket).items()}
        state = self.states.state if self.states is not None else {}
        extras = {} if continuation \
            else self._extras([req.rid for _, req, _, _ in rows], B)
        step = self._prefill_cont if continuation and self.cfg.enc_dec \
            else self._prefill
        with self.tracer.annotate("prefill_step"):
            logits, self.pool.kv, state = step(
                self.params, self.pool.kv, state, meta, jnp.asarray(toks),
                extras)
        if self.states is not None:
            self.states.state = state
        self._m_padded.inc(B * bucket)
        self._m_actual.inc(sum(c for _, _, _, c in rows))
        return logits

    def _after_chunk(self, slot_idx: int, req, n_done: int, n_chunk: int,
                     logits_row: Optional[np.ndarray], now: float,
                     pages: List[int]) -> None:
        """Advance a slot's prefill cursor past one chunk: publish the newly
        completed full prompt pages (immutable from here on — later chunks
        and decode write strictly past them, so a same-prefix request queued
        behind a long prompt starts hitting the cache mid-prefill), and on
        the final chunk take the first token from this call's logits."""
        slot = self.sched.slots[slot_idx]
        slot.n_filled = n_done + n_chunk
        if self.radix is not None:
            ps = self.scfg.page_size
            full = min(slot.n_filled, len(req.prompt)) // ps
            if full:
                self.radix.insert(req.prompt[:full * ps], pages[:full])
        if slot.n_filled >= len(req.prompt):
            if req.t_first is None:       # replay keeps the original TTFT
                req.t_first = now
            self.tracer.on_first_token(req.rid, now)
            tok = int(logits_row.argmax())
            req.generated.append(tok)
            self._emit_token(req.rid, len(req.generated) - 1, tok, now)
            self._maybe_retire(slot_idx, now)

    def _launch_prefill(self, adms: List[Admission], t0: float):
        """Launch a batch of already-accounted admissions: fork COW pages if
        a cache match ended mid-page, then prefill each request's *first
        chunk* — the whole uncached tail unless chunking caps it — straight
        into the bound pages / state slots in one call."""
        for adm in adms:
            self.tracer.on_admitted(adm.req.rid, t0,
                                    cached_tokens=adm.n_matched)
            if adm.cow_dst is not None:
                self.pool.kv = self._copy(self.pool.kv,
                                          jnp.asarray(adm.cow_src, jnp.int32),
                                          jnp.asarray(adm.cow_dst, jnp.int32))
                self._m_cow.inc()
        rows = [(adm.slot_idx, adm.req, adm.n_matched, adm.n_chunk)
                for adm in adms]
        out = self._prefill_launch(rows)
        self._m_prefill_steps.inc()
        if len(adms) > 1:
            self._m_multi_admit.inc()
        return rows, out

    def _launch_chunks(self, slot_idxs: List[int], t0: float):
        """Launch a batch of continuation chunks for mid-prefill slots."""
        rows = []
        for i in slot_idxs:
            slot = self.sched.slots[i]
            n_done = slot.n_filled
            n_chunk = self.sched._chunk_len(n_done, len(slot.req.prompt))
            rows.append((i, slot.req, n_done, n_chunk))
        out = self._prefill_launch(rows, continuation=True)
        self._m_prefill_steps.inc()
        self._m_chunk_steps.inc()
        return rows, out

    def _collect_prefill(self, pending: _Pending) -> None:
        """Collect half of a prefill/chunk step: block on the device logits,
        then advance every row's cursor (first tokens, cache publishes,
        retirement)."""
        logits = np.asarray(pending.out_dev)     # blocks: device step done
        now = time.perf_counter()
        for r, (slot_idx, req, n_done, n_chunk) in enumerate(pending.rows):
            slot = self.sched.slots[slot_idx]
            if slot is None or slot.req is not req:
                continue              # cancelled/quarantined under our feet
            self.tracer.on_chunk(req.rid, pending.t0, now,
                                 n_done=n_done, n_chunk=n_chunk)
            if not np.isfinite(logits[r]).all():
                # checked *before* _after_chunk so a poisoned prompt never
                # publishes its pages to the radix cache
                self._quarantine_slot(slot_idx, "nan_logits", now)
                continue
            pages = (pending.payload[r].pages if pending.kind == "prefill"
                     else slot.pages)
            self._after_chunk(slot_idx, req, n_done, n_chunk, logits[r],
                              now, pages)

    def _run_restore(self, adm: Admission, t0: float) -> None:
        """Re-admit a checkpointed (preempted) request: write its state
        snapshot back into the claimed slot and resume decoding where it
        left off — no prompt replay (the scheduler already bound the slot at
        the checkpointed position)."""
        self.tracer.on_admitted(adm.req.rid, t0, kind="restore")
        _, saved = adm.restore
        self.states.restore(adm.slot_idx, saved)
        self._m_restores.inc()
        self.tracer.on_restored(adm.req.rid, time.perf_counter())

    # --------------------------------------------------------------- decode

    def _decode_plan(self, active: List[int],
                     pos_offset: int = 0) -> Dict[str, Any]:
        """Flat per-step decode metadata, derived once on the host (numpy)
        instead of re-derived by every layer's block inside the scanned
        decode step.  ``pos_offset=1`` builds the *next* step's plan while
        this step's collect hasn't advanced the cursors yet (staging)."""
        B = self.scfg.max_slots
        maxp = max(self.pool.table_width, 1)
        pos = np.zeros((B,), np.int32)
        tables = np.full((B, maxp), NULL_PAGE, np.int32)
        for i in active:
            slot = self.sched.slots[i]
            pos[i] = slot.pos + pos_offset
            tables[i] = slot.table
        return {k: jnp.asarray(v) for k, v in decode_meta(
            self.cfg, self.scfg.page_size, tables, pos).items()}

    def _launch_decode(self, active: List[int]):
        """Launch one fixed-shape decode step, reusing a staged plan when
        its fingerprint still matches reality (a used plan is bit-identical
        to a replan — same positions, tables, pages — so tokens are exact).
        Returns (device next-token array, launch time) without blocking."""
        B = self.scfg.max_slots
        tokens = np.zeros((B,), np.int32)
        for i in active:
            tokens[i] = self.sched.slots[i].req.generated[-1]
        meta = None
        if self._staged is not None:
            st, self._staged = self._staged, None
            fp = tuple(
                (i, self.sched.slots[i].req.rid, self.sched.slots[i].pos,
                 len(self.sched.slots[i].pages), 0) for i in active)
            if tuple(active) == st.active and fp == st.fp:
                meta = st.meta
                self._m_overlap_used.inc()
            else:
                self._m_overlap_dropped.inc()
        if meta is None:
            meta = self._decode_plan(active)
        state = self.states.state if self.states is not None else {}
        t_launch = time.perf_counter()
        with self.tracer.annotate("decode_step"):
            nxt, ok, self.pool.kv, state = self._decode(
                self.params, self.pool.kv, state, meta, jnp.asarray(tokens))
        if self.states is not None:
            self.states.state = state
        return nxt, ok, t_launch

    def _collect_decode(self, pending: _Pending) -> None:
        """Collect half of a decode step: block on the device tokens, then
        advance cursors, fire streaming hooks, retire finished slots.  A row
        whose finite flag came back False is quarantined instead of emitting
        its garbage argmax — its survivors' rows are untouched."""
        nxt_dev, ok_dev, t_launch = pending.out_dev
        nxt = np.asarray(nxt_dev)                # blocks: device step done
        ok = np.asarray(ok_dev)
        now = time.perf_counter()
        self._h_decode_step.observe(now - t_launch)
        if self.admission is not None:
            self.admission.observe_step(now - t_launch)
        for i in pending.rows:
            slot = self.sched.slots[i]
            if slot is None:
                continue              # quarantined earlier in this collect
            if not ok[i]:
                self._quarantine_slot(i, "nan_logits", now)
                continue
            slot.pos += 1
            tok = int(nxt[i])
            slot.req.generated.append(tok)
            self._emit_token(slot.req.rid, len(slot.req.generated) - 1,
                             tok, now)
            self._maybe_retire(i, now)

    # ------------------------------------------------------------- speculate

    def _verify_plan(self, active: List[int],
                     drafts: Dict[int, List[int]]) -> Dict[str, Any]:
        """Fixed-shape verify-step metadata: like ``_decode_plan`` but with
        per-row live query counts (1 + draft length) and per-query write
        targets for all Q = spec_k + 1 positions.  Idle rows keep pos=0,
        n_q=1 and a NULL_PAGE table, so their single query writes to the
        reserved sink page exactly as an idle decode row does."""
        B = self.scfg.max_slots
        Q = self.spec_k + 1
        maxp = max(self.pool.table_width, 1)
        pos = np.zeros((B,), np.int32)
        n_q = np.ones((B,), np.int32)
        tables = np.full((B, maxp), NULL_PAGE, np.int32)
        for i in active:
            slot = self.sched.slots[i]
            pos[i] = slot.pos
            n_q[i] = 1 + len(drafts[i])
            tables[i] = slot.table
        return {k: jnp.asarray(v) for k, v in verify_meta(
            self.cfg, self.scfg.page_size, tables, pos, n_q, Q).items()}

    def _launch_verify(self, active: List[int]):
        """Launch one fixed-shape speculative verify step: draft up to
        ``spec_k`` tokens per row from the request's own history (prompt +
        generation), then run draft + carried token through the small-q
        verify step in one device call.  Rows whose proposer finds nothing
        run with an empty draft — the step degenerates to a decode step for
        them.  Drafts are clamped so the furthest K/V write (pos + draft
        len) stays inside both the token budget and the page horizon.
        Returns (device [B, Q] next-token array, launch time, drafts)."""
        B = self.scfg.max_slots
        Q = self.spec_k + 1
        tokens = np.zeros((B, Q), np.int32)
        drafts: Dict[int, List[int]] = {}
        prefix = self.pool.spec.prefix_tokens
        for i in active:
            req = self.sched.slots[i].req
            # a draft token beyond the remaining budget could never be
            # emitted (the bonus token fills the last budget slot), and its
            # K/V write must stay under the max_len page horizon
            kmax = min(self.spec_k,
                       req.max_new - len(req.generated) - 1,
                       prefix + self.scfg.max_len - 1
                       - self.sched.slots[i].pos)
            draft = self.proposer.propose(
                req.prompt + req.generated)[:max(kmax, 0)]
            drafts[i] = draft
            tokens[i, 0] = req.generated[-1]
            tokens[i, 1:1 + len(draft)] = draft
            if draft:
                self._m_spec_proposed.inc(len(draft))
        meta = self._verify_plan(active, drafts)
        state = self.states.state if self.states is not None else {}
        t_launch = time.perf_counter()
        with self.tracer.annotate("verify_step"):
            nxt, ok, self.pool.kv, state = self._verify(
                self.params, self.pool.kv, state, meta, jnp.asarray(tokens))
        if self.states is not None:
            self.states.state = state
        return nxt, ok, t_launch, drafts

    def _collect_verify(self, pending: _Pending) -> None:
        """Collect half of a verify step: block on the [B, Q] greedy tokens,
        accept each row's longest draft prefix the argmax reproduced, and
        emit accepted + bonus tokens — the identical stream a sequence of
        one-token decode steps would have produced.  EOS or budget reached
        mid-emit stops the emission there (trailing accepted tokens are
        discarded exactly as decode would never have produced them)."""
        nxt_dev, ok_dev, t_launch, drafts = pending.out_dev
        nxt = np.asarray(nxt_dev)                # blocks: device step done
        ok = np.asarray(ok_dev)
        now = time.perf_counter()
        self._h_decode_step.observe(now - t_launch)
        if self.admission is not None:
            self.admission.observe_step(now - t_launch)
        for i in pending.rows:
            slot = self.sched.slots[i]
            if slot is None:
                continue              # quarantined earlier in this collect
            if not ok[i]:
                self._quarantine_slot(i, "nan_logits", now)
                continue
            req = slot.req
            draft = drafts[i]
            a = accept_length(draft, nxt[i, :len(draft)]) if draft else 0
            if draft:
                self._m_spec_accepted.inc(a)
                self._h_accept.observe(a / len(draft))
            for j in range(a + 1):
                tok = int(nxt[i, j])
                slot.pos += 1
                req.generated.append(tok)
                self._emit_token(req.rid, len(req.generated) - 1, tok, now)
                done = len(req.generated) >= req.max_new
                if self.scfg.eos_id >= 0 and tok == self.scfg.eos_id:
                    done = True
                if done:
                    req.t_finish = now
                    self.sched.retire(i)
                    self.tracer.on_finished(req.rid, now, len(req.generated))
                    break

    def _emit_token(self, rid: int, index: int, tok: int, now: float) -> None:
        """Fire the streaming hook and the injector's token seam (the
        client-disconnect fault watches the stream, not the scheduler)."""
        if self.on_token is not None:
            self.on_token(rid, index, tok, now)
        if self.injector is not None:
            self.injector.on_token(rid, index)

    def _maybe_retire(self, slot_idx: int, now: float) -> None:
        req = self.sched.slots[slot_idx].req
        done = len(req.generated) >= req.max_new
        if self.scfg.eos_id >= 0 and req.generated[-1] == self.scfg.eos_id:
            done = True
        if done:
            req.t_finish = now
            self.sched.retire(slot_idx)
            self.tracer.on_finished(req.rid, now, len(req.generated))


# ---------------------------------------------------------- static baseline

@functools.lru_cache(maxsize=None)
def _static_steps(cfg: ArchConfig, mesh=None):
    """Jitted (prefill_at, decode) steps, cached per config so repeated
    generate_static calls (verify replays, benchmarks) reuse compilations.
    The decode step donates its cache argument; callers never reuse it."""
    return (jax.jit(make_serve_step(cfg, mesh, "prefill_at")),
            jax.jit(make_serve_step(cfg, mesh, "decode"), donate_argnums=(1,)))


def generate_static(cfg: ArchConfig, params, prompts: Sequence[Sequence[int]],
                    max_new_tokens=16, scfg: Optional[ServeConfig] = None,
                    *, batch_size: int = 1, mesh=None,
                    eos_id: Optional[int] = None,
                    seed: int = 0) -> Tuple[List[List[int]], Dict]:
    """Static-batching reference: contiguous KV caches, arrival-order batches
    padded to a shared bucket, each batch decoded until its slowest request
    is done.  ``batch_size=1`` is the exact single-request greedy baseline
    the engine's output is verified against.  ``eos_id`` defaults to
    ``scfg.eos_id`` so the stop rule matches the Engine's.

    Right-padding is causally invisible to attention families (masked), but
    recurrent state (ssm/hybrid) absorbs pad tokens: those families are only
    exact when every prompt in a batch has the same length, so they skip
    bucketing and pad to the batch max instead.  Enc-dec (audio) and vlm
    archs get synthetic frontend inputs drawn per *request index*
    (``fold_in(seed, i)``, fixed shapes) — the same inputs the continuous
    engine synthesizes per rid, so the two paths are comparable."""
    scfg = scfg or ServeConfig()
    eos = scfg.eos_id if eos_id is None else eos_id
    budgets = ([max_new_tokens] * len(prompts)
               if isinstance(max_new_tokens, int) else list(max_new_tokens))
    prefill, decode = _static_steps(cfg, mesh)
    model = build_model(cfg)
    n_img = cfg.n_image_tokens

    all_tokens: List[Optional[List[int]]] = [None] * len(prompts)
    latencies: List[float] = [0.0] * len(prompts)
    ttfts: List[float] = [0.0] * len(prompts)
    decode_step_s: List[float] = []
    prefill_padded = prefill_actual = 0
    t0 = time.perf_counter()
    for lo in range(0, len(prompts), batch_size):
        idxs = list(range(lo, min(lo + batch_size, len(prompts))))
        B = len(idxs)
        lens = [len(prompts[i]) for i in idxs]
        budget = [min(budgets[i], scfg.max_len - len(prompts[i])) for i in idxs]
        # recurrent state absorbs pad tokens and the sliding-window ring is
        # filled from the final prompt positions: both need the prompt end to
        # be the sequence end, so those families pad to the batch max instead
        # of a bucket (exact at batch_size=1 / equal lengths)
        bucket = (max(lens)
                  if cfg.family in ("ssm", "hybrid") or cfg.sliding_window
                  else scfg.bucket_of(max(lens)))
        toks = np.zeros((B, bucket), np.int32)
        for r, i in enumerate(idxs):
            toks[r, :lens[r]] = prompts[i]
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.enc_dec or n_img:
            rows = [_synthetic_frontend(cfg, scfg, seed, i) for i in idxs]
            key = "frames" if cfg.enc_dec else "image_embeds"
            batch[key] = jnp.asarray(np.stack(rows))
        # vlm hidden sequence = image tokens ++ text tokens: offset positions
        last_idx = jnp.asarray([n_img + l - 1 for l in lens], jnp.int32)
        logits, cache = prefill(params, batch, last_idx)
        # grow the contiguous cache to max_len (the pre-paging zero-pad copy)
        if cfg.enc_dec:
            fresh = init_tree(
                model.cache_defs(B, scfg.max_len, enc_len=scfg.enc_len),
                jax.random.PRNGKey(0))
        else:
            fresh = init_cache(cfg, B, n_img + scfg.max_len)
        cache = jax.tree.map(
            lambda f, c: c if f.shape == c.shape else jnp.pad(
                c, [(0, fs - cs) for fs, cs in zip(f.shape, c.shape)]),
            fresh, cache)
        # per-row positions: decode writes resume at each prompt's true length
        cache["pos"] = jnp.asarray([n_img + l for l in lens], jnp.int32)
        cur = jnp.asarray(np.asarray(logits).argmax(-1), jnp.int32)
        t_first = time.perf_counter() - t0       # batch's first tokens exist
        prefill_padded += B * bucket
        prefill_actual += sum(lens)
        gen = [np.asarray(cur).copy()]
        # the whole batch decodes until its slowest member is done
        for _ in range(max(budget) - 1):
            t_step = time.perf_counter()
            cur, cache = decode(params, cache, cur)
            gen.append(np.asarray(cur).copy())   # np.asarray blocks: the
            decode_step_s.append(time.perf_counter() - t_step)  # step is done
        jax.block_until_ready(cur)
        t_batch = time.perf_counter() - t0
        stacked = np.stack(gen, axis=1)               # [B, max(budget)]
        for r, i in enumerate(idxs):
            row = stacked[r, :budget[r]].tolist()
            if eos >= 0 and eos in row:
                row = row[:row.index(eos) + 1]
            all_tokens[i] = row
            latencies[i] = t_batch
            ttfts[i] = t_first
    wall = time.perf_counter() - t0
    # the shared schema (same keys as the engine path, column-for-column);
    # stall is honestly zero — the static path has no interleaving to stall
    return all_tokens, shared_metrics(
        len(prompts), sum(len(t) for t in all_tokens), latencies, wall,
        ttfts=ttfts, prompt_tokens=sum(len(p) for p in prompts),
        prefill_steps=-(-len(prompts) // batch_size),
        prefill_padded_tokens=prefill_padded,
        prefill_actual_tokens=prefill_actual,
        decode_step_s=decode_step_s)
