"""Synchronous continuous-batching inference engine.

``Engine`` exposes the classic three-call serving API:

    eng = Engine(cfg)                      # or Engine(cfg, scfg, params)
    eng.add_request([1, 2, 3], max_new_tokens=16)
    while eng.step():                      # one prefill OR one decode step
        pass
    results = eng.collect()                # finished RequestResults

plus ``run_offline(prompts)``, the batch driver used by ``launch/serve.py``
and the throughput benchmark.  Prefill writes straight into the paged pool
(``prefill_paged``): the request's pages are bound up front and the prompt —
or, with the radix prefix cache enabled, only its uncached tail — is computed
at a bucketed length and scattered token-granularly through the page table.
The engine compiles exactly ``len(buckets) + 2`` programs: one tail prefill
per length bucket, one fixed-shape ``[max_slots]`` paged decode step, and one
page-copy (COW fork) kernel — traffic mix never triggers recompilation, and
the jitted steps are cached per ``ArchConfig`` so every Engine instance (and
test) reuses them.

``generate_static`` is the static-batching baseline kept for comparison and
verification: contiguous per-request KV caches, the whole batch padded
together and decoded until its slowest member finishes.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ServeConfig
from ..models.registry import build_model, init_cache, init_params
from ..models.steps import make_serve_step
from .kv_pool import NULL_PAGE, PagedKVPool
from .radix_cache import RadixCache
from .scheduler import Admission, Request, Scheduler


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt: List[int]
    tokens: List[int]                 # generated tokens (greedy), incl. EOS
    latency: float                    # arrival -> finish (s)
    ttft: float                       # arrival -> first token (s)
    n_preemptions: int = 0
    cached_tokens: int = 0            # prompt tokens reused from the cache


def _percentile(xs: Sequence[float], q: float) -> float:
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs), q))


def _metrics(n_requests: int, n_tokens: int, latencies: Sequence[float],
             wall: float) -> Dict[str, float]:
    """The one metrics schema both engines report (keep them comparable)."""
    return {
        "n_requests": n_requests,
        "new_tokens": n_tokens,
        "wall_s": wall,
        "tokens_per_s": n_tokens / max(wall, 1e-9),
        "requests_per_s": n_requests / max(wall, 1e-9),
        "latency_p50_s": _percentile(latencies, 50),
        "latency_p95_s": _percentile(latencies, 95),
    }


def _aggregate(results: List[RequestResult], wall: float) -> Dict[str, float]:
    m = _metrics(len(results), sum(len(r.tokens) for r in results),
                 [r.latency for r in results], wall)
    # engine-only extras: prefill accounting + TTFT (generate_static has
    # neither a prefix cache nor per-request first-token times)
    prompt_tokens = sum(len(r.prompt) for r in results)
    cached = sum(r.cached_tokens for r in results)
    m.update({
        "ttft_p50_s": _percentile([r.ttft for r in results], 50),
        "ttft_p95_s": _percentile([r.ttft for r in results], 95),
        "prompt_tokens": prompt_tokens,
        "cached_tokens": cached,
        "prefill_tokens": prompt_tokens - cached,
        "cache_hit_rate": cached / max(prompt_tokens, 1),
    })
    return m


def _copy_page_fn(kv, src, dst):
    """Fork physical page ``src`` into ``dst`` across every layer (COW)."""
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), kv)


@functools.lru_cache(maxsize=None)
def _paged_steps(cfg: ArchConfig, mesh=None):
    """Jitted (prefill_paged, decode_paged, copy_page) steps, cached per
    config so every Engine instance reuses compilations.  The pool argument
    is donated in all three; callers always rebind ``pool.kv``."""
    return (jax.jit(make_serve_step(cfg, mesh, "prefill_paged"),
                    donate_argnums=(1,)),
            jax.jit(make_serve_step(cfg, mesh, "decode_paged"),
                    donate_argnums=(1,)),
            jax.jit(_copy_page_fn, donate_argnums=(0,)))


class Engine:
    """Continuous-batching engine over a paged KV pool (attention families)."""

    def __init__(self, cfg: ArchConfig, scfg: Optional[ServeConfig] = None,
                 params=None, *, mesh=None, seed: int = 0):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.model = build_model(cfg)
        ok, why = self.model.supports_paged_decode()
        if not ok:
            raise NotImplementedError(f"Engine({cfg.name}): {why}")
        if cfg.n_image_tokens:
            raise NotImplementedError(
                f"Engine({cfg.name}): image-conditioned prefill not wired up")
        self.params = init_params(cfg, jax.random.PRNGKey(seed)) \
            if params is None else params
        self.pool = PagedKVPool(cfg, self.scfg)
        self.radix = RadixCache(self.pool, self.scfg.page_size,
                                self.scfg.cache_eviction) \
            if self.scfg.prefix_cache else None
        self.sched = Scheduler(self.scfg, self.pool, self.radix)
        self._next_rid = 0
        self._prefill, self._decode, self._copy = _paged_steps(cfg, mesh)

    # ----------------------------------------------------------- public API

    def add_request(self, prompt: Sequence[int], max_new_tokens: int = 16,
                    rid: Optional[int] = None) -> int:
        """Queue a prompt; returns the request id."""
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        prompt = [int(t) for t in prompt]
        max_new = min(int(max_new_tokens), self.scfg.max_len - len(prompt))
        if max_new < 1:
            raise ValueError(f"request {rid}: no token budget under "
                             f"max_len={self.scfg.max_len}")
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      arrival=time.perf_counter())
        self.sched.add(req)
        return rid

    def step(self) -> bool:
        """Run one scheduler action (a prefill or a decode). False when idle."""
        action = self.sched.next_action()
        if action is None:
            return False
        if action[0] == "prefill":
            self._run_prefill(action[1])
        else:
            self._run_decode(action[1])
        return True

    def collect(self) -> List[RequestResult]:
        """Pop every finished request as a RequestResult."""
        out = []
        for req in self.sched.finished:
            out.append(RequestResult(
                rid=req.rid, prompt=req.prompt, tokens=list(req.generated),
                latency=req.t_finish - req.arrival,
                ttft=req.t_first - req.arrival,
                n_preemptions=req.n_preemptions,
                cached_tokens=req.cached_tokens))
        self.sched.finished.clear()
        return out

    def run_offline(self, prompts: Sequence[Sequence[int]],
                    max_new_tokens=16) -> Tuple[List[RequestResult], Dict]:
        """Admit every prompt, drive the loop dry, return (results, metrics).

        ``max_new_tokens`` is an int or a per-prompt sequence."""
        budgets = ([max_new_tokens] * len(prompts)
                   if isinstance(max_new_tokens, int) else list(max_new_tokens))
        t0 = time.perf_counter()
        for p, m in zip(prompts, budgets):
            self.add_request(p, m)
        while self.step():
            pass
        wall = time.perf_counter() - t0
        results = sorted(self.collect(), key=lambda r: r.rid)
        metrics = _aggregate(results, wall)
        if self.radix is not None:
            metrics["cache_pages"] = len(self.radix.cached_pages)
            metrics["cache_evictions"] = self.radix.evictions
        return results, metrics

    # -------------------------------------------------------------- prefill

    def _bucket(self, n: int) -> int:
        for b in self.scfg.buckets:
            if b >= n:
                return b
        raise ValueError(f"prompt len {n} exceeds largest bucket "
                         f"{self.scfg.buckets[-1]}")

    def _run_prefill(self, adm: Admission) -> None:
        """Execute an already-accounted admission: fork the COW page if the
        cache match ended mid-page, then prefill the uncached tail straight
        into the slot's pages."""
        req = adm.req
        if adm.cow_dst is not None:
            self.pool.kv = self._copy(self.pool.kv,
                                      jnp.asarray(adm.cow_src, jnp.int32),
                                      jnp.asarray(adm.cow_dst, jnp.int32))
        tail = req.prompt[adm.n_matched:]
        bucket = self._bucket(len(tail))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(tail)] = tail
        logits, self.pool.kv = self._prefill(
            self.params, self.pool.kv, jnp.asarray(adm.table[None]),
            jnp.asarray([adm.n_matched], jnp.int32),
            jnp.asarray([len(tail)], jnp.int32), jnp.asarray(toks))
        first = int(np.asarray(logits)[0].argmax())
        now = time.perf_counter()
        req.t_first = now
        req.generated.append(first)
        if self.radix is not None:
            # publish the full prompt pages for reuse (they are immutable for
            # the slot's lifetime: decode writes land strictly past them)
            full = len(req.prompt) // self.scfg.page_size
            if full:
                self.radix.insert(req.prompt[:full * self.scfg.page_size],
                                  adm.pages[:full])
        self._maybe_retire(adm.slot_idx, now)

    # --------------------------------------------------------------- decode

    def _run_decode(self, active: List[int]) -> None:
        B, maxp = self.scfg.max_slots, self.scfg.pages_per_request
        tokens = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        tables = np.full((B, maxp), NULL_PAGE, np.int32)
        for i in active:
            slot = self.sched.slots[i]
            tokens[i] = slot.req.generated[-1]
            pos[i] = slot.pos
            tables[i] = slot.table
        nxt, self.pool.kv = self._decode(
            self.params, self.pool.kv, jnp.asarray(tables), jnp.asarray(pos),
            jnp.asarray(tokens))
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        for i in active:
            slot = self.sched.slots[i]
            slot.pos += 1
            slot.req.generated.append(int(nxt[i]))
            self._maybe_retire(i, now)

    def _maybe_retire(self, slot_idx: int, now: float) -> None:
        req = self.sched.slots[slot_idx].req
        done = len(req.generated) >= req.max_new
        if self.scfg.eos_id >= 0 and req.generated[-1] == self.scfg.eos_id:
            done = True
        if done:
            req.t_finish = now
            self.sched.retire(slot_idx)


# ---------------------------------------------------------- static baseline

@functools.lru_cache(maxsize=None)
def _static_steps(cfg: ArchConfig, mesh=None):
    """Jitted (prefill_at, decode) steps, cached per config so repeated
    generate_static calls (verify replays, benchmarks) reuse compilations.
    The decode step donates its cache argument; callers never reuse it."""
    return (jax.jit(make_serve_step(cfg, mesh, "prefill_at")),
            jax.jit(make_serve_step(cfg, mesh, "decode"), donate_argnums=(1,)))


def generate_static(cfg: ArchConfig, params, prompts: Sequence[Sequence[int]],
                    max_new_tokens=16, scfg: Optional[ServeConfig] = None,
                    *, batch_size: int = 1, mesh=None,
                    eos_id: Optional[int] = None,
                    seed: int = 0) -> Tuple[List[List[int]], Dict]:
    """Static-batching reference: contiguous KV caches, arrival-order batches
    padded to a shared bucket, each batch decoded until its slowest request
    is done.  ``batch_size=1`` is the exact single-request greedy baseline
    the engine's output is verified against.  ``eos_id`` defaults to
    ``scfg.eos_id`` so the stop rule matches the Engine's.

    Right-padding is causally invisible to attention families (masked), but
    recurrent state (ssm/hybrid) absorbs pad tokens: those families are only
    exact when every prompt in a batch has the same length, so they skip
    bucketing and pad to the batch max instead.  Enc-dec (audio) and vlm
    archs get synthetic frontend inputs (random frames / image embeddings
    derived from ``seed``), matching the pre-paging serve driver."""
    scfg = scfg or ServeConfig()
    eos = scfg.eos_id if eos_id is None else eos_id
    budgets = ([max_new_tokens] * len(prompts)
               if isinstance(max_new_tokens, int) else list(max_new_tokens))
    prefill, decode = _static_steps(cfg, mesh)
    key = jax.random.PRNGKey(seed)
    n_img = cfg.n_image_tokens

    def bucket_of(n: int) -> int:
        for b in scfg.buckets:
            if b >= n:
                return b
        raise ValueError(f"prompt len {n} exceeds largest bucket")

    all_tokens: List[Optional[List[int]]] = [None] * len(prompts)
    latencies: List[float] = [0.0] * len(prompts)
    t0 = time.perf_counter()
    for lo in range(0, len(prompts), batch_size):
        idxs = list(range(lo, min(lo + batch_size, len(prompts))))
        B = len(idxs)
        lens = [len(prompts[i]) for i in idxs]
        budget = [min(budgets[i], scfg.max_len - len(prompts[i])) for i in idxs]
        bucket = (max(lens) if cfg.family in ("ssm", "hybrid")
                  else bucket_of(max(lens)))
        toks = np.zeros((B, bucket), np.int32)
        for r, i in enumerate(idxs):
            toks[r, :lens[r]] = prompts[i]
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.enc_dec:
            batch["frames"] = jax.random.normal(
                key, (B, bucket, cfg.frontend_dim), jnp.bfloat16)
        elif n_img:
            batch["image_embeds"] = jax.random.normal(
                key, (B, n_img, cfg.frontend_dim), jnp.bfloat16)
        # vlm hidden sequence = image tokens ++ text tokens: offset positions
        last_idx = jnp.asarray([n_img + l - 1 for l in lens], jnp.int32)
        logits, cache = prefill(params, batch, last_idx)
        # grow the contiguous cache to max_len (the pre-paging zero-pad copy)
        fresh = init_cache(cfg, B, n_img + scfg.max_len)
        cache = jax.tree.map(
            lambda f, c: c if f.shape == c.shape else jnp.pad(
                c, [(0, fs - cs) for fs, cs in zip(f.shape, c.shape)]),
            fresh, cache)
        # per-row positions: decode writes resume at each prompt's true length
        cache["pos"] = jnp.asarray([n_img + l for l in lens], jnp.int32)
        cur = jnp.asarray(np.asarray(logits).argmax(-1), jnp.int32)
        gen = [np.asarray(cur).copy()]
        # the whole batch decodes until its slowest member is done
        for _ in range(max(budget) - 1):
            cur, cache = decode(params, cache, cur)
            gen.append(np.asarray(cur).copy())
        jax.block_until_ready(cur)
        t_batch = time.perf_counter() - t0
        stacked = np.stack(gen, axis=1)               # [B, max(budget)]
        for r, i in enumerate(idxs):
            row = stacked[r, :budget[r]].tolist()
            if eos >= 0 and eos in row:
                row = row[:row.index(eos) + 1]
            all_tokens[i] = row
            latencies[i] = t_batch
    wall = time.perf_counter() - t0
    return all_tokens, _metrics(len(prompts), sum(len(t) for t in all_tokens),
                                latencies, wall)
