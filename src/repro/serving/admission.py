"""Deadline-aware admission control and the serving health state machine.

Admission control answers one question at the front door: *given what the
engine has recently measured about itself, can this request plausibly meet
its deadline?*  If not, shedding it immediately (HTTP 503 + Retry-After)
is strictly better than letting it queue, time out mid-flight, and waste
the prefill work — the goodput-under-overload benchmark in
``benchmarks/serve_throughput.py`` quantifies exactly that trade.

The estimate is deliberately simple and self-calibrating: EWMAs of observed
per-step latency, TTFT, and total service time, combined as

    est_wait  = ceil(queue_depth / max_slots) * service_ewma
    est_ttft  = est_wait + ttft_ewma
    est_total = est_wait + service_ewma

A request is shed with reason ``"overloaded"`` when either estimate exceeds
the corresponding deadline.  Requests without deadlines are never shed by
the estimator (only by ``draining``).

:class:`HealthState` is the engine-owned lifecycle machine reported by
``GET /health``::

    starting ── healthy ── draining ── drained
        └──────┬───┘
            degraded ─────┘

Transitions outside the arrows are ignored (returns False), which makes the
mark_* helpers idempotent and safe to call from both the engine thread and
the event loop.
"""

from __future__ import annotations

import math
import random
from typing import Dict, List, Optional, Tuple


class HealthState:
    """Serving lifecycle: starting → healthy → degraded → draining → drained."""

    STATES = ("starting", "healthy", "degraded", "draining", "drained")
    _ALLOWED = {
        "starting": {"healthy", "degraded", "draining"},
        "healthy": {"degraded", "draining"},
        "degraded": {"draining"},
        "draining": {"drained"},
        "drained": set(),
    }

    def __init__(self, metrics=None):
        self.state = "starting"
        self.reason = ""
        self.history: List[str] = ["starting"]
        self._gauge = None
        if metrics is not None:
            self._gauge = metrics.gauge(
                "server.health_state",
                "Health state index (0=starting 1=healthy 2=degraded 3=draining 4=drained).",
            )
            self._gauge.set(0)

    def _to(self, new: str, reason: str = "") -> bool:
        if new == self.state:
            return False
        if new not in self._ALLOWED[self.state]:
            return False
        self.state = new
        self.reason = reason
        self.history.append(new)
        if self._gauge is not None:
            self._gauge.set(self.STATES.index(new))
        return True

    def mark_healthy(self) -> bool:
        return self._to("healthy")

    def mark_degraded(self, reason: str) -> bool:
        return self._to("degraded", reason)

    def begin_drain(self) -> bool:
        return self._to("draining", "drain requested")

    def mark_drained(self) -> bool:
        return self._to("drained")

    @property
    def draining(self) -> bool:
        return self.state in ("draining", "drained")

    @property
    def accepting(self) -> bool:
        return self.state in ("starting", "healthy", "degraded")

    def to_dict(self) -> Dict[str, object]:
        return {
            "state": self.state,
            "ok": self.state in ("starting", "healthy"),
            "reason": self.reason,
            "history": list(self.history),
        }


class AdmissionController:
    """Sheds requests whose deadlines the calibrated queue model can't meet."""

    def __init__(
        self,
        max_slots: int,
        metrics=None,
        seed: int = 0,
        step_s_prior: float = 0.05,
        ewma: float = 0.3,
    ):
        assert max_slots >= 1
        self.max_slots = max_slots
        self._ewma = ewma
        self._step_s = step_s_prior   # per-engine-step latency (always available)
        self._ttft_s: Optional[float] = None     # observed once results flow
        self._service_s: Optional[float] = None  # arrival → finish per request
        self._rng = random.Random(seed)
        self._m_shed = None
        if metrics is not None:
            self._m_shed = metrics.counter(
                "admission.shed",
                "Requests shed at admission, by reason.",
                labels=("reason",),
            )

    def _blend(self, old: Optional[float], new: float) -> float:
        return new if old is None else (1 - self._ewma) * old + self._ewma * new

    def observe_step(self, dt_s: float) -> None:
        self._step_s = self._blend(self._step_s, dt_s)

    def observe_result(self, ttft_s: Optional[float], service_s: Optional[float]) -> None:
        if ttft_s is not None and ttft_s > 0:
            self._ttft_s = self._blend(self._ttft_s, ttft_s)
        if service_s is not None and service_s > 0:
            self._service_s = self._blend(self._service_s, service_s)

    def estimate_queue_wait(self, queue_depth: int) -> float:
        """queue depth × calibrated service time, in admission waves."""
        if queue_depth <= 0:
            return 0.0
        if self._service_s is not None:
            waves = math.ceil(queue_depth / self.max_slots)
            return waves * self._service_s
        return queue_depth * self._step_s

    def check(
        self,
        queue_depth: int,
        deadline_s: Optional[float] = None,
        ttft_deadline_s: Optional[float] = None,
    ) -> Optional[str]:
        """Return a shed reason, or None to admit."""
        if deadline_s is None and ttft_deadline_s is None:
            return None
        wait = self.estimate_queue_wait(queue_depth)
        if ttft_deadline_s is not None:
            est_ttft = wait + (self._ttft_s if self._ttft_s is not None else self._step_s)
            if est_ttft > ttft_deadline_s:
                return "overloaded"
        if deadline_s is not None:
            est_total = wait + (self._service_s if self._service_s is not None else self._step_s)
            if est_total > deadline_s:
                return "overloaded"
        return None

    def note_shed(self, reason: str) -> None:
        if self._m_shed is not None:
            self._m_shed.labels(reason=reason).inc()

    def retry_after_s(self, queue_depth: int) -> float:
        """Backoff hint: estimated drain time with deterministic seeded jitter."""
        base = min(max(self.estimate_queue_wait(max(queue_depth, 1)), 0.05), 30.0)
        return base * (0.5 + self._rng.random())
