"""Radix-tree prefix cache over the paged KV pool (SGLang-style).

The paper's core complaint is that "redundant data aggravates the system
workload"; in serving, that redundancy is identical prompt prefixes being
prefilled from scratch for every request.  This module shares the KV pages of
common prefixes instead: a token-keyed radix tree whose nodes map prompt
prefix spans to physical pages of the ``PagedKVPool``.

Page-quantized edges
    Sharing granularity is a KV *page*, so every tree node covers exactly one
    full page (``page_size`` tokens) and is keyed by that page's token tuple.
    A prompt's cacheable prefix is its full prompt pages —
    ``len(prompt) // page_size`` of them; the partially-filled last page is
    never shared (decode keeps writing into it).  This quantization removes
    the edge-splitting bookkeeping of a classic radix tree: a "match" is a
    walk of exact page-key lookups, and sub-page divergence simply duplicates
    at most one page of KV per branch.

Matching and copy-on-write
    ``match`` walks full-page hits, then scans the children of the last
    matched node for the longest *partial* page match.  A partial match can
    never be shared — the new request must write its own tokens into the rest
    of that page — so the scheduler forks it: a fresh exclusively-owned page
    is allocated and the matched slots are device-copied into it (COW),
    after which the tail prefill fills the remainder.

Ownership
    The tree holds one pool reference per cached page (taken at ``insert``,
    dropped at eviction/``reset``); every matched request additionally
    ``share``s the pages it reuses, so eviction can never free a page a live
    slot still reads — the pool only frees at refcount zero.  Node ``lock``
    counts pin the matched path while its requests are live, keeping the LRU
    evictor away from pages it would immediately be asked for again.

Eviction
    When the free list runs dry the scheduler calls ``evict(n)``: leaf nodes
    with ``lock == 0`` are detached in least-recently-used order and the
    tree's page references dropped, until ``n`` tree references have been
    released or nothing evictable remains.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from .kv_pool import PagedKVPool
from .telemetry import MetricsRegistry


class RadixNode:
    """One full KV page of a cached prompt prefix."""
    __slots__ = ("key", "page", "parent", "children", "lock", "last_access")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["RadixNode"]):
        self.key = key                     # this page's page_size tokens
        self.page = page                   # physical page in the pool
        self.parent = parent
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.lock = 0                      # live requests pinned to this node
        self.last_access = 0


@dataclasses.dataclass
class MatchResult:
    """Outcome of matching a prompt against the tree (no state mutated).

    ``pages`` are the full-page hits, shareable as-is.  ``cow_len > 0`` means
    the first ``cow_len`` token slots of page ``cow_src`` extend the match but
    live in a partially-matched page: fork (copy) before use, never share.
    ``nodes`` is the matched path incl. the COW source — lock it while the
    admitted request is alive.  ``n_matched`` counts reused prompt tokens:
    ``len(pages) * page_size + cow_len``."""
    nodes: List[RadixNode]
    pages: List[int]
    cow_src: Optional[int]
    cow_len: int
    n_matched: int


class RadixCache:
    def __init__(self, pool: PagedKVPool, page_size: int,
                 eviction: str = "lru",
                 metrics: Optional[MetricsRegistry] = None):
        assert eviction in ("lru", "none"), eviction
        self.pool = pool
        self.ps = page_size
        self.eviction = eviction
        self.root = RadixNode((), -1, None)
        self._clock = itertools.count(1)
        self.evictions = 0      # lifetime count, surfaced as cache_evictions
        # telemetry: token-level hit accounting upholds the invariant
        # hit_tokens + miss_tokens == lookup_tokens for every match() call
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_lookups = self.metrics.counter(
            "radix.lookups", "match() calls (one per admission attempt)")
        self._m_lookup_tok = self.metrics.counter(
            "radix.lookup_tokens", "matchable prompt tokens offered")
        self._m_hit_tok = self.metrics.counter(
            "radix.hit_tokens", "prompt tokens served from the tree "
            "(full pages + COW partial)")
        self._m_partial_tok = self.metrics.counter(
            "radix.partial_hit_tokens", "hit tokens needing a COW fork")
        self._m_miss_tok = self.metrics.counter(
            "radix.miss_tokens", "prompt tokens the tree could not serve")
        self._m_inserted = self.metrics.counter(
            "radix.inserted_pages", "pages newly published to the tree")
        self._m_evictions = self.metrics.counter(
            "radix.evictions", "tree references dropped under pressure")
        self._m_nodes = self.metrics.gauge(
            "radix.cached_pages", "pages currently cached (tree nodes)")
        self._m_locked = self.metrics.gauge(
            "radix.locked_nodes", "nodes pinned by live requests")
        self._n_nodes = 0
        self._n_locked = 0

    # -------------------------------------------------------------- querying

    def match(self, tokens: Sequence[int], max_match: int) -> MatchResult:
        """Longest cached prefix of ``tokens``, capped at ``max_match`` tokens
        (callers pass ``len(prompt) - 1`` so at least one tail token is left
        to prefill for first-token logits).  Touches LRU clocks only."""
        ps = self.ps
        tokens = list(tokens)
        node, n, nodes, pages = self.root, 0, [], []
        tick = next(self._clock)
        while n + ps <= max_match:
            child = node.children.get(tuple(tokens[n:n + ps]))
            if child is None:
                break
            child.last_access = tick
            nodes.append(child)
            pages.append(child.page)
            node, n = child, n + ps
        # partial page: best common prefix among this node's children
        cow_src, cow_len = None, 0
        rest = tokens[n:max_match]
        for key, child in node.children.items():
            c = 0
            for a, b in zip(key, rest):
                if a != b:
                    break
                c += 1
            if c > cow_len:
                cow_src, cow_len = child.page, c
                best = child
        if cow_len:
            best.last_access = tick
            nodes.append(best)
        n_matched = n + cow_len
        matchable = min(len(tokens), max_match)
        self._m_lookups.inc()
        self._m_lookup_tok.inc(matchable)
        self._m_hit_tok.inc(n_matched)
        self._m_partial_tok.inc(cow_len)
        self._m_miss_tok.inc(matchable - n_matched)
        return MatchResult(nodes=nodes, pages=pages, cow_src=cow_src,
                           cow_len=cow_len, n_matched=n_matched)

    # -------------------------------------------------------------- mutation

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Publish a prompt's full prompt pages for reuse.

        ``tokens`` must cover ``pages`` exactly (``len == len(pages) * ps``)
        and the pages must stay immutable while cached (full prompt pages
        are: decode writes land strictly past them).  Walks existing nodes
        without touching them — a double insert of an identical prompt adds
        no nodes and takes no extra references; only genuinely new pages are
        attached, with one pool reference each (the tree's).  Returns the
        number of pages newly cached."""
        ps = self.ps
        tokens = list(tokens)
        assert len(tokens) == len(pages) * ps, (len(tokens), len(pages), ps)
        node, new = self.root, 0
        tick = next(self._clock)
        for i, page in enumerate(pages):
            key = tuple(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = RadixNode(key, page, node)
                node.children[key] = child
                self.pool.share([page])
                new += 1
            child.last_access = tick
            node = child
        self._m_inserted.inc(new)
        self._n_nodes += new
        self._m_nodes.set(self._n_nodes)
        return new

    def lock(self, nodes: Sequence[RadixNode]) -> None:
        for nd in nodes:
            if nd.lock == 0:
                self._n_locked += 1
            nd.lock += 1
        self._m_locked.set(self._n_locked)

    def unlock(self, nodes: Sequence[RadixNode]) -> None:
        for nd in nodes:
            assert nd.lock > 0, "unlock of an unlocked radix node"
            nd.lock -= 1
            if nd.lock == 0:
                self._n_locked -= 1
        self._m_locked.set(self._n_locked)

    def evict(self, n_pages: int) -> int:
        """Detach up to ``n_pages`` LRU unlocked leaves, dropping the tree's
        page references.  Returns the number of references released (the pool
        frees each page only once every other owner has released it too)."""
        if self.eviction == "none":
            return 0
        freed = 0
        # one tree walk per call; evicting a leaf may expose its parent
        leaves = [nd for nd in self._walk()
                  if not nd.children and nd.lock == 0]
        while freed < n_pages and leaves:
            # prefer leaves whose page the tree solely owns — evicting those
            # actually frees pages; co-owned leaves (a live slot shares the
            # page) are burned only when needed to expose freeable ancestors
            freeing = [nd for nd in leaves if self.pool.ref(nd.page) == 1]
            victim = min(freeing or leaves, key=lambda nd: nd.last_access)
            leaves.remove(victim)
            parent = victim.parent
            del parent.children[victim.key]
            self.pool.release([victim.page])
            self.evictions += 1
            self._m_evictions.inc()
            self._n_nodes -= 1
            self._m_nodes.set(self._n_nodes)
            freed += 1
            if parent is not self.root and not parent.children \
                    and parent.lock == 0:
                leaves.append(parent)
        return freed

    def make_room(self, n_free: int) -> bool:
        """Evict (LRU) until the pool has ``n_free`` free pages, but only if
        that target is actually reachable — a hopeless request (the freeable
        mass is too small because live slots co-own most cached pages) evicts
        nothing, so a failed admission can't wipe the cache for no gain."""
        if self.pool.num_free >= n_free:
            return True
        if self.eviction == "none":
            return False
        if self.pool.num_free + self._freeable() < n_free:
            return False
        while self.pool.num_free < n_free:
            # batch: a single call may release co-owned refs without freeing
            if not self.evict(n_free - self.pool.num_free):
                return False            # unreachable unless _freeable lied
        return True

    def _freeable(self) -> int:
        """Upper bound on pages eviction could return to the free list: nodes
        whose page the tree solely owns, within fully-unlocked subtrees (a
        locked descendant pins every ancestor — leaves evict first)."""
        count = 0

        def visit(nd: RadixNode) -> bool:
            """Returns whether nd's whole subtree is unlocked."""
            nonlocal count
            open_ = all([visit(c) for c in nd.children.values()]) \
                and nd.lock == 0
            if open_ and nd is not self.root and self.pool.ref(nd.page) == 1:
                count += 1
            return open_

        visit(self.root)
        return count

    def reset(self) -> None:
        """Drop every cached page (the tree's references only: pages shared
        with live slots stay allocated until those slots release them)."""
        for nd in list(self._walk()):
            self.pool.release([nd.page])
        self.root.children.clear()
        self._n_nodes = 0
        self._m_nodes.set(0)

    # ------------------------------------------------------------ inspection

    def _walk(self) -> Iterator[RadixNode]:
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            yield nd

    @property
    def num_nodes(self) -> int:
        return sum(1 for _ in self._walk())

    @property
    def cached_pages(self) -> List[int]:
        return [nd.page for nd in self._walk()]
