"""Continuous-batching request scheduler.

Policy (SGLang/Orca-style, simplified to a synchronous loop):

* **Admission**: whenever a decode slot is free and the page pool can cover
  the prompt, the oldest queued request is admitted via a single-request
  bucketed tail prefill.  Prefill has priority over decode — keeping slots
  full is what buys continuous batching its throughput.  With the radix
  prefix cache enabled, admission first matches the prompt against the tree:
  matched full pages are shared (refcount +1), a partially-matched page is
  forked copy-on-write, and only the uncached tail is prefilled.  Admission
  is **all-or-nothing**: every accounting step (dequeue, share, alloc, lock,
  bind) happens only after capacity is proven, so a failed attempt mutates
  nothing.
* **Decode**: otherwise every live slot advances one token in a single
  fixed-shape jitted step; idle slots ride along masked (their page-table
  rows point at the null page).
* **Growth / eviction / preemption**: a slot crossing a page boundary gets a
  fresh page from the free list; if the pool is exhausted, unlocked radix
  nodes are LRU-evicted first, then the youngest slot is preempted — its
  page references are released (shared pages survive via the tree) and the
  request is requeued from scratch (greedy decode is deterministic, so the
  replay reproduces its prefix — usually straight from the cache).
* **Retirement**: EOS or max-tokens retires the slot, releases its page
  references and radix locks immediately, making room for the next admission.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

from ..configs.base import ServeConfig
from .kv_pool import PagedKVPool
from .radix_cache import RadixCache, RadixNode


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    # --- filled in by the engine ---
    arrival: float = 0.0
    t_first: Optional[float] = None      # first-token (prefill done) time
    t_finish: Optional[float] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    n_preemptions: int = 0
    cached_tokens: int = 0               # prompt tokens served from the cache

    @property
    def finished(self) -> bool:
        return self.t_finish is not None


@dataclasses.dataclass
class Slot:
    """A live request bound to a decode-batch row."""
    req: Request
    pos: int                              # next write position (= tokens cached)
    table: np.ndarray                     # [pages_per_request] int32
    pages: List[int]                      # referenced physical pages, in order
    admit_seq: int                        # admission order (preemption victim key)
    nodes: List[RadixNode] = dataclasses.field(default_factory=list)
    n_shared: int = 0                     # leading pages shared via the cache


@dataclasses.dataclass
class Admission:
    """An admission the scheduler has fully accounted; the engine only has to
    run the device work (COW copy + tail prefill)."""
    slot_idx: int
    req: Request
    n_matched: int                        # cached prompt tokens (incl. COW)
    cow_src: Optional[int]                # page to fork, or None
    cow_dst: Optional[int]                # exclusively-owned fork target
    table: np.ndarray                     # the bound slot's page table
    pages: List[int]                      # shared + exclusive pages, in order


class Scheduler:
    def __init__(self, scfg: ServeConfig, pool: PagedKVPool,
                 radix: Optional[RadixCache] = None):
        self.scfg = scfg
        self.pool = pool
        self.radix = radix
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Slot]] = [None] * scfg.max_slots
        self.finished: List[Request] = []
        self._admit_seq = 0

    # ------------------------------------------------------------- inventory

    def add(self, req: Request) -> None:
        if len(req.prompt) >= self.scfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt len {len(req.prompt)} >= "
                f"max_len {self.scfg.max_len}")
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    # ------------------------------------------------------------ scheduling

    def next_action(self) -> Optional[Tuple]:
        """('prefill', Admission) | ('decode', [slot_idx, ...]) | None."""
        if self.queue:
            adm = self.try_admit()
            if adm is not None:
                return ("prefill", adm)
        active = self.active_slots()
        if active:
            self._grow_pages()
            active = self.active_slots()          # growth may have preempted
            if active:
                return ("decode", active)
        if self.queue:
            # no slot/page capacity and nothing running to free any.  If the
            # prefix cache is what holds the pool (cache_eviction="none", or
            # only co-owned leaves blocked make_room), serving beats caching:
            # flush the tree's references and retry once before giving up.
            if self.radix is not None and self.radix.num_nodes:
                self.radix.reset()
                adm = self.try_admit()
                if adm is not None:
                    return ("prefill", adm)
            raise RuntimeError(
                f"scheduler deadlock: request {self.queue[0].rid} needs "
                f"{self.pool.pages_needed(len(self.queue[0].prompt))} pages, "
                f"pool has {self.pool.num_free} free and no live slots")
        return None

    def try_admit(self) -> Optional[Admission]:
        """Admit the oldest queued request if (and only if) every resource it
        needs is available; on failure nothing — queue, pool, tree — changes.
        """
        idx = self.free_slot()
        if idx is None or not self.queue:
            return None
        req = self.queue[0]
        n = len(req.prompt)
        nodes: List[RadixNode] = []
        shared: List[int] = []
        cow_src, cow_len, n_matched = None, 0, 0
        if self.radix is not None:
            m = self.radix.match(req.prompt, n - 1)
            nodes, shared = m.nodes, m.pages
            cow_src, cow_len, n_matched = m.cow_src, m.cow_len, m.n_matched
        # the last prompt token is always computed, so at least one page is
        # never shared: need >= 1
        need = self.pool.pages_needed(n) - len(shared)
        if self.pool.num_free < need:
            if self.radix is not None:
                # pin the matched path so making room can't evict it; a
                # hopeless attempt evicts nothing (all-or-nothing extends to
                # the cache contents)
                self.radix.lock(nodes)
                self.radix.make_room(need)
                self.radix.unlock(nodes)
            if self.pool.num_free < need:
                return None
        # ---- commit point: capacity proven, take everything atomically ----
        self.queue.popleft()
        self.pool.share(shared)
        fresh = self.pool.alloc(need)
        assert fresh is not None
        if self.radix is not None:
            self.radix.lock(nodes)
        pages = shared + fresh
        slot = self.bind(idx, req, pages, pos=n, nodes=nodes,
                         n_shared=len(shared))
        req.cached_tokens = n_matched
        return Admission(slot_idx=idx, req=req, n_matched=n_matched,
                         cow_src=cow_src,
                         cow_dst=fresh[0] if cow_len else None,
                         table=slot.table, pages=pages)

    # ----------------------------------------------------- slot transitions

    def bind(self, slot_idx: int, req: Request, pages: List[int], pos: int,
             nodes: Optional[List[RadixNode]] = None,
             n_shared: int = 0) -> Slot:
        table = self.pool.new_table()
        table[:len(pages)] = pages
        slot = Slot(req=req, pos=pos, table=table, pages=pages,
                    admit_seq=self._admit_seq, nodes=list(nodes or []),
                    n_shared=n_shared)
        self._admit_seq += 1
        self.slots[slot_idx] = slot
        return slot

    def _unbind(self, slot_idx: int) -> Slot:
        """Release a slot's page references and radix locks (shared pages are
        freed only when their last owner — usually the tree — lets go)."""
        slot = self.slots[slot_idx]
        assert slot is not None
        self.pool.release(slot.pages)
        if self.radix is not None and slot.nodes:
            self.radix.unlock(slot.nodes)
        self.slots[slot_idx] = None
        return slot

    def retire(self, slot_idx: int) -> Request:
        """EOS / max-len eviction: drop every page reference the slot holds."""
        slot = self._unbind(slot_idx)
        self.finished.append(slot.req)
        return slot.req

    def preempt(self, slot_idx: int) -> Request:
        """Release the slot's references and requeue its request for a clean
        replay.  Only exclusively-owned pages actually return to the free
        list; pages published to the radix cache stay resident, so the replay
        typically re-admits as a cache hit."""
        slot = self._unbind(slot_idx)
        slot.req.generated.clear()
        slot.req.t_first = None
        slot.req.cached_tokens = 0
        slot.req.n_preemptions += 1
        self.queue.appendleft(slot.req)
        return slot.req

    def _grow_pages(self) -> None:
        """Before a decode step, every live slot must own the page its next
        write lands in.  When the pool runs dry, LRU-evict unlocked cache
        nodes first, then preempt youngest-first."""
        ps = self.scfg.page_size
        for i in sorted(self.active_slots(),
                        key=lambda i: self.slots[i].admit_seq):
            slot = self.slots[i]
            if slot is None:
                continue
            if slot.pos % ps != 0 or slot.pos // ps < len(slot.pages):
                continue                       # current page still has room
            while True:
                pages = self.pool.alloc(1)
                if pages is not None:
                    slot.table[len(slot.pages)] = pages[0]
                    slot.pages.extend(pages)
                    break
                if self.radix is not None and self.radix.make_room(1):
                    continue                   # eviction freed a page
                victims = [j for j in self.active_slots() if j != i]
                if not victims:
                    # last resort before giving up: the cache may hold pages
                    # this slot doesn't use (cache_eviction="none" keeps
                    # make_room from touching them) — flush and retry
                    if self.radix is not None and self.radix.num_nodes:
                        self.radix.reset()
                        continue
                    raise RuntimeError(
                        "page pool exhausted with a single live slot; "
                        "increase ServeConfig.num_pages")
                victim = max(victims, key=lambda j: self.slots[j].admit_seq)
                self.preempt(victim)
