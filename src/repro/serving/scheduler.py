"""Continuous-batching request scheduler.

Policy (SGLang/Orca-style, simplified to a synchronous loop):

* **Admission**: whenever a decode slot is free and the page pool can cover
  the prompt, the oldest queued request is admitted via a single-request
  bucketed prefill.  Prefill has priority over decode — keeping slots full
  is what buys continuous batching its throughput.
* **Decode**: otherwise every live slot advances one token in a single
  fixed-shape jitted step; idle slots ride along masked (their page-table
  rows point at the null page).
* **Growth / preemption**: a slot crossing a page boundary gets a fresh page
  from the free list; if the pool is exhausted, the youngest slot is
  preempted — its pages are freed and the request is requeued from scratch
  (greedy decode is deterministic, so the replay reproduces its prefix).
* **Eviction**: EOS or max-tokens retires the slot and frees its pages
  immediately, making room for the next admission.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

from ..configs.base import ServeConfig
from .kv_pool import PagedKVPool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    # --- filled in by the engine ---
    arrival: float = 0.0
    t_first: Optional[float] = None      # first-token (prefill done) time
    t_finish: Optional[float] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    n_preemptions: int = 0

    @property
    def finished(self) -> bool:
        return self.t_finish is not None


@dataclasses.dataclass
class Slot:
    """A live request bound to a decode-batch row."""
    req: Request
    pos: int                              # next write position (= tokens cached)
    table: np.ndarray                     # [pages_per_request] int32
    pages: List[int]                      # allocated physical pages, in order
    admit_seq: int                        # admission order (preemption victim key)


class Scheduler:
    def __init__(self, scfg: ServeConfig, pool: PagedKVPool):
        self.scfg = scfg
        self.pool = pool
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Slot]] = [None] * scfg.max_slots
        self.finished: List[Request] = []
        self._admit_seq = 0

    # ------------------------------------------------------------- inventory

    def add(self, req: Request) -> None:
        if len(req.prompt) >= self.scfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt len {len(req.prompt)} >= "
                f"max_len {self.scfg.max_len}")
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    # ------------------------------------------------------------ scheduling

    def next_action(self) -> Optional[Tuple]:
        """('prefill', slot_idx, request) | ('decode', [slot_idx, ...]) | None."""
        if self.queue:
            idx = self.free_slot()
            need = self.pool.pages_needed(len(self.queue[0].prompt))
            if idx is not None and self.pool.num_free >= need:
                return ("prefill", idx, self.queue.popleft())
        active = self.active_slots()
        if active:
            self._grow_pages()
            active = self.active_slots()          # growth may have preempted
            if active:
                return ("decode", active)
        if self.queue:
            # no slot/page capacity and nothing running to free any: stuck
            raise RuntimeError(
                f"scheduler deadlock: request {self.queue[0].rid} needs "
                f"{self.pool.pages_needed(len(self.queue[0].prompt))} pages, "
                f"pool has {self.pool.num_free} free and no live slots")
        return None

    # ----------------------------------------------------- slot transitions

    def bind(self, slot_idx: int, req: Request, pages: List[int],
             pos: int) -> Slot:
        table = self.pool.new_table()
        table[:len(pages)] = pages
        slot = Slot(req=req, pos=pos, table=table, pages=pages,
                    admit_seq=self._admit_seq)
        self._admit_seq += 1
        self.slots[slot_idx] = slot
        return slot

    def retire(self, slot_idx: int) -> Request:
        """EOS / max-len eviction: free every page the slot holds."""
        slot = self.slots[slot_idx]
        assert slot is not None
        self.pool.free(slot.pages)
        self.slots[slot_idx] = None
        self.finished.append(slot.req)
        return slot.req

    def preempt(self, slot_idx: int) -> Request:
        """Free the slot's pages and requeue its request for a clean replay."""
        slot = self.slots[slot_idx]
        assert slot is not None
        self.pool.free(slot.pages)
        self.slots[slot_idx] = None
        slot.req.generated.clear()
        slot.req.t_first = None
        slot.req.n_preemptions += 1
        self.queue.appendleft(slot.req)
        return slot.req

    def _grow_pages(self) -> None:
        """Before a decode step, every live slot must own the page its next
        write lands in.  Preempts youngest-first when the pool runs dry."""
        ps = self.scfg.page_size
        for i in sorted(self.active_slots(),
                        key=lambda i: self.slots[i].admit_seq):
            slot = self.slots[i]
            if slot is None:
                continue
            if slot.pos % ps != 0 or slot.pos // ps < len(slot.pages):
                continue                       # current page still has room
            while True:
                pages = self.pool.alloc(1)
                if pages is not None:
                    slot.table[len(slot.pages)] = pages[0]
                    slot.pages.extend(pages)
                    break
                victims = [j for j in self.active_slots() if j != i]
                if not victims:
                    raise RuntimeError(
                        "page pool exhausted with a single live slot; "
                        "increase ServeConfig.num_pages")
                victim = max(victims, key=lambda j: self.slots[j].admit_seq)
                self.preempt(victim)
