"""Continuous-batching request scheduler.

Policy (SGLang/Orca-style, simplified to a synchronous loop):

* **Admission**: whenever a decode slot is free and the pools can cover the
  prompt, queued requests are admitted via a bucketed tail prefill — and the
  head of the queue is drained *in batch*: every consecutive queued request
  whose tail lands in the same prefill bucket is admitted into the same
  prefill call, up to the free slots (``try_admit_batch``).  Prefill has
  priority over decode — keeping slots full is what buys continuous batching
  its throughput.  With the radix prefix cache enabled, admission first
  matches the prompt against the tree: matched full pages are shared
  (refcount +1), a partially-matched page is forked copy-on-write, and only
  the uncached tail is prefilled.  Admission is **all-or-nothing** per
  request: every accounting step (dequeue, share, alloc, claim, lock, bind)
  happens only after capacity is proven, so a failed attempt mutates nothing.
* **Families**: the page budget is family-aware (``pool.pages_for``): plain
  ceil for token-addressable KV/MLA pages, capped at the ring horizon for
  sliding-window families (pages recycle in place once positions age out of
  the window), zero for pure state-slot families.  State-slot families
  (SSM / RG-LRU hybrids, the enc-dec cross cache) additionally claim one
  ``StateSlotPool`` slot, whose index is the decode row.
* **Chunked prefill** (``ServeConfig.prefill_chunk_tokens > 0``, paged
  text-prompt families): a prompt longer than the budget is prefilled in
  page-aligned *chunks* that interleave Sarathi-style with decode steps —
  after any prefill step, a decode step runs whenever a slot is decode-ready,
  so one long prompt can never head-of-line-block every live request for its
  whole prefill.  A mid-prefill request stays resident in its slot with all
  its pages and an ``n_filled`` cursor; it joins the decode batch only once
  the cursor reaches the prompt end (and earns its first token from that
  final chunk's logits).  Continuation chunks batch like admissions do
  (same-bucket, oldest first, capped at the budget), and completed pages
  publish to the radix cache after every chunk, so a same-prefix request
  queued behind a long prompt starts hitting the cache mid-prefill.
* **Decode**: otherwise every decode-ready slot advances one token in a
  single fixed-shape jitted step; idle slots ride along masked (their
  page-table rows point at the null page).
* **Growth / eviction / preemption**: a slot crossing a page boundary gets a
  fresh page from the free list — unless it has reached the ring horizon, in
  which case the table entry it is about to write already points at the page
  that just aged out (recycling, no host work at all).  If the pool is
  exhausted, unlocked radix nodes are LRU-evicted first, then the youngest
  slot is preempted.  For checkpointable (pure state-slot) families
  preemption snapshots the slot state to host memory and re-admission
  *restores* it, resuming mid-generation; for paged families the request is
  requeued from scratch (greedy decode is deterministic, so the replay
  reproduces its prefix — usually straight from the cache).
* **Retirement**: EOS or max-tokens retires the slot, releases its page
  references, state slot, and radix locks immediately, making room for the
  next admission.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, List, Optional, Tuple

import numpy as np

from ..configs.base import ServeConfig
from .kv_pool import PagedKVPool, StateSlotPool
from .radix_cache import RadixCache, RadixNode
from .speculate import speculation_k
from .telemetry import MetricsRegistry, Tracer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    # --- filled in by the engine ---
    arrival: float = 0.0
    t_first: Optional[float] = None      # first-token (prefill done) time
    t_finish: Optional[float] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    n_preemptions: int = 0
    cached_tokens: int = 0               # prompt tokens served from the cache
    # checkpoint-on-preempt snapshot: (pos, host state pytree), or None
    checkpoint: Optional[Tuple[int, Any]] = None
    error: str = ""                      # nonempty: rejected or cancelled
    # --- fault tolerance / QoS (serving/admission) ---
    deadline: Optional[float] = None         # absolute: finish by this time
    ttft_deadline: Optional[float] = None    # absolute: first token by this
    retry_after_s: float = 0.0               # backoff hint set when shed

    @property
    def finished(self) -> bool:
        return self.t_finish is not None


@dataclasses.dataclass
class Slot:
    """A live request bound to a decode-batch row."""
    req: Request
    pos: int                              # next write position (= tokens cached)
    table: np.ndarray                     # [table_width] int32
    pages: List[int]                      # referenced physical pages, in order
    admit_seq: int                        # admission order (preemption victim key)
    nodes: List[RadixNode] = dataclasses.field(default_factory=list)
    n_shared: int = 0                     # leading pages shared via the cache
    n_filled: int = 0                     # prompt tokens resident (cached +
                                          # prefilled); < len(prompt) means
                                          # the slot is mid-chunked-prefill
                                          # and not yet decode-ready

    @property
    def prefilling(self) -> bool:
        return self.n_filled < len(self.req.prompt)


@dataclasses.dataclass
class Admission:
    """An admission the scheduler has fully accounted; the engine only has to
    run the device work (COW copy + chunk prefill, or a state restore)."""
    slot_idx: int
    req: Request
    n_matched: int                        # cached prompt tokens (incl. COW)
    cow_src: Optional[int]                # page to fork, or None
    cow_dst: Optional[int]                # exclusively-owned fork target
    table: np.ndarray                     # the bound slot's page table
    pages: List[int]                      # shared + exclusive pages, in order
    n_chunk: int = 0                      # first-chunk tokens to prefill (the
                                          # whole tail when chunking is off)
    restore: Optional[Tuple[int, Any]] = None   # checkpointed (pos, state)


class Scheduler:
    def __init__(self, scfg: ServeConfig, pool: PagedKVPool,
                 radix: Optional[RadixCache] = None,
                 states: Optional[StateSlotPool] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        self.scfg = scfg
        self.pool = pool
        self.radix = radix
        self.states = states
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Slot]] = [None] * scfg.max_slots
        self.finished: List[Request] = []
        self._admit_seq = 0
        # telemetry: queueing + admission-policy visibility (the engine's
        # step counters say what ran; these say what was *decided* and why)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self._m_queue = self.metrics.gauge(
            "sched.queue_depth", "requests waiting for admission")
        self._m_slots = self.metrics.gauge(
            "sched.slots_live", "decode slots bound to live requests")
        self._m_queued = self.metrics.counter(
            "sched.queued", "requests entering the queue (incl. requeues)")
        self._m_admits = self.metrics.counter(
            "sched.admissions", "committed admissions by kind",
            labels=("kind",))               # fresh | cache_hit | restore
        self._m_rejects = self.metrics.counter(
            "sched.rejections", "admission attempts blocked, by reason",
            labels=("reason",))             # no_slot | no_pages
        self._m_preempt = self.metrics.counter(
            "sched.preemptions", "slots evicted under pressure, by kind",
            labels=("kind",))               # checkpoint | replay
        self._m_chunks = self.metrics.counter(
            "sched.chunk_continuations", "continuation chunks scheduled")
        # chunked prefill applies to families whose prompt KV is
        # token-addressable pages at text positions: recurrent state must be
        # carried through a whole prompt in one call, and the vlm image
        # prefix belongs to the first hidden positions of one call
        self.chunk: int = (scfg.chunk_tokens
                           if pool.spec.paged and not pool.spec.prefix_tokens
                           else 0)
        # speculative decoding widens the per-step write horizon: a verify
        # step may write K/V at positions pos .. pos + spec_k, so page
        # growth must cover the whole span (same gate as the engine)
        self.spec_k = speculation_k(pool.cfg, pool.spec, scfg)
        self._last_was_prefill = False

    # ------------------------------------------------------------- inventory

    def add(self, req: Request) -> None:
        if len(req.prompt) >= self.scfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt len {len(req.prompt)} >= "
                f"max_len {self.scfg.max_len}")
        self.queue.append(req)
        self._m_queued.inc()
        self._m_queue.set(len(self.queue))
        self.tracer.on_queued(req.rid, req.arrival or self.tracer.now())

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def decode_ready(self) -> List[int]:
        """Slots whose prompt KV is fully resident — the decode batch.
        Mid-chunked-prefill slots ride along masked (null-page tables would
        be wrong: they own real pages, they just haven't earned a first
        token yet)."""
        return [i for i, s in enumerate(self.slots)
                if s is not None and not s.prefilling]

    def prefilling_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if s is not None and s.prefilling]

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _chunk_len(self, n_done: int, n_prompt: int) -> int:
        """Length of the next prefill chunk for a prompt with ``n_done``
        tokens already resident: everything that's left when chunking is
        off, else up to the budget, ending on a page boundary (so completed
        pages can publish to the radix cache) unless the prompt ends first.
        A cache hit can leave ``n_done`` mid-page (COW); alignment recovers
        at the first chunk boundary."""
        if not self.chunk:
            return n_prompt - n_done
        ps = self.scfg.page_size
        end = min(n_prompt, (n_done + self.chunk) // ps * ps)
        if end <= n_done:                  # can't happen with chunk >= ps;
            end = min(n_prompt, n_done + self.chunk)   # guard anyway
        return end - n_done

    def sweep_deadlines(self, now: float) -> Tuple[List[Request], List[int]]:
        """Deadline police: requests whose total deadline passed, or whose
        TTFT deadline passed before any token, are expired.  Queued expirees
        are removed from the queue and returned; live expirees are returned
        as still-bound slot indices — the engine owns the terminal path
        (staged-step teardown, tracer instant, metrics) and retires them."""
        def expired(req: Request) -> bool:
            if req.deadline is not None and now > req.deadline:
                return True
            return (req.ttft_deadline is not None and req.t_first is None
                    and now > req.ttft_deadline)

        expired_q = [r for r in self.queue if expired(r)]
        for r in expired_q:
            self.queue.remove(r)
        if expired_q:
            self._m_queue.set(len(self.queue))
        expired_live = [i for i, s in enumerate(self.slots)
                        if s is not None and expired(s.req)]
        return expired_q, expired_live

    # ------------------------------------------------------------ scheduling

    def next_action(self) -> Optional[Tuple]:
        """('prefill', [Admission, ...]) | ('prefill_chunk', [slot_idx, ...])
        | ('restore', Admission) | ('decode', [slot_idx, ...]) | None.

        Without chunking, prefill has strict priority over decode (keeping
        slots full is what buys continuous batching its throughput).  With a
        chunk budget set, steps interleave Sarathi-style instead: a prefill
        step (admission or continuation chunk) is followed by a decode step
        whenever any slot is decode-ready, so a long prompt advances one
        bounded chunk at a time instead of stalling every live request for
        its whole prefill."""
        order = ("prefill", "decode")
        if self.chunk and self._last_was_prefill and self.decode_ready():
            order = ("decode", "prefill")
        for phase in order:
            act = self._prefill_action() if phase == "prefill" \
                else self._decode_action()
            if act is not None:
                self._last_was_prefill = act[0] != "decode"
                return act
        if self.queue:
            # no slot/page capacity and nothing running to free any.  If the
            # prefix cache is what holds the pool (cache_eviction="none", or
            # only co-owned leaves blocked make_room), serving beats caching:
            # flush the tree's references and retry once before giving up.
            if self.radix is not None and self.radix.num_nodes:
                self.radix.reset()
                act = self._prefill_action()
                if act is not None:
                    self._last_was_prefill = True
                    return act
            raise RuntimeError(
                f"scheduler deadlock: request {self.queue[0].rid} needs "
                f"{self.pool.pages_for(len(self.queue[0].prompt))} pages, "
                f"pool has {self.pool.num_free} free and no live slots")
        return None

    def _prefill_action(self) -> Optional[Tuple]:
        """Admissions first (keeping slots full is what buys continuous
        batching its throughput), then continuation chunks of already-
        admitted prompts."""
        if self.queue:
            adms = self.try_admit_batch()
            if adms:
                if adms[0].restore is not None:
                    return ("restore", adms[0])
                return ("prefill", adms)
        chunks = self._chunk_batch()
        if chunks:
            self._m_chunks.inc(len(chunks))
            return ("prefill_chunk", chunks)
        return None

    def _decode_action(self) -> Optional[Tuple]:
        if not self.decode_ready():
            return None
        self._grow_pages()
        active = self.decode_ready()              # growth may have preempted
        return ("decode", active) if active else None

    def _chunk_batch(self) -> List[int]:
        """Continuation chunks, oldest admissions first: consecutive
        mid-prefill slots whose next chunk lands in the same bucket are
        batched, up to the per-step token budget."""
        jobs: List[int] = []
        bucket: Optional[int] = None
        total = 0
        for i in sorted(self.prefilling_slots(),
                        key=lambda i: self.slots[i].admit_seq):
            slot = self.slots[i]
            c = self._chunk_len(slot.n_filled, len(slot.req.prompt))
            b = self.scfg.bucket_of(c)
            if bucket is not None and b != bucket:
                break
            if jobs and total + c > self.chunk:
                break
            jobs.append(i)
            bucket, total = b, total + c
        return jobs

    def try_admit_batch(self) -> List[Admission]:
        """Drain the queue head into one prefill: consecutive requests whose
        first chunks share a bucket are admitted together (each one
        individually all-or-nothing), capped at the per-step token budget
        when chunking is on.  A checkpointed request is admitted alone — its
        action is a state restore, not a prefill.  With the prefix cache on,
        a request whose prompt pages an *earlier admission in this batch* is
        about to publish waits a step instead, so it re-matches as a cache
        hit rather than prefilling the shared prefix redundantly."""
        adms: List[Admission] = []
        bucket: Optional[int] = None
        ps = self.scfg.page_size
        total = 0
        pending_keys: set = set()
        while self.queue:
            head = self.queue[0]
            if head.checkpoint is not None:
                if not adms:
                    adm = self.try_admit()
                    if adm is not None:
                        adms.append(adm)
                break
            n_matched = 0
            match = None
            keys = set()
            if self.radix is not None:
                # one probe (clock-touches only) finds the chunk bucket and
                # is reused by try_admit below — nothing mutates in between
                match = self.radix.match(head.prompt, len(head.prompt) - 1)
                n_matched = match.n_matched
                # a radix node is its token *prefix*: key the pages this
                # prompt would publish by their cumulative prefixes
                keys = {tuple(head.prompt[:(j + 1) * ps])
                        for j in range(len(head.prompt) // ps)}
                if keys & pending_keys:
                    break
            c = self._chunk_len(n_matched, len(head.prompt))
            b = self.scfg.bucket_of(c)
            if bucket is not None and b != bucket:
                break
            if self.chunk and adms and total + c > self.chunk:
                break
            adm = self.try_admit(match)
            if adm is None:
                break
            adms.append(adm)
            bucket, total = b, total + c
            pending_keys |= keys
        return adms

    def try_admit(self, match=None) -> Optional[Admission]:
        """Admit the oldest queued request if (and only if) every resource it
        needs is available; on failure nothing — queue, pool, tree — changes.
        ``match`` is an optional precomputed ``radix.match`` result for the
        head request (the batch loop's probe), reused to avoid a second
        tree walk."""
        idx = self.free_slot()
        if not self.queue:
            return None
        if idx is None:
            self._m_rejects.labels(reason="no_slot").inc()
            return None
        req = self.queue[0]
        if req.checkpoint is not None:
            # checkpointable families are page-free: a slot is all it needs
            self.queue.popleft()
            self._m_queue.set(len(self.queue))
            self._m_admits.labels(kind="restore").inc()
            pos, _ = req.checkpoint
            slot = self.bind(idx, req, [], pos=pos,
                             n_filled=len(req.prompt))
            adm = Admission(slot_idx=idx, req=req, n_matched=0, cow_src=None,
                            cow_dst=None, table=slot.table, pages=[],
                            restore=req.checkpoint)
            req.checkpoint = None
            return adm
        n = len(req.prompt)
        nodes: List[RadixNode] = []
        shared: List[int] = []
        cow_src, cow_len, n_matched = None, 0, 0
        if self.radix is not None:
            m = match or self.radix.match(req.prompt, n - 1)
            nodes, shared = m.nodes, m.pages
            cow_src, cow_len, n_matched = m.cow_src, m.cow_len, m.n_matched
        # the last prompt token is always computed, so at least one page is
        # never shared: need >= 1 for paged families (0 for state-slot-only)
        need = self.pool.pages_for(n) - len(shared)
        if self.pool.num_free < need:
            if self.radix is not None:
                # pin the matched path so making room can't evict it; a
                # hopeless attempt evicts nothing (all-or-nothing extends to
                # the cache contents)
                self.radix.lock(nodes)
                self.radix.make_room(need)
                self.radix.unlock(nodes)
            if self.pool.num_free < need:
                self._m_rejects.labels(reason="no_pages").inc()
                return None
        # ---- commit point: capacity proven, take everything atomically ----
        self.queue.popleft()
        self._m_queue.set(len(self.queue))
        self._m_admits.labels(
            kind="cache_hit" if n_matched else "fresh").inc()
        self.pool.share(shared)
        fresh = self.pool.alloc(need)
        assert fresh is not None
        if self.radix is not None:
            self.radix.lock(nodes)
        pages = shared + fresh
        slot = self.bind(idx, req, pages,
                         pos=self.pool.spec.prefix_tokens + n, nodes=nodes,
                         n_shared=len(shared), n_filled=n_matched)
        req.cached_tokens = n_matched
        return Admission(slot_idx=idx, req=req, n_matched=n_matched,
                         cow_src=cow_src,
                         cow_dst=fresh[0] if cow_len else None,
                         table=slot.table, pages=pages,
                         n_chunk=self._chunk_len(n_matched, n))

    # ----------------------------------------------------- slot transitions

    def bind(self, slot_idx: int, req: Request, pages: List[int], pos: int,
             nodes: Optional[List[RadixNode]] = None,
             n_shared: int = 0, n_filled: Optional[int] = None) -> Slot:
        table = self.pool.new_table()
        table[:len(pages)] = pages
        slot = Slot(req=req, pos=pos, table=table, pages=pages,
                    admit_seq=self._admit_seq, nodes=list(nodes or []),
                    n_shared=n_shared,
                    n_filled=len(req.prompt) if n_filled is None else n_filled)
        self._admit_seq += 1
        self.slots[slot_idx] = slot
        if self.states is not None:
            self.states.claim(slot_idx)
        self._m_slots.set(sum(s is not None for s in self.slots))
        return slot

    def _unbind(self, slot_idx: int) -> Slot:
        """Release a slot's page references, state slot, and radix locks
        (shared pages are freed only when their last owner — usually the
        tree — lets go)."""
        slot = self.slots[slot_idx]
        assert slot is not None
        self.pool.release(slot.pages)
        if self.states is not None:
            self.states.release(slot_idx)
        if self.radix is not None and slot.nodes:
            self.radix.unlock(slot.nodes)
        self.slots[slot_idx] = None
        self._m_slots.set(sum(s is not None for s in self.slots))
        return slot

    def retire(self, slot_idx: int) -> Request:
        """EOS / max-len eviction: drop every page reference the slot holds."""
        slot = self._unbind(slot_idx)
        self.finished.append(slot.req)
        return slot.req

    def preempt(self, slot_idx: int) -> Request:
        """Evict a live slot and requeue its request.

        Checkpointable (pure state-slot) families snapshot the slot's state
        to host memory first — re-admission restores it and decoding resumes
        mid-generation, tokens intact.  Paged families release their page
        references for a clean replay (only exclusively-owned pages actually
        return to the free list; pages published to the radix cache stay
        resident, so the replay typically re-admits as a cache hit)."""
        checkpointable = (self.states is not None
                          and self.pool.spec.checkpointable)
        if checkpointable:
            slot = self.slots[slot_idx]
            assert slot is not None
            slot.req.checkpoint = (slot.pos,
                                   self.states.checkpoint(slot_idx))
        slot = self._unbind(slot_idx)
        if not checkpointable:
            # replay regenerates the same greedy tokens, but t_first is NOT
            # reset: TTFT measures the first token *ever* produced, so the
            # legacy RequestResult.ttft agrees with tracer ttft_s
            slot.req.generated.clear()
            slot.req.cached_tokens = 0
        slot.req.n_preemptions += 1
        self.queue.appendleft(slot.req)
        self._m_preempt.labels(
            kind="checkpoint" if checkpointable else "replay").inc()
        self._m_queue.set(len(self.queue))
        self.tracer.on_preempted(slot.req.rid, self.tracer.now(),
                                 checkpointable)
        return slot.req

    def _grow_pages(self) -> None:
        """Before a decode step, every live slot must own the page its next
        write lands in — and with speculation on, every page any of the up
        to ``spec_k + 1`` verify-step writes (positions pos .. pos + spec_k)
        lands in, since an accepted draft advances the cursor several
        positions in one step (it may cross a page boundary mid-step).
        Ring-horizon slots recycle in place (their next table entry already
        points at the page that aged out of the window).  When the pool runs
        dry, LRU-evict unlocked cache nodes first, then preempt
        youngest-first."""
        if not self.pool.spec.paged:
            return                         # state-slot families never grow
        ps = self.scfg.page_size
        cap = self.pool.table_width
        for i in sorted(self.active_slots(),
                        key=lambda i: self.slots[i].admit_seq):
            slot = self.slots[i]
            if slot is None:
                continue
            if slot.prefilling:
                continue                   # all prompt pages bound at admission;
                                           # the decode page can wait its turn
            # last page index this step's writes can reach; past the ring
            # horizon the table entries recycle in place instead of growing
            need_to = min((slot.pos + self.spec_k) // ps, cap - 1)
            while len(slot.pages) <= need_to:
                if self.slots[i] is not slot:
                    break                  # preemption below evicted *us*
                pages = self.pool.alloc(1)
                if pages is not None:
                    slot.table[len(slot.pages)] = pages[0]
                    slot.pages.extend(pages)
                    continue
                if self.radix is not None and self.radix.make_room(1):
                    continue                   # eviction freed a page
                victims = [j for j in self.active_slots() if j != i]
                if not victims:
                    # last resort before giving up: the cache may hold pages
                    # this slot doesn't use (cache_eviction="none" keeps
                    # make_room from touching them) — flush and retry
                    if self.radix is not None and self.radix.num_nodes:
                        self.radix.reset()
                        continue
                    raise RuntimeError(
                        "page pool exhausted with a single live slot; "
                        "increase ServeConfig.num_pages")
                victim = max(victims, key=lambda j: self.slots[j].admit_seq)
                self.preempt(victim)
