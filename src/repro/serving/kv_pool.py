"""Paged KV cache pool: fixed-size pages, free-list allocation, refcounts.

The pool replaces the old ``pad_cache_to`` whole-cache zero-pad copy with
vLLM/MaxText-style paging: KV for *all* live requests lives in one
``[L, num_pages, page_size, K, D]`` pair of arrays, and each request owns an
ordered list of physical pages recorded in an int32 page table.  Allocation
and release are O(1) host-side free-list operations — admitting or retiring a
request never touches the device arrays.

Ownership is *refcounted* so pages can be shared across owners: the radix
prefix cache (``radix_cache``) holds one reference per cached page, and every
slot whose prompt prefix matched holds its own reference on the same physical
pages.  ``alloc`` hands out pages at refcount 1, ``share`` adds an owner,
``release`` (aliased as ``free``) drops one — the page only returns to the
free list when its last owner lets go.  A shared page is immutable by
convention: only full prompt pages are ever shared, and writes always land at
positions past every sharer's prompt (see ``radix_cache`` / ``scheduler``).

Physical page 0 is reserved as the *null page*: idle decode slots keep their
table rows zeroed so their (discarded) writes land there, and page-table
entries past a request's allocated region point at it harmlessly (attention
masks positions > pos, so stale bytes are softmax-zero).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from ..configs.base import ArchConfig, ServeConfig
from ..models.params import init_tree
from ..models.registry import build_model

NULL_PAGE = 0


class PagedKVPool:
    """Device KV pages + host-side page accounting for the serving engine."""

    def __init__(self, cfg: ArchConfig, scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        model = build_model(cfg)
        defs = model.paged_cache_defs(scfg.total_pages, scfg.page_size)
        # zeros init: pages hold only finite values from day one, so masked
        # (zero-weight) reads of stale pages can never produce NaNs
        self.kv: Dict[str, jax.Array] = init_tree(defs, jax.random.PRNGKey(0))
        self._free: List[int] = list(range(scfg.total_pages - 1, NULL_PAGE, -1))
        self._ref: Dict[int, int] = {}

    # ------------------------------------------------------------ accounting

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._ref)

    @property
    def refcounts(self) -> Dict[int, int]:
        """Live page -> owner count (copy; empty when the pool is idle)."""
        return dict(self._ref)

    def ref(self, page: int) -> int:
        return self._ref.get(page, 0)

    def pages_needed(self, n_tokens: int) -> int:
        ps = self.scfg.page_size
        return -(-n_tokens // ps)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages from the free list; None (no partial grab) if short.

        Each returned page starts at refcount 1 (the caller is the owner)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def share(self, pages: Sequence[int]) -> None:
        """Add one owner to each (already-allocated) page."""
        for p in pages:
            assert p != NULL_PAGE, "tried to share the reserved null page"
            assert p in self._ref, f"share of unallocated page {p}"
            self._ref[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one owner per page; pages at refcount 0 return to the free
        list.  Releasing a page you don't own is a double free."""
        for p in pages:
            assert p != NULL_PAGE, "tried to free the reserved null page"
            assert p in self._ref, f"double free of page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)

    # exclusive-ownership spelling used by pre-refcount call sites/tests
    free = release

    # ------------------------------------------------------------ page tables

    def new_table(self) -> np.ndarray:
        """An all-null page table row ([pages_per_request] int32)."""
        return np.full((self.scfg.pages_per_request,), NULL_PAGE, np.int32)
