"""Paged cache pool + state-slot pool: the two allocators behind the engine.

``PagedKVPool`` — fixed-size pages, free-list allocation, refcounts.

The pool replaces the old ``pad_cache_to`` whole-cache zero-pad copy with
vLLM/MaxText-style paging: the token-addressable cache for *all* live
requests lives in one layer-stacked array set (K/V pages for attention
families, latent pages for MLA), and each request owns an ordered list of
physical pages recorded in an int32 page table.  Allocation and release are
O(1) host-side free-list operations — admitting or retiring a request never
touches the device arrays.

The pool is *family-aware* via the model's ``cache_spec()``:

* plain / MLA paged families: ``pages_for(n)`` is ``ceil(n / page_size)``;
* sliding-window families: the table is a ring of ``horizon_pages`` entries
  and ``pages_for`` caps there — a request holds O(window) pages no matter
  how long it generates (aged-out pages are recycled in place);
* vlm: every request carries ``prefix_tokens`` image positions before its
  text, accounted into ``pages_for``;
* pure state-slot families (SSM / RG-LRU hybrids): ``paged_defs`` is empty,
  ``pages_for`` is 0, and all capacity lives in the ``StateSlotPool``.

Ownership is *refcounted* so pages can be shared across owners: the radix
prefix cache (``radix_cache``) holds one reference per cached page, and every
slot whose prompt prefix matched holds its own reference on the same physical
pages.  ``alloc`` hands out pages at refcount 1, ``share`` adds an owner,
``release`` (aliased as ``free``) drops one — the page only returns to the
free list when its last owner lets go.  A shared page is immutable by
convention: only full prompt pages are ever shared, and writes always land at
positions past every sharer's prompt (see ``radix_cache`` / ``scheduler``).

Physical page 0 is reserved as the *null page*: idle decode slots keep their
table rows zeroed so their (discarded) writes land there, and page-table
entries past a request's allocated region point at it harmlessly (attention
masks positions > pos, so stale bytes are softmax-zero).

``StateSlotPool`` — per-request fixed-size state, slot index == decode row.

Recurrent families (SSM conv taps + SSD state, RG-LRU conv + hidden state,
the hybrid local-attention ring) and the enc-dec pinned cross cache don't
grow with generated length; they get exactly one *state slot* per live
request, claimed at admission and released at retirement.  The slot lifetime
contract is alloc -> checkpoint-on-preempt -> restore -> free: preempting a
request snapshots its slot to host memory (``checkpoint``) so re-admission
can ``restore`` it and resume decoding mid-stream instead of replaying the
prompt.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ServeConfig
from ..models.cache_spec import CacheFamilySpec, window_pages
from ..models.params import init_tree
from ..models.registry import build_model
from .telemetry import MetricsRegistry

NULL_PAGE = 0


class PagedKVPool:
    """Device cache pages + host-side page accounting for the serving engine."""

    def __init__(self, cfg: ArchConfig, scfg: ServeConfig,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.scfg = scfg
        model = build_model(cfg)
        self.spec: CacheFamilySpec = model.cache_spec()
        ps = scfg.page_size
        self.horizon_pages: Optional[int] = (
            window_pages(self.spec.window, ps) if self.spec.window else None)
        if self.horizon_pages is not None and scfg.speculate_tokens:
            # speculative verify writes up to K draft tokens past pos before
            # accept/rollback; one slack page keeps a rejected draft's write
            # from recycling a slot that is still inside the window after
            # rollback (safe because K < page_size, asserted by ServeConfig —
            # the recycled slot's recovered position is already out of window
            # for every post-rollback query)
            self.horizon_pages += 1
        # widest table any request can need: full prompt+generation (plus the
        # vlm image prefix), capped at the ring horizon for windowed families
        raw = -(-(self.spec.prefix_tokens + scfg.max_len) // ps)
        self.table_width: int = (
            0 if not self.spec.paged
            else min(raw, self.horizon_pages) if self.horizon_pages else raw)
        self.total_pages: int = (
            scfg.num_pages or scfg.max_slots * max(self.table_width, 1) + 1)
        defs = model.paged_cache_defs(self.total_pages, ps,
                                      kv_dtype=scfg.kv_dtype)
        # zeros init: pages hold only finite values from day one, so masked
        # (zero-weight) reads of stale pages can never produce NaNs
        self.kv: Dict[str, jax.Array] = init_tree(defs, jax.random.PRNGKey(0))
        # int8 scale leaves share the payload's page axis (axis 1 after layer
        # stacking): one physical page id addresses payload and scales
        # together, so ``pages_for``/``table_width``, refcounts, radix
        # sharing, COW forks, and ring recycling need no separate scale
        # accounting, and the conservation counters below reconcile
        # unchanged under int8.  The invariant the whole design rests on:
        for leaf in jax.tree.leaves(self.kv):
            assert leaf.shape[1] == self.total_pages, (
                "paged-cache leaf does not share the pool page axis: "
                f"{leaf.shape} vs {self.total_pages} pages")
        self._free: List[int] = list(range(self.total_pages - 1, NULL_PAGE, -1))
        self._ref: Dict[int, int] = {}
        # telemetry: conservation counters (allocated == released + live at
        # any instant) plus occupancy gauges the scheduler can't see from
        # num_free alone
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_alloc = self.metrics.counter(
            "pool.pages_allocated", "pages handed out by alloc()")
        self._m_released = self.metrics.counter(
            "pool.pages_released", "pages returned to the free list")
        self._m_shares = self.metrics.counter(
            "pool.refs_shared", "extra owners added via share()")
        self._m_scrubbed = self.metrics.counter(
            "pool.pages_scrubbed", "pages zero-scrubbed during quarantine")
        self._m_live = self.metrics.gauge(
            "pool.pages_live", "pages currently allocated (refcount > 0)")
        self._m_free = self.metrics.gauge(
            "pool.free_pages", "free-list depth")
        self._m_refs = self.metrics.gauge(
            "pool.ref_total", "sum of refcounts over live pages")
        self._m_free.set(len(self._free))

    # ------------------------------------------------------------ accounting

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._ref)

    @property
    def refcounts(self) -> Dict[int, int]:
        """Live page -> owner count (copy; empty when the pool is idle)."""
        return dict(self._ref)

    def ref(self, page: int) -> int:
        return self._ref.get(page, 0)

    def pages_needed(self, n_tokens: int) -> int:
        """Raw page count for ``n_tokens`` contiguous positions."""
        ps = self.scfg.page_size
        return -(-n_tokens // ps)

    def pages_for(self, n_prompt_tokens: int) -> int:
        """Family-aware page budget for admitting a prompt: adds the vlm
        image prefix, caps at the ring horizon for windowed families, and is
        0 when the whole cache lives in state slots."""
        if not self.spec.paged:
            return 0
        n = self.pages_needed(self.spec.prefix_tokens + n_prompt_tokens)
        return min(n, self.horizon_pages) if self.horizon_pages else n

    @property
    def page_nbytes(self) -> int:
        """Device bytes one physical page occupies across all layers and
        leaves — int8 pools count payload *and* scale leaves, since a page id
        owns its slice of both."""
        return sum(leaf.size // leaf.shape[1] * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.kv))

    @property
    def kv_bytes_per_token(self) -> float:
        """Device bytes one token slot costs (``page_nbytes / page_size``) —
        the decode read path moves exactly this much per live token, so it is
        the quantization win the benchmarks gate on."""
        return self.page_nbytes / self.scfg.page_size

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages from the free list; None (no partial grab) if short.

        Each returned page starts at refcount 1 (the caller is the owner)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self._m_alloc.inc(n)
        self._m_refs.inc(n)
        self._sync_gauges()
        return pages

    def share(self, pages: Sequence[int]) -> None:
        """Add one owner to each (already-allocated) page."""
        for p in pages:
            assert p != NULL_PAGE, "tried to share the reserved null page"
            assert p in self._ref, f"share of unallocated page {p}"
            self._ref[p] += 1
        self._m_shares.inc(len(pages))
        self._m_refs.inc(len(pages))

    def release(self, pages: Sequence[int]) -> None:
        """Drop one owner per page; pages at refcount 0 return to the free
        list.  Releasing a page you don't own is a double free."""
        for p in pages:
            assert p != NULL_PAGE, "tried to free the reserved null page"
            assert p in self._ref, f"double free of page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)
                self._m_released.inc()
        self._m_refs.dec(len(pages))
        self._sync_gauges()

    def _sync_gauges(self) -> None:
        self._m_live.set(len(self._ref))
        self._m_free.set(len(self._free))

    def note_scrubbed(self, n: int) -> None:
        """Record ``n`` pages zero-scrubbed by the engine's quarantine path."""
        self._m_scrubbed.inc(n)

    def conservation_ok(self) -> bool:
        """Counter reconciliation: every page ever allocated is either live
        or has been released, and the free list + live set tile the pool
        (minus the reserved null page)."""
        alloc = self.metrics.value("pool.pages_allocated")
        released = self.metrics.value("pool.pages_released")
        if alloc != released + len(self._ref):
            return False
        return len(self._free) + len(self._ref) == self.total_pages - 1

    # exclusive-ownership spelling used by pre-refcount call sites/tests
    free = release

    # ------------------------------------------------------------ page tables

    def new_table(self) -> np.ndarray:
        """An all-null page table row ([table_width] int32)."""
        return np.full((max(self.table_width, 1),), NULL_PAGE, np.int32)


class StateSlotPool:
    """Per-request fixed-size state slots, one per decode row.

    The device state is one layer-stacked pytree whose slot axis is axis 1
    and whose slot index equals the engine's decode-batch row, so the decode
    step reads/writes it with no gather.  ``claim``/``release`` book-keep
    which rows are live; ``checkpoint``/``restore`` implement the
    preemption half of the slot lifetime contract (see module docstring)."""

    def __init__(self, cfg: ArchConfig, scfg: ServeConfig,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.scfg = scfg
        model = build_model(cfg)
        defs = model.state_slot_defs(scfg.max_slots, scfg.max_len,
                                     enc_len=scfg.enc_len)
        self.state: Any = init_tree(defs, jax.random.PRNGKey(0))
        self.n_slots = scfg.max_slots
        self._claimed: Set[int] = set()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_resident = self.metrics.gauge(
            "states.slots_claimed", "state slots held by live requests")
        self._m_claims = self.metrics.counter(
            "states.claims", "state-slot claims (admissions)")
        self._m_ckpt = self.metrics.counter(
            "states.checkpoints", "slot snapshots taken on preemption")
        self._m_restore = self.metrics.counter(
            "states.restores", "checkpointed snapshots written back")

    # ------------------------------------------------------------ accounting

    @property
    def num_claimed(self) -> int:
        return len(self._claimed)

    @property
    def claimed(self) -> Set[int]:
        return set(self._claimed)

    def claim(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots, slot
        assert slot not in self._claimed, f"double claim of state slot {slot}"
        self._claimed.add(slot)
        self._m_claims.inc()
        self._m_resident.set(len(self._claimed))

    def release(self, slot: int) -> None:
        assert slot in self._claimed, f"release of unclaimed state slot {slot}"
        self._claimed.remove(slot)
        self._m_resident.set(len(self._claimed))

    # ------------------------------------------------- checkpoint / restore

    def checkpoint(self, slot: int) -> Any:
        """Snapshot one slot's state to host memory (preemption)."""
        assert slot in self._claimed, f"checkpoint of unclaimed slot {slot}"
        self._m_ckpt.inc()
        return jax.tree.map(lambda a: np.asarray(a[:, slot]), self.state)

    def restore(self, slot: int, saved: Any) -> None:
        """Write a checkpointed snapshot back into (a possibly different)
        claimed slot."""
        assert slot in self._claimed, f"restore into unclaimed slot {slot}"
        self._m_restore.inc()
        self.state = jax.tree.map(
            lambda a, s: a.at[:, slot].set(jnp.asarray(s, a.dtype)),
            self.state, saved)

    # --------------------------------------------------- fault-tolerance hooks

    def _fill_row(self, slot: int, value: float) -> None:
        def fill(a):
            if not jnp.issubdtype(a.dtype, jnp.floating):
                return a
            return a.at[:, slot].set(jnp.asarray(value, a.dtype))
        self.state = jax.tree.map(fill, self.state)

    def scrub(self, slot: int) -> None:
        """Zero one slot row (quarantine cleanup).  Rows are overwritten at
        the next claim anyway; scrubbing keeps the any-idle-row-is-finite
        invariant so a stale NaN can never leak through a masked read."""
        self._fill_row(slot, 0.0)

    def poison(self, slot: int) -> None:
        """Fill one slot row with NaN (fault injection only)."""
        self._fill_row(slot, float("nan"))
