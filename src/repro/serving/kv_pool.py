"""Paged KV cache pool: fixed-size pages, free-list allocation, page tables.

The pool replaces the old ``pad_cache_to`` whole-cache zero-pad copy with
vLLM/MaxText-style paging: KV for *all* live requests lives in one
``[L, num_pages, page_size, K, D]`` pair of arrays, and each request owns an
ordered list of physical pages recorded in an int32 page table.  Allocation
and release are O(1) host-side free-list operations — admitting or retiring a
request never touches the device arrays.

Physical page 0 is reserved as the *null page*: idle decode slots keep their
table rows zeroed so their (discarded) writes land there, and page-table
entries past a request's allocated region point at it harmlessly (attention
masks positions > pos, so stale bytes are softmax-zero).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from ..configs.base import ArchConfig, ServeConfig
from ..models.params import init_tree
from ..models.registry import build_model

NULL_PAGE = 0


class PagedKVPool:
    """Device KV pages + host-side page accounting for the serving engine."""

    def __init__(self, cfg: ArchConfig, scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        model = build_model(cfg)
        defs = model.paged_cache_defs(scfg.total_pages, scfg.page_size)
        # zeros init: pages hold only finite values from day one, so masked
        # (zero-weight) reads of stale pages can never produce NaNs
        self.kv: Dict[str, jax.Array] = init_tree(defs, jax.random.PRNGKey(0))
        self._free: List[int] = list(range(scfg.total_pages - 1, NULL_PAGE, -1))
        self._allocated: set = set()

    # ------------------------------------------------------------ accounting

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._allocated)

    def pages_needed(self, n_tokens: int) -> int:
        ps = self.scfg.page_size
        return -(-n_tokens // ps)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages from the free list; None (no partial grab) if short."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            assert p != NULL_PAGE, "tried to free the reserved null page"
            assert p in self._allocated, f"double free of page {p}"
            self._allocated.remove(p)
            self._free.append(p)

    # ------------------------------------------------------------ page tables

    def new_table(self) -> np.ndarray:
        """An all-null page table row ([pages_per_request] int32)."""
        return np.full((self.scfg.pages_per_request,), NULL_PAGE, np.int32)
