"""Deterministic fault injection for the serving engine.

The serving stack's recovery paths (request quarantine, pool-pressure
survival, watchdog drain, disconnect cleanup) are only trustworthy if they
are exercised on every CI run, not just when hardware misbehaves.  This
module provides a seeded, declarative ``FaultPlan`` that the engine consults
at its seams, so a chaos run is exactly reproducible:

    plan = FaultPlan.parse("nan_logits:rid=1,at=2;step_error:rid=2,at=1")
    eng = Engine(cfg, scfg, params, faults=plan)

Fault taxonomy (``Fault.kind``):

``nan_logits``
    Poison the target request's exclusively-owned KV page (or state-slot
    row) with NaN right before the decode/verify launch at which it has
    produced exactly ``at`` tokens.  Masked attention is a zero-*weight*
    multiply, so the NaN propagates into that row's logits; the jitted step
    reports a per-row finite flag and the engine quarantines the row.
``step_error``
    Raise :class:`RequestFault` at the host seam immediately *before* the
    decode/verify launch once the target has ``>= at`` tokens.  Raising
    before launch matters: the jitted steps donate the KV/state buffers, so
    a post-launch exception would invalidate the pool for everyone.  An
    exception raised *inside* a donated step remains fatal by design.
``pool_pressure``
    At engine tick ``at``, grab ``min(pages, free)`` pages from the pool and
    hold them for ``steps`` ticks, forcing eviction/preemption churn.  If
    the scheduler deadlocks (no progress possible), the engine asks the
    injector to release the hostage pages and retries once.
``client_disconnect``
    After the target rid has streamed ``at`` tokens, cancel it as if the
    client vanished.  The cancel is deferred to the top of the next
    dispatch — mutating slots mid-collect is unsafe.
``detok_stall``
    Sleep ``stall_s`` seconds inside the detokenizer worker at its ``at``-th
    token event, exercising backpressure and (with a watchdog armed) the
    hung-pipeline recovery path.

All faults are one-shot; :meth:`FaultPlan.unfired` lets ``--verify`` assert
the plan actually executed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

FAULT_KINDS = (
    "nan_logits",
    "step_error",
    "pool_pressure",
    "client_disconnect",
    "detok_stall",
)


class RequestFault(RuntimeError):
    """A fault attributable to a single request (raised pre-launch)."""

    def __init__(self, rid: int, kind: str):
        super().__init__(f"injected {kind} for rid={rid}")
        self.rid = rid
        self.kind = kind


@dataclasses.dataclass
class Fault:
    """One injected fault.  Field meaning depends on ``kind`` (see module doc)."""

    kind: str
    rid: int = -1       # target request id (nan_logits/step_error/client_disconnect)
    at: int = 1         # token count / engine tick / detok event index trigger
    pages: int = 0      # pool_pressure: pages to hold
    steps: int = 1      # pool_pressure: ticks to hold them
    stall_s: float = 0.0  # detok_stall: sleep duration
    fired: bool = dataclasses.field(default=False, compare=False)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected one of {FAULT_KINDS}")
        if self.kind == "nan_logits" and self.at < 1:
            # Token 0 comes from prefill (checked host-side); the poison seam
            # only exists once the request is decoding.
            raise ValueError("nan_logits requires at >= 1")
        if self.kind == "pool_pressure" and self.pages < 1:
            raise ValueError("pool_pressure requires pages >= 1")
        if self.kind == "detok_stall" and self.stall_s <= 0:
            raise ValueError("detok_stall requires stall_s > 0")

    def describe(self) -> str:
        parts = [f"rid={self.rid}", f"at={self.at}"]
        if self.kind == "pool_pressure":
            parts = [f"at={self.at}", f"pages={self.pages}", f"steps={self.steps}"]
        if self.kind == "detok_stall":
            parts = [f"at={self.at}", f"stall_s={self.stall_s}"]
        return f"{self.kind}:{','.join(parts)}"


@dataclasses.dataclass
class FaultPlan:
    """A deterministic, ordered set of faults for one serve run."""

    faults: List[Fault] = dataclasses.field(default_factory=list)
    seed: int = 0

    @staticmethod
    def parse(spec: str, seed: int = 0) -> "FaultPlan":
        """Parse ``"kind:k=v,k=v;kind2:k=v"`` into a plan.

        Keys: ``rid``, ``at``, ``pages``, ``steps`` (ints) and ``stall_s``
        (float).  Example: ``"nan_logits:rid=1,at=2;pool_pressure:at=2,pages=4"``.
        """
        faults: List[Fault] = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            kind, _, rest = part.partition(":")
            kwargs = {}
            for kv in filter(None, (s.strip() for s in rest.split(","))):
                key, _, val = kv.partition("=")
                if key == "stall_s":
                    kwargs[key] = float(val)
                elif key in ("rid", "at", "pages", "steps"):
                    kwargs[key] = int(val)
                else:
                    raise ValueError(f"unknown fault field {key!r} in {part!r}")
            faults.append(Fault(kind=kind.strip(), **kwargs))
        if not faults:
            raise ValueError(f"empty fault plan spec: {spec!r}")
        return FaultPlan(faults=faults, seed=seed)

    def unfired(self) -> List[str]:
        return [f.describe() for f in self.faults if not f.fired]


class FaultInjector:
    """Engine-side executor for a :class:`FaultPlan`.

    The engine calls the seam hooks below; each fault fires at most once.
    All counters land in ``engine.faults_injected{kind=...}``.
    """

    def __init__(self, plan: FaultPlan, metrics):
        self.plan = plan
        self._tick = 0
        self._held: List[int] = []       # pool_pressure hostage pages
        self._release_at = -1
        self._pending_cancels: List[int] = []
        self._detok_events = 0
        self._m_injected = metrics.counter(
            "engine.faults_injected",
            "Faults fired by the injection harness, by kind.",
            labels=("kind",),
        )

    def _fire(self, fault: Fault) -> None:
        fault.fired = True
        self._m_injected.labels(kind=fault.kind).inc()

    def unfired(self) -> List[str]:
        return self.plan.unfired()

    # ---- engine seams ----------------------------------------------------

    def on_tick(self, engine) -> None:
        """Top of ``_dispatch_next``: tick clock, pressure, deferred cancels."""
        self._tick += 1
        for rid in self._pending_cancels:
            engine.cancel(rid)
        self._pending_cancels.clear()
        pool = engine.pool
        if self._held and self._tick >= self._release_at:
            self.release_pressure(engine)
        for f in self.plan.faults:
            if f.fired or f.kind != "pool_pressure" or self._tick < f.at:
                continue
            if not pool.spec.paged:
                self._fire(f)  # state-slot pools have no page pool to squeeze
                continue
            grab = min(f.pages, pool.num_free)
            if grab > 0:
                held = pool.alloc(grab)
                assert held is not None
                self._held.extend(held)
            self._release_at = self._tick + max(f.steps, 1)
            self._fire(f)

    def release_pressure(self, engine) -> bool:
        """Release hostage pages (deadlock recovery / drain).  True if any."""
        if not self._held:
            return False
        engine.pool.release(self._held)
        self._held = []
        return True

    def before_launch(self, engine, kind: str, rows: List[int]) -> None:
        """Immediately before a decode/verify launch over slot indices ``rows``.

        May raise :class:`RequestFault` (step_error) or poison a row's KV
        (nan_logits).  Only the decode/verify seam is used: the donated
        buffers are still intact here, and prefill batches commit multiple
        admissions at once, which a single-request fault must not strand.
        """
        if kind not in ("decode", "verify"):
            return
        for f in self.plan.faults:
            if f.fired or f.kind not in ("step_error", "nan_logits"):
                continue
            for i in rows:
                slot = engine.sched.slots[i]
                if slot is None or slot.req.rid != f.rid:
                    continue
                n = len(slot.req.generated)
                if f.kind == "step_error" and n >= f.at:
                    self._fire(f)
                    raise RequestFault(f.rid, "step_error")
                if f.kind == "nan_logits" and n == f.at:
                    engine.poison_slot(i)
                    self._fire(f)

    def on_token(self, rid: int, index: int) -> None:
        """After a token is emitted for ``rid`` (its ``index``-th token)."""
        for f in self.plan.faults:
            if f.fired or f.kind != "client_disconnect" or f.rid != rid:
                continue
            if index + 1 >= f.at:
                self._pending_cancels.append(rid)
                self._fire(f)

    def on_detok(self, sleep_fn) -> None:
        """Inside the detokenizer worker, once per token event."""
        self._detok_events += 1
        for f in self.plan.faults:
            if f.fired or f.kind != "detok_stall":
                continue
            if self._detok_events >= f.at:
                self._fire(f)
                sleep_fn(f.stall_s)

    def on_drain(self, engine) -> None:
        self.release_pressure(engine)
