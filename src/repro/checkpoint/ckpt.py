"""Checkpoint / restore with elastic resharding and async save.

Format: one ``.npz`` of flattened leaves (keyed by pytree path) + a JSON
manifest carrying step, data cursor, RNG, and the mesh shape the checkpoint was
taken on.  ``restore`` re-places every leaf with *any* target sharding — a
checkpoint from a 256-chip pod restores onto 512 chips (or a degraded slice),
which is the elasticity story for node failures at scale.  Saves run on a
background thread so the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(path: str, step: int, tree, *, extra: Optional[dict] = None,
         _async: bool = False) -> Optional[threading.Thread]:
    """Atomically write ``<path>/ckpt_<step>``. Returns the thread when async."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

    def _write():
        d = os.path.join(path, f"ckpt_{step}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten_with_paths(host)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in flat.items()})
        manifest = {"step": step, "keys": sorted(flat.keys()),
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.replace(tmp, d)
        _gc(path, keep=3)

    if _async:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(path: str, keep: int):
    steps = sorted(all_steps(path))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"ckpt_{s}"), ignore_errors=True)


def all_steps(path: str):
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        if name.startswith("ckpt_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(path: str) -> Optional[int]:
    steps = all_steps(path)
    return steps[-1] if steps else None


def restore(path: str, like, *, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``like``; re-place with ``shardings``
    (pytree of NamedSharding matching ``like``) for elastic re-meshing."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"ckpt_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(paths_leaves))
    out = []
    for (p, leaf), sh in zip(paths_leaves, shard_leaves):
        key = jax.tree_util.keystr(p)
        arr = arrays[key]
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out), int(manifest["step"]), manifest.get("extra", {})
