from .fault_tolerance import LoopConfig, TrainLoop  # noqa: F401
from .elastic import degraded_mesh, restore_on_mesh  # noqa: F401
