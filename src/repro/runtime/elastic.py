"""Elastic re-meshing: restore a checkpoint onto a different device topology.

At 1000+ nodes the common failure unit is a pod (or a slice of one); recovery
is restarting the job on the surviving/replacement topology.  Because our
checkpoints store *global* arrays keyed by pytree path, restoring onto a new
mesh is just re-placing each leaf with the sharding resolved against that mesh
(``models.shardings.resolve`` handles non-dividing axes by replication)."""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh

from ..checkpoint import ckpt
from ..models import params as params_lib


def restore_on_mesh(path: str, defs, mesh: Optional[Mesh], *,
                    step: Optional[int] = None):
    """Restore a checkpoint of a defs-described pytree onto ``mesh``."""
    like = params_lib.abstract_tree(defs, None)
    like = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), like)
    shardings = params_lib.specs_tree(defs, mesh) if mesh is not None else None
    # restore() needs concrete leaves only for structure; abstract works
    return ckpt.restore(path, like, step=step, shardings=shardings)


def degraded_mesh(original: Mesh, lost_axis: str = "pod") -> dict:
    """Describe the fallback topology after losing one unit of ``lost_axis``
    (used by launch scripts to compute the restart mesh)."""
    shape = dict(zip(original.axis_names, original.devices.shape))
    if lost_axis in shape and shape[lost_axis] > 1:
        shape[lost_axis] -= 1
    return shape
