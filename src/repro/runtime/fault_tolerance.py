"""Fault-tolerant training loop.

Hadoop gave the paper re-execution of failed tasks for free; the SPMD analogue
is (a) frequent async checkpoints, (b) a NaN/inf step guard that skips poisoned
updates (the paper's noisy-data concern, §III-A), and (c) deterministic resume:
after a crash the loop restores the last checkpoint, fast-forwards the data
cursor, and replays the identical stream.  Straggler mitigation lives in the
pipeline prefetch + the hierarchical reduce (see core.mapreduce).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt


@dataclasses.dataclass
class LoopConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    async_save: bool = True
    max_bad_steps: int = 10           # consecutive non-finite steps before abort
    log_every: int = 10
    resume: bool = True


class TrainLoop:
    """Wraps a jitted ``step(state, batch) -> (state, metrics)`` with
    checkpoint/restart + NaN-guard.  ``state`` is any pytree that includes the
    params/optimizer; ``metrics`` must include a scalar 'loss'."""

    def __init__(self, step_fn: Callable, state, data: Iterator,
                 cfg: LoopConfig, *, state_shardings=None,
                 data_state: Optional[Callable] = None):
        self.step_fn = step_fn
        self.state = state
        self.data = data
        self.cfg = cfg
        self.step = 0
        self.bad_streak = 0
        self._pending_save = None
        self.history: list = []
        if cfg.resume and cfg.ckpt_dir and ckpt.latest_step(cfg.ckpt_dir) is not None:
            self.state, self.step, extra = ckpt.restore(
                cfg.ckpt_dir, self.state, shardings=state_shardings)
            print(f"[loop] resumed from step {self.step}")

    def run(self, n_steps: int) -> Dict[str, Any]:
        cfg = self.cfg
        t0 = time.perf_counter()
        it = iter(self.data)
        # deterministic resume: fast-forward the stream to the cursor
        for _ in range(self.step):
            next(it)
        target = self.step + n_steps
        while self.step < target:
            batch = next(it)
            new_state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])
            if not math.isfinite(loss):
                # NaN guard: drop the update, keep counting
                self.bad_streak += 1
                print(f"[loop] step {self.step}: non-finite loss ({loss}); "
                      f"update skipped ({self.bad_streak}/{cfg.max_bad_steps})")
                if self.bad_streak >= cfg.max_bad_steps:
                    raise RuntimeError("too many consecutive non-finite steps")
                self.step += 1
                continue
            self.bad_streak = 0
            self.state = new_state
            self.step += 1
            self.history.append(loss)
            if cfg.log_every and self.step % cfg.log_every == 0:
                dt = time.perf_counter() - t0
                print(f"[loop] step {self.step} loss {loss:.4f} "
                      f"({dt / max(1, len(self.history)):.3f}s/step)")
            if cfg.ckpt_dir and self.step % cfg.ckpt_every == 0:
                self._save()
        if cfg.ckpt_dir:
            self._save()
            if self._pending_save is not None:
                self._pending_save.join()
        return {"final_loss": self.history[-1] if self.history else float("nan"),
                "steps": self.step, "history": self.history}

    def _save(self):
        if self._pending_save is not None:
            self._pending_save.join()    # keep at most one in flight
        self._pending_save = ckpt.save(
            self.cfg.ckpt_dir, self.step, self.state,
            extra={"time": time.time()}, _async=self.cfg.async_save)
