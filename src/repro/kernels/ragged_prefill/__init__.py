"""Fused ragged paged-prefill kernels (vanilla GQA, sliding-window ring,
MLA materialized-K) — the ``pallas`` attention backend's prefill cores."""
from .ops import mla_ragged_prefill_attend, ragged_prefill_attend

__all__ = ["mla_ragged_prefill_attend", "ragged_prefill_attend"]
