"""Jit'd public wrappers for the fused ragged paged-prefill kernels.

On CPU (this container, CI) the kernel bodies execute in interpret mode; on
TPU the same ``pallas_call`` lowers to Mosaic.  The wrappers accept the
model-layout tensors (``q: [B, T, H, D]``, pools ``[P, ps, K, D]`` /
``[P, ps, L]``) and handle the kernel's grouped-query / head-major layouts,
q-block padding, and per-row int32 metadata; see
``src/repro/kernels/README.md`` for the full ragged-prefill contract
(per-row (start, n_live) metadata, masking rules, pre- vs post-write pool
semantics, numerics).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .. import default_interpret
from .kernel import (mla_ragged_prefill_fwd, ragged_prefill_fwd,
                     windowed_ragged_prefill_fwd)


def _pad_q(q, q_blk):
    """Pad the token axis (axis 2 of [B, K/H, T, ...]) to a q_blk multiple.
    Padding rows attend causally-valid garbage and are sliced off."""
    T = q.shape[2]
    pad = (-T) % q_blk
    if pad:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, pad)]
                    + [(0, 0)] * (q.ndim - 3))
    return q, T


@partial(jax.jit, static_argnames=("window", "softcap", "q_blk", "interpret"))
def ragged_prefill_attend(q, k_new, v_new, k_pages, v_pages, tables, start,
                          n_live, *, window: int = 0, softcap: float = 0.0,
                          q_blk: int = 128, k_scale=None, v_scale=None,
                          interpret: bool = None):
    """Ragged chunk-prefill attend against the paged KV pool.

    q: [B, T, H, D] roped chunk queries at per-row offsets ``start`` [B];
    n_live: [B] real chunk tokens.  ``window == 0``: ``k_pages``/``v_pages``
    [P, ps, K, D] are the *post-write* pool (the chunk's K/V are already
    resident; ``k_new``/``v_new`` are ignored).  ``window > 0``: the pool is
    *pre-write*, ``tables`` [B, n_ring] is the page ring, and
    ``k_new``/``v_new`` [B, T, K, D] carry the chunk's fresh roped K/V (T
    must be a page multiple).  Returns [B, T, H, D].  ``k_scale``/
    ``v_scale``: [P, ps, K] bf16 absmax scales when the pool is int8; the
    windowed path's fresh K/V stay at model dtype (only resident ring pages
    are quantized)."""
    B, T, H, D = q.shape
    K = k_pages.shape[2]
    assert H % K == 0, (H, K)
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, K, H // K, D).transpose(0, 2, 1, 3, 4)
    blk = min(q_blk, ((T + 7) // 8) * 8)
    qg, T0 = _pad_q(qg, blk)
    tables = jnp.asarray(tables, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    n_live = jnp.asarray(n_live, jnp.int32)
    if window == 0:
        o = ragged_prefill_fwd(qg, k_pages, v_pages, tables, start, n_live,
                               scale=scale, softcap=softcap, q_blk=blk,
                               k_scale=k_scale, v_scale=v_scale,
                               interpret=default_interpret(interpret))
    else:
        # never round the fresh chunk to the pool dtype: under int8 the pool
        # is quantized but the chunk attends at model precision
        new_dt = k_new.dtype if k_scale is not None else k_pages.dtype
        kn = jnp.asarray(k_new, new_dt)
        vn = jnp.asarray(v_new, new_dt)
        o = windowed_ragged_prefill_fwd(
            qg, kn, vn, k_pages, v_pages, tables, start, n_live,
            window=window, scale=scale, softcap=softcap, q_blk=blk,
            k_scale=k_scale, v_scale=v_scale,
            interpret=default_interpret(interpret))
    return o[:, :, :T0].transpose(0, 2, 1, 3, 4).reshape(B, T0, H, D)


@partial(jax.jit, static_argnames=("nope", "q_blk", "interpret"))
def mla_ragged_prefill_attend(q, ckv_pages, krope_pages, wkv_b, tables, start,
                              n_live, *, nope: int, q_blk: int = 128,
                              ckv_scale=None, krope_scale=None,
                              interpret: bool = None):
    """Ragged MLA chunk-prefill attend against the post-write latent pages.

    q: [B, T, H, nope+rope] (rope part already roped); ckv_pages:
    [P, ps, L]; krope_pages: [P, ps, R]; wkv_b: [L, H, nope + v_head_dim];
    tables: [B, n_pages].  Per-head K/V are materialized page-by-page inside
    the kernel (``ckv @ w_uk`` ++ krope, ``ckv @ w_uv``) with the reference
    einsum's rounding.  Returns [B, T, H, v_head_dim].  ``ckv_scale``/
    ``krope_scale``: [P, ps] bf16 scales when the latent pages are int8."""
    B, T, H, E = q.shape
    scale = 1.0 / math.sqrt(E)
    qg = q.transpose(0, 2, 1, 3)                       # [B, H, T, E]
    blk = min(q_blk, ((T + 7) // 8) * 8)
    qg, T0 = _pad_q(qg, blk)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    o = mla_ragged_prefill_fwd(
        qg, ckv_pages, krope_pages, w_uk, w_uv,
        jnp.asarray(tables, jnp.int32), jnp.asarray(start, jnp.int32),
        jnp.asarray(n_live, jnp.int32), scale=scale, q_blk=blk,
        ckv_scale=ckv_scale, krope_scale=krope_scale,
        interpret=default_interpret(interpret))
    return o[:, :, :T0].transpose(0, 2, 1, 3)
