"""Fused ragged paged-prefill — Pallas TPU kernels.

A chunk-prefill step attends a ragged batch of prompt chunks — every row at
its own offset (``start``), with its own live length — against that row's
paged KV.  The per-row page table rides in as a *scalar-prefetch* operand so
the K/V BlockSpec index maps resolve ``tables[b, j]`` before the body runs
and the pipeline DMAs exactly the physical pages the row owns: the
``pool[tables]`` gather the XLA reference path materializes in HBM never
exists here, and no row pays for another row's prompt length.

Three kernel bodies cover every paged prefill family in
``models.cache_spec``:

* ``_ragged_prefill_kernel`` — vanilla GQA.  The chunk's K/V are already
  resident (scattered before the attend), so the kernel sweeps the row's
  pages with absolute causal masking (``k_abs <= q_abs``); pages wholly past
  the chunk's last query are skipped.
* ``_windowed_ragged_prefill_kernel`` — sliding-window page rings.  The ring
  is read *pre-write* (writing first would recycle slots still holding
  in-window keys of the chunk's earliest queries): ring slots are masked by
  the absolute position recovered from the ring layout relative to
  ``start - 1``, and the chunk's fresh K/V ride in as extra key blocks with
  the causal+window rule.
* ``_mla_ragged_prefill_kernel`` — MLA materialized-K.  Per latent page, the
  per-head K (``ckv @ w_uk`` ++ roped ``krope``) and V (``ckv @ w_uv``) are
  materialized *inside the kernel* — rounded to the cache dtype at exactly
  the point the reference einsum rounds — so the [B, S, H, *] K/V tensors
  the reference path builds in HBM never exist.

Numerics match the reference chunked path's rounding points exactly: fp32
scores (scale after the dot, softcap after scale), one softmax at the true
global max over the row's full key set (a two-phase page sweep — scores
first, probability-weighted values second — rather than an online softmax,
so the probabilities round at the same max as the reference), probabilities
rounded to the value dtype before the PV product, fp32 PV accumulation, one
cast at the block output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params

# the reference mask constant (models.attention.NEG_INF): finite, so a
# fully-masked row softmaxes to the same uniform distribution the reference
# produces instead of NaN
NEG_INF = -1e30


def _store_scores(s_scr, seg, q_abs, s, valid):
    s_scr[:, pl.ds(seg, s.shape[1])] = jnp.where(valid, s, NEG_INF)


def _softmax_rows(s_scr):
    """One softmax over each row's full key set, at the true global max —
    the same formulation (and degenerate all-masked behavior) as
    ``jax.nn.softmax`` in the reference chunked path."""
    s_scr[...] = jax.nn.softmax(s_scr[...], axis=-1)


def _pv_accumulate(acc_scr, s_scr, seg, v, v_dtype):
    """Fold one page of the PV product: probabilities are rounded to the
    value dtype first (the reference's ``a.astype(v.dtype)``), accumulation
    stays fp32."""
    p = s_scr[:, pl.ds(seg, v.shape[0])].astype(v_dtype).astype(jnp.float32)
    acc_scr[...] += jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


# ------------------------------------------------------------- vanilla GQA

def _ragged_prefill_kernel(tables_ref, start_ref, n_live_ref, q_ref, k_ref,
                           v_ref, *rest, page_size: int, n_pages: int,
                           q_blk: int, scale: float, softcap: float, v_dtype,
                           quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, s_scr, acc_scr = rest
    else:
        o_ref, s_scr, acc_scr = rest
    b = pl.program_id(0)
    qb = pl.program_id(2)
    i = pl.program_id(3)
    start = start_ref[b]
    T, G, D = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
    rows = T * G
    j = jnp.where(i < n_pages, i, i - n_pages)
    # absolute query position of each (token, head-group) row
    q_abs = start + qb * q_blk \
        + jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0) // G

    @pl.when(i < n_pages)
    def _():
        k_abs = j * page_size \
            + jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 1)
        # a page wholly past this block's last query is all-causal-masked;
        # skip the dot, the NEG_INF fill is what the reference mask produces
        live_page = j * page_size <= start + qb * q_blk + q_blk - 1

        @pl.when(live_page)
        def _():
            q = q_ref[0, 0].astype(jnp.float32).reshape(rows, D)
            k = k_ref[0, :, 0].astype(jnp.float32)               # [ps, D]
            if quantized:
                k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            _store_scores(s_scr, j * page_size, q_abs, s, k_abs <= q_abs)

        @pl.when(jnp.logical_not(live_page))
        def _():
            s_scr[:, pl.ds(j * page_size, page_size)] = jnp.full(
                (rows, page_size), NEG_INF, jnp.float32)

    @pl.when(i == n_pages - 1)
    def _():
        _softmax_rows(s_scr)

    @pl.when(i == n_pages)
    def _():
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    @pl.when(i >= n_pages)
    def _():
        v = v_ref[0, :, 0].astype(jnp.float32)                   # [ps, D]
        if quantized:
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        _pv_accumulate(acc_scr, s_scr, j * page_size, v, v_dtype)

    @pl.when(i == 2 * n_pages - 1)
    def _():
        o_ref[0, 0] = acc_scr[...].reshape(T, G, D).astype(o_ref.dtype)


def ragged_prefill_fwd(q, k_pages, v_pages, tables, start, n_live, *,
                       scale: float, softcap: float = 0.0, q_blk: int = 128,
                       k_scale=None, v_scale=None, interpret: bool = False):
    """q: [B, K, T, G, D] roped chunk queries (T padded to a q_blk multiple);
    k_pages/v_pages: [P, ps, K, D] *post-write* pool; tables: [B, n_pages]
    int32; start/n_live: [B] int32.  Returns [B, K, T, G, D].
    ``k_scale``/``v_scale``: [P, ps, K] bf16 absmax scales when the pool is
    int8 (the fresh chunk was quantized on write, so every page — prefix and
    chunk alike — dequantizes through the same scale pool)."""
    B, K, T, G, D = q.shape
    ps = k_pages.shape[1]
    n_pages = tables.shape[1]
    n_qb = T // q_blk
    quantized = k_scale is not None
    # probabilities round to the value dtype before PV (the reference's
    # ``a.astype(v.dtype)``); the dequantized values are fp32, so quantized
    # runs keep fp32 probabilities exactly like the reference dequant path
    kernel = functools.partial(
        _ragged_prefill_kernel, page_size=ps, n_pages=n_pages, q_blk=q_blk,
        scale=scale, softcap=softcap,
        v_dtype=jnp.float32 if quantized else v_pages.dtype,
        quantized=quantized)

    def _page_map(b, kh, qb, i, tr, st, nl):
        return (tr[b, jnp.where(i < n_pages, i, i - n_pages)], 0, kh, 0)

    def _scale_map(b, kh, qb, i, tr, st, nl):
        return (tr[b, jnp.where(i < n_pages, i, i - n_pages)], 0, kh)

    in_specs = [
        pl.BlockSpec((1, 1, q_blk, G, D),
                     lambda b, kh, qb, i, tr, st, nl: (b, kh, qb, 0, 0)),
        pl.BlockSpec((1, ps, 1, D), _page_map),
        pl.BlockSpec((1, ps, 1, D), _page_map),
    ]
    operands = [tables, start, n_live, q, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec((1, ps, 1), _scale_map),
                     pl.BlockSpec((1, ps, 1), _scale_map)]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, K, n_qb, 2 * n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, q_blk, G, D),
            lambda b, kh, qb, i, tr, st, nl: (b, kh, qb, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_blk * G, n_pages * ps), jnp.float32),
            pltpu.VMEM((q_blk * G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, T, G, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)


# ------------------------------------------------------ sliding-window ring

def _windowed_ragged_prefill_kernel(tables_ref, start_ref, n_live_ref, q_ref,
                                    kn_ref, vn_ref, k_ref, v_ref, *rest,
                                    page_size: int, n_ring: int, n_fresh: int,
                                    q_blk: int, window: int, scale: float,
                                    softcap: float, v_dtype,
                                    quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, s_scr, acc_scr = rest
    else:
        o_ref, s_scr, acc_scr = rest
    b = pl.program_id(0)
    qb = pl.program_id(2)
    i = pl.program_id(3)
    start = start_ref[b]
    n_live = n_live_ref[b]
    T, G, D = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
    rows = T * G
    n_kv = n_ring + n_fresh
    j = jnp.where(i < n_kv, i, i - n_kv)
    ring_n = n_ring * page_size
    q_abs = start + qb * q_blk \
        + jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0) // G
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 1)

    @pl.when(i < n_kv)
    def _():
        q = q_ref[0, 0].astype(jnp.float32).reshape(rows, D)

        @pl.when(j < n_ring)
        def _():
            # pre-write ring: slot positions recovered relative to start - 1
            # (the last position written before this chunk); start == 0
            # leaves every slot negative, i.e. fully masked
            idx = j * page_size + col
            last = start - 1
            k_abs = last - ((last % ring_n - idx) % ring_n)
            valid = (k_abs >= 0) & (k_abs > q_abs - window)
            k = k_ref[0, :, 0].astype(jnp.float32)
            if quantized:
                # only the resident ring pages are int8; the fresh chunk's
                # K/V below ride in at model dtype
                k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            _store_scores(s_scr, j * page_size, q_abs, s, valid)

        @pl.when(j >= n_ring)
        def _():
            jf = j - n_ring
            k_abs = start + jf * page_size + col
            valid = (k_abs <= q_abs) & (k_abs > q_abs - window) \
                & (jf * page_size + col < n_live)
            k = kn_ref[0, :, 0].astype(jnp.float32)
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            _store_scores(s_scr, j * page_size, q_abs, s, valid)

    @pl.when(i == n_kv - 1)
    def _():
        _softmax_rows(s_scr)

    @pl.when(i == n_kv)
    def _():
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    @pl.when(i >= n_kv)
    def _():
        vr = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            vr = vr * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        vf = vn_ref[0, :, 0].astype(jnp.float32)
        vsel = jnp.where(j < n_ring, vr, vf)
        _pv_accumulate(acc_scr, s_scr, j * page_size, vsel, v_dtype)

    @pl.when(i == 2 * n_kv - 1)
    def _():
        o_ref[0, 0] = acc_scr[...].reshape(T, G, D).astype(o_ref.dtype)


def windowed_ragged_prefill_fwd(q, k_new, v_new, k_pages, v_pages, tables,
                                start, n_live, *, window: int, scale: float,
                                softcap: float = 0.0, q_blk: int = 128,
                                k_scale=None, v_scale=None,
                                interpret: bool = False):
    """q: [B, K, T, G, D]; k_new/v_new: [B, T, K, D] fresh roped chunk K/V
    (T a multiple of the page size); k_pages/v_pages: [P, ps, K, D]
    *pre-write* pool; tables: [B, n_ring] ring tables.  Returns
    [B, K, T, G, D].  ``k_scale``/``v_scale``: [P, ps, K] bf16 scales for
    the int8 ring pages; the fresh chunk stays at model dtype (it is
    quantized only when written back after the attend)."""
    B, K, T, G, D = q.shape
    ps = k_pages.shape[1]
    Tk = k_new.shape[1]                   # fresh K/V length (un-padded chunk)
    assert Tk % ps == 0, (Tk, ps)
    n_ring = tables.shape[1]
    n_fresh = Tk // ps
    n_kv = n_ring + n_fresh
    n_qb = T // q_blk
    quantized = k_scale is not None
    kernel = functools.partial(
        _windowed_ragged_prefill_kernel, page_size=ps, n_ring=n_ring,
        n_fresh=n_fresh, q_blk=q_blk, window=window, scale=scale,
        softcap=softcap,
        v_dtype=jnp.float32 if quantized else v_pages.dtype,
        quantized=quantized)

    def _ring_map(b, kh, qb, i, tr, st, nl):
        j = jnp.where(i < n_kv, i, i - n_kv)
        return (tr[b, jnp.minimum(j, n_ring - 1)], 0, kh, 0)

    def _ring_scale_map(b, kh, qb, i, tr, st, nl):
        j = jnp.where(i < n_kv, i, i - n_kv)
        return (tr[b, jnp.minimum(j, n_ring - 1)], 0, kh)

    def _fresh_map(b, kh, qb, i, tr, st, nl):
        j = jnp.where(i < n_kv, i, i - n_kv)
        return (b, jnp.clip(j - n_ring, 0, n_fresh - 1), kh, 0)

    in_specs = [
        pl.BlockSpec((1, 1, q_blk, G, D),
                     lambda b, kh, qb, i, tr, st, nl: (b, kh, qb, 0, 0)),
        pl.BlockSpec((1, ps, 1, D), _fresh_map),
        pl.BlockSpec((1, ps, 1, D), _fresh_map),
        pl.BlockSpec((1, ps, 1, D), _ring_map),
        pl.BlockSpec((1, ps, 1, D), _ring_map),
    ]
    operands = [tables, start, n_live, q, k_new, v_new, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec((1, ps, 1), _ring_scale_map),
                     pl.BlockSpec((1, ps, 1), _ring_scale_map)]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, K, n_qb, 2 * n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, q_blk, G, D),
            lambda b, kh, qb, i, tr, st, nl: (b, kh, qb, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_blk * G, n_kv * ps), jnp.float32),
            pltpu.VMEM((q_blk * G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, T, G, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)


# ------------------------------------------------------ MLA materialized-K

def _mla_ragged_prefill_kernel(tables_ref, start_ref, n_live_ref, q_ref,
                               ckv_ref, kr_ref, wuk_ref, wuv_ref, *rest,
                               page_size: int, n_pages: int, q_blk: int,
                               scale: float, kv_dtype, quantized: bool):
    if quantized:
        cs_ref, rs_ref, o_ref, s_scr, acc_scr = rest
    else:
        o_ref, s_scr, acc_scr = rest
    b = pl.program_id(0)
    qb = pl.program_id(2)
    i = pl.program_id(3)
    start = start_ref[b]
    T, E = q_ref.shape[2], q_ref.shape[3]
    j = jnp.where(i < n_pages, i, i - n_pages)
    q_abs = start + qb * q_blk \
        + jax.lax.broadcasted_iota(jnp.int32, (T, page_size), 0)

    @pl.when(i < n_pages)
    def _():
        k_abs = j * page_size \
            + jax.lax.broadcasted_iota(jnp.int32, (T, page_size), 1)
        live_page = j * page_size <= start + qb * q_blk + q_blk - 1

        @pl.when(live_page)
        def _():
            ckv = ckv_ref[0].astype(jnp.float32)                 # [ps, L]
            kr = kr_ref[0].astype(jnp.float32)                   # [ps, R]
            if quantized:
                ckv = ckv * cs_ref[0].astype(jnp.float32)[:, None]
                kr = kr * rs_ref[0].astype(jnp.float32)[:, None]
            wuk = wuk_ref[:, 0].astype(jnp.float32)              # [L, nope]
            # materialize this page's per-head K, rounded to the cache dtype
            # exactly where the reference ``ckv @ wkv_b`` einsum rounds
            k_nope = jax.lax.dot_general(
                ckv, wuk, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(kv_dtype)
            k = jnp.concatenate([k_nope.astype(jnp.float32), kr], axis=-1)
            q = q_ref[0, 0].astype(jnp.float32)                  # [T, E]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
            _store_scores(s_scr, j * page_size, q_abs, s, k_abs <= q_abs)

        @pl.when(jnp.logical_not(live_page))
        def _():
            s_scr[:, pl.ds(j * page_size, page_size)] = jnp.full(
                (T, page_size), NEG_INF, jnp.float32)

    @pl.when(i == n_pages - 1)
    def _():
        _softmax_rows(s_scr)

    @pl.when(i == n_pages)
    def _():
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    @pl.when(i >= n_pages)
    def _():
        ckv = ckv_ref[0].astype(jnp.float32)
        if quantized:
            ckv = ckv * cs_ref[0].astype(jnp.float32)[:, None]
        wuv = wuv_ref[:, 0].astype(jnp.float32)                  # [L, vd]
        v = jax.lax.dot_general(
            ckv, wuv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(kv_dtype)
        _pv_accumulate(acc_scr, s_scr, j * page_size,
                       v.astype(jnp.float32), kv_dtype)

    @pl.when(i == 2 * n_pages - 1)
    def _():
        o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)


def mla_ragged_prefill_fwd(q, ckv_pages, krope_pages, w_uk, w_uv, tables,
                           start, n_live, *, scale: float, q_blk: int = 128,
                           ckv_scale=None, krope_scale=None,
                           interpret: bool = False):
    """q: [B, H, T, nope+rope] (rope part roped); ckv_pages: [P, ps, L];
    krope_pages: [P, ps, R]; w_uk: [L, H, nope]; w_uv: [L, H, vd]; tables:
    [B, n_pages].  Returns the attended values [B, H, T, vd].
    ``ckv_scale``/``krope_scale``: [P, ps] bf16 scales when the latent pages
    are int8 — the dequantized latent is fp32, so the in-kernel K/V
    materialization stays fp32 (``kv_dtype``) exactly like the reference
    dequant einsum."""
    B, H, T, E = q.shape
    L = ckv_pages.shape[2]
    vd = w_uv.shape[2]
    ps = ckv_pages.shape[1]
    n_pages = tables.shape[1]
    n_qb = T // q_blk
    quantized = ckv_scale is not None
    kernel = functools.partial(
        _mla_ragged_prefill_kernel, page_size=ps, n_pages=n_pages,
        q_blk=q_blk, scale=scale,
        kv_dtype=jnp.float32 if quantized else ckv_pages.dtype,
        quantized=quantized)

    def _page_map(b, h, qb, i, tr, st, nl):
        return (tr[b, jnp.where(i < n_pages, i, i - n_pages)], 0, 0)

    def _scale_map(b, h, qb, i, tr, st, nl):
        return (tr[b, jnp.where(i < n_pages, i, i - n_pages)], 0)

    in_specs = [
        pl.BlockSpec((1, 1, q_blk, E),
                     lambda b, h, qb, i, tr, st, nl: (b, h, qb, 0)),
        pl.BlockSpec((1, ps, L), _page_map),
        pl.BlockSpec((1, ps, krope_pages.shape[2]), _page_map),
        pl.BlockSpec((L, 1, w_uk.shape[2]),
                     lambda b, h, qb, i, tr, st, nl: (0, h, 0)),
        pl.BlockSpec((L, 1, vd),
                     lambda b, h, qb, i, tr, st, nl: (0, h, 0)),
    ]
    operands = [tables, start, n_live, q, ckv_pages, krope_pages, w_uk, w_uv]
    if quantized:
        in_specs += [pl.BlockSpec((1, ps), _scale_map),
                     pl.BlockSpec((1, ps), _scale_map)]
        operands += [ckv_scale, krope_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, H, n_qb, 2 * n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, q_blk, vd),
            lambda b, h, qb, i, tr, st, nl: (b, h, qb, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_blk, n_pages * ps), jnp.float32),
            pltpu.VMEM((q_blk, vd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, vd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)
