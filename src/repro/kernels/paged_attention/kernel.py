"""Fused paged-attention decode — Pallas TPU kernels.

One decode step reads every live token of a request's KV straight out of the
paged pool: the per-request page table rides in as a *scalar-prefetch*
operand, so the K/V BlockSpec index maps resolve ``tables[b, i]`` before the
body runs and the pipeline DMAs exactly the physical pages the request owns —
the ``pool[tables]`` gather that the XLA reference path materializes in HBM
never exists here.  This is the TPU-native shape of vLLM/SGLang
PagedAttention: walk the page table, attend in place.

Two kernel bodies cover every paged decode family in ``models.cache_spec``:

* ``_paged_decode_kernel`` — vanilla GQA (mask ``idx <= pos``) and
  sliding-window page *rings* (``window > 0``: absolute positions are
  recovered from the ring layout and masked to the window, exactly the
  reference ring rule).  Grid ``(B, K, n_pages)``; the innermost dimension
  sweeps the request's pages with online-softmax state (running max ``m``,
  normalizer ``l``, accumulator ``acc``) in fp32 VMEM scratch.  GQA never
  replicates KV: the q block is the ``G = H // K`` head group of one KV head.
* ``_mla_paged_decode_kernel`` — DeepSeek-style absorbed-latent decode.
  Scores are ``q_eff·ckv + q_rope·krope`` against the rank-``L`` latent pages
  (one shared "KV head"); the context accumulator stays in latent space
  (``acc += p·ckv``) so the kernel's output is the ``[H, L]`` context that the
  caller up-projects with ``w_uv`` — per-head K/V are never materialized.

Pages whose first token already lies past ``pos`` are skipped via ``pl.when``
(a null-page read would be masked anyway, but skipping saves the DMA wait);
fully-masked pages are absorbed by the -inf-guarded online-softmax update.

Each decode body has a small-q *verify* twin (``_paged_verify_kernel`` /
``_mla_paged_verify_kernel``) for speculative decoding: the q block carries
``Q = 1 + K`` query tokens per row (last emitted token + draft), a third
scalar-prefetch operand ``n_q`` gives each row's live query count, and the
mask becomes per-query causal — query ``j`` sits at absolute position
``pos + j``, so flattened row ``j*G + g`` runs exactly the decode body's ops
at that position and ``Q == 1`` reproduces the decode kernel bit-for-bit.
Dead rows (``j >= n_q``) stay fully masked and finish as exact zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params

NEG_INF = float("-inf")


def _online_softmax_update(s, v, m_scr, l_scr, acc_scr):
    """Fold one masked score block ``s`` ([rows, ps]) and its values ``v``
    ([ps, d]) into the running (m, l, acc) scratch state."""
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # guard fully-masked rows (m_new == -inf)
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - safe_m[:, None])
    p = jnp.where(jnp.isfinite(m_new)[:, None], p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _finish(o_ref, m_scr, l_scr, acc_scr):
    o_ref[0, 0] = (acc_scr[...]
                   / jnp.maximum(l_scr[...], 1e-20)[:, None]).astype(o_ref.dtype)


def _init(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
    l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
    acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)


def _page_mask(s, page_idx, pos, *, page_size, window, ring):
    """Validity of the ``page_size`` token slots of page ``page_idx`` against
    absolute position ``pos`` — the decode masking contract (see
    kernels/README.md): causal ``idx <= pos`` when ``window == 0``, else the
    ring rule recovering each slot's absolute position from the ring layout."""
    idx = page_idx * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if window == 0:
        return idx <= pos
    slot = pos % ring
    k_abs = pos - ((slot - idx) % ring)
    return (k_abs >= 0) & (k_abs <= pos) & (k_abs > pos - window)


def _paged_decode_kernel(tables_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                         page_size: int, scale: float, softcap: float,
                         window: int, ring: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _():
        _init(m_scr, l_scr, acc_scr)

    pos = pos_ref[b]
    # vanilla: pages strictly past pos hold no valid token yet; ring: every
    # resident page can hold in-window tokens, sweep them all
    live = (i * page_size <= pos) if window == 0 else (i * page_size < ring)

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)                  # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)               # [ps, D]
        v = v_ref[0, :, 0].astype(jnp.float32)               # [ps, D]
        if quantized:
            # in-register dequant: f32(q8) * f32(bf16 per-token scale) — the
            # HBM gather above moved int8, half the bf16 bytes
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        # scale after the dot, the reference ordering, so the two backends'
        # fp32 scores round identically
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        valid = _page_mask(s, i, pos, page_size=page_size, window=window,
                           ring=ring)
        _online_softmax_update(jnp.where(valid, s, NEG_INF), v,
                               m_scr, l_scr, acc_scr)

    @pl.when(i == pl.num_programs(2) - 1)
    def _():
        _finish(o_ref, m_scr, l_scr, acc_scr)


def paged_decode_fwd(q, k_pages, v_pages, tables, pos, *, scale: float,
                     softcap: float = 0.0, window: int = 0,
                     k_scale=None, v_scale=None, interpret: bool = False):
    """q: [B, K, G, D]; k_pages/v_pages: [P, ps, K, D]; tables: [B, n_pages]
    int32 physical page ids; pos: [B] int32 absolute positions.  Returns
    [B, K, G, D].  ``window > 0`` treats the table as a page ring of
    ``n_pages * ps`` token slots.  ``k_scale``/``v_scale``: [P, ps, K] bf16
    per-token-per-head absmax scales when the pool is int8-quantized — the
    kernel dequantizes in-register after the page DMA."""
    B, K, G, D = q.shape
    ps = k_pages.shape[1]
    n_pages = tables.shape[1]
    quantized = k_scale is not None
    kernel = functools.partial(
        _paged_decode_kernel, page_size=ps, scale=scale, softcap=softcap,
        window=window, ring=n_pages * ps, quantized=quantized)
    page_spec = pl.BlockSpec((1, ps, 1, D),
                             lambda b, kh, i, tr, pr: (tr[b, i], 0, kh, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, kh, i, tr, pr: (b, kh, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [tables, pos, q, k_pages, v_pages]
    if quantized:
        scale_spec = pl.BlockSpec((1, ps, 1),
                                  lambda b, kh, i, tr, pr: (tr[b, i], 0, kh))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, kh, i, tr, pr: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def _paged_verify_kernel(tables_ref, pos_ref, nq_ref, q_ref, k_ref, v_ref,
                         *rest, page_size: int, scale: float, softcap: float,
                         window: int, ring: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _():
        _init(m_scr, l_scr, acc_scr)

    pos = pos_ref[b]
    n_q = nq_ref[b]
    # vanilla: pages strictly past the last live query's position hold no
    # attendable token; ring: every resident page can hold in-window tokens
    live = (i * page_size <= pos + n_q - 1) if window == 0 \
        else (i * page_size < ring)

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)                  # [Q, G, D]
        Q, G, D = q.shape
        q = q.reshape(Q * G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)               # [ps, D]
        v = v_ref[0, :, 0].astype(jnp.float32)               # [ps, D]
        if quantized:
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        # flattened row j*G + g is query j of head group g, at absolute
        # position pos + j — the decode mask evaluated per row
        qi = jax.lax.broadcasted_iota(jnp.int32, (Q, G), 0).reshape(Q * G, 1)
        valid = _page_mask(s, i, pos + qi, page_size=page_size,
                           window=window, ring=ring)
        valid = valid & (qi < n_q)
        _online_softmax_update(jnp.where(valid, s, NEG_INF), v,
                               m_scr, l_scr, acc_scr)

    @pl.when(i == pl.num_programs(2) - 1)
    def _():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-20)[:, None]).reshape(
                           o_ref.shape[2:]).astype(o_ref.dtype)


def paged_verify_fwd(q, k_pages, v_pages, tables, pos, n_q, *, scale: float,
                     softcap: float = 0.0, window: int = 0,
                     k_scale=None, v_scale=None, interpret: bool = False):
    """Small-q speculative verify: q [B, K, Q, G, D] — per row the last
    emitted token plus its draft, padded to Q; pos [B] base positions; n_q
    [B] live query counts (1 + draft length).  Same page-table / ring /
    int8-scale contract as ``paged_decode_fwd``; pages are swept once per
    row with all Q queries' masks evaluated against them.  Returns
    [B, K, Q, G, D]; dead query rows (j >= n_q) are exact zeros."""
    B, K, Q, G, D = q.shape
    ps = k_pages.shape[1]
    n_pages = tables.shape[1]
    quantized = k_scale is not None
    kernel = functools.partial(
        _paged_verify_kernel, page_size=ps, scale=scale, softcap=softcap,
        window=window, ring=n_pages * ps, quantized=quantized)
    page_spec = pl.BlockSpec(
        (1, ps, 1, D), lambda b, kh, i, tr, pr, nr: (tr[b, i], 0, kh, 0))
    in_specs = [
        pl.BlockSpec((1, 1, Q, G, D),
                     lambda b, kh, i, tr, pr, nr: (b, kh, 0, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [tables, pos, n_q, q, k_pages, v_pages]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, ps, 1), lambda b, kh, i, tr, pr, nr: (tr[b, i], 0, kh))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, K, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Q, G, D),
                               lambda b, kh, i, tr, pr, nr: (b, kh, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Q * G,), jnp.float32),
            pltpu.VMEM((Q * G,), jnp.float32),
            pltpu.VMEM((Q * G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Q, G, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def _mla_paged_decode_kernel(tables_ref, pos_ref, q_eff_ref, q_rope_ref,
                             ckv_ref, krope_ref, *rest, page_size: int,
                             scale: float, quantized: bool):
    if quantized:
        cs_ref, rs_ref, ctx_ref, m_scr, l_scr, acc_scr = rest
    else:
        ctx_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _():
        _init(m_scr, l_scr, acc_scr)

    pos = pos_ref[b]

    @pl.when(i * page_size <= pos)
    def _():
        qe = q_eff_ref[0].astype(jnp.float32)                # [H, L]
        qr = q_rope_ref[0].astype(jnp.float32)               # [H, R]
        ckv = ckv_ref[0].astype(jnp.float32)                 # [ps, L]
        kr = krope_ref[0].astype(jnp.float32)                # [ps, R]
        if quantized:
            # one scale per latent token slot (the latent vector is the
            # quantization granule); dequantized ckv also feeds the latent
            # accumulator below, so context picks up the scales too
            ckv = ckv * cs_ref[0].astype(jnp.float32)[:, None]
            kr = kr * rs_ref[0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(qe, ckv, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        s = s * scale                                        # [H, ps]
        valid = _page_mask(s, i, pos, page_size=page_size, window=0, ring=0)
        # context accumulates in latent space: acc += p @ ckv  -> [H, L]
        _online_softmax_update(jnp.where(valid, s, NEG_INF), ckv,
                               m_scr, l_scr, acc_scr)

    @pl.when(i == pl.num_programs(1) - 1)
    def _():
        ctx_ref[0] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-20)[:, None]).astype(
                          ctx_ref.dtype)


def mla_paged_decode_fwd(q_eff, q_rope, ckv_pages, krope_pages, tables, pos,
                         *, scale: float, ckv_scale=None, krope_scale=None,
                         interpret: bool = False):
    """q_eff: [B, H, L] (w_uk-absorbed queries); q_rope: [B, H, R];
    ckv_pages: [P, ps, L]; krope_pages: [P, ps, R]; tables: [B, n_pages];
    pos: [B].  Returns the latent context [B, H, L].  ``ckv_scale``/
    ``krope_scale``: [P, ps] bf16 per-token absmax scales when the latent
    pages are int8-quantized."""
    B, H, L = q_eff.shape
    R = q_rope.shape[-1]
    ps = ckv_pages.shape[1]
    n_pages = tables.shape[1]
    quantized = ckv_scale is not None
    kernel = functools.partial(_mla_paged_decode_kernel, page_size=ps,
                               scale=scale, quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, H, L), lambda b, i, tr, pr: (b, 0, 0)),
        pl.BlockSpec((1, H, R), lambda b, i, tr, pr: (b, 0, 0)),
        pl.BlockSpec((1, ps, L), lambda b, i, tr, pr: (tr[b, i], 0, 0)),
        pl.BlockSpec((1, ps, R), lambda b, i, tr, pr: (tr[b, i], 0, 0)),
    ]
    operands = [tables, pos, q_eff, q_rope, ckv_pages, krope_pages]
    if quantized:
        scale_spec = pl.BlockSpec((1, ps), lambda b, i, tr, pr: (tr[b, i], 0))
        in_specs += [scale_spec, scale_spec]
        operands += [ckv_scale, krope_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, L), lambda b, i, tr, pr: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, L), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, L), q_eff.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def _mla_paged_verify_kernel(tables_ref, pos_ref, nq_ref, q_eff_ref,
                             q_rope_ref, ckv_ref, krope_ref, *rest,
                             page_size: int, scale: float, quantized: bool):
    if quantized:
        cs_ref, rs_ref, ctx_ref, m_scr, l_scr, acc_scr = rest
    else:
        ctx_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _():
        _init(m_scr, l_scr, acc_scr)

    pos = pos_ref[b]
    n_q = nq_ref[b]

    @pl.when(i * page_size <= pos + n_q - 1)
    def _():
        qe = q_eff_ref[0].astype(jnp.float32)                # [Q, H, L]
        Q, H, L = qe.shape
        qe = qe.reshape(Q * H, L)
        qr = q_rope_ref[0].astype(jnp.float32).reshape(Q * H, -1)
        ckv = ckv_ref[0].astype(jnp.float32)                 # [ps, L]
        kr = krope_ref[0].astype(jnp.float32)                # [ps, R]
        if quantized:
            ckv = ckv * cs_ref[0].astype(jnp.float32)[:, None]
            kr = kr * rs_ref[0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(qe, ckv, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        s = s * scale                                        # [Q*H, ps]
        qi = jax.lax.broadcasted_iota(jnp.int32, (Q, H), 0).reshape(Q * H, 1)
        valid = _page_mask(s, i, pos + qi, page_size=page_size, window=0,
                           ring=0)
        valid = valid & (qi < n_q)
        _online_softmax_update(jnp.where(valid, s, NEG_INF), ckv,
                               m_scr, l_scr, acc_scr)

    @pl.when(i == pl.num_programs(1) - 1)
    def _():
        ctx_ref[0] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-20)[:, None]).reshape(
                          ctx_ref.shape[1:]).astype(ctx_ref.dtype)


def mla_paged_verify_fwd(q_eff, q_rope, ckv_pages, krope_pages, tables, pos,
                         n_q, *, scale: float, ckv_scale=None,
                         krope_scale=None, interpret: bool = False):
    """Small-q absorbed-latent verify: q_eff [B, Q, H, L] / q_rope
    [B, Q, H, R] against the latent pages, with pos/n_q as in
    ``paged_verify_fwd``.  Returns the latent context [B, Q, H, L]; dead
    query rows are exact zeros."""
    B, Q, H, L = q_eff.shape
    R = q_rope.shape[-1]
    ps = ckv_pages.shape[1]
    n_pages = tables.shape[1]
    quantized = ckv_scale is not None
    kernel = functools.partial(_mla_paged_verify_kernel, page_size=ps,
                               scale=scale, quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, Q, H, L), lambda b, i, tr, pr, nr: (b, 0, 0, 0)),
        pl.BlockSpec((1, Q, H, R), lambda b, i, tr, pr, nr: (b, 0, 0, 0)),
        pl.BlockSpec((1, ps, L), lambda b, i, tr, pr, nr: (tr[b, i], 0, 0)),
        pl.BlockSpec((1, ps, R), lambda b, i, tr, pr, nr: (tr[b, i], 0, 0)),
    ]
    operands = [tables, pos, n_q, q_eff, q_rope, ckv_pages, krope_pages]
    if quantized:
        scale_spec = pl.BlockSpec((1, ps),
                                  lambda b, i, tr, pr, nr: (tr[b, i], 0))
        in_specs += [scale_spec, scale_spec]
        operands += [ckv_scale, krope_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Q, H, L),
                               lambda b, i, tr, pr, nr: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Q * H,), jnp.float32),
            pltpu.VMEM((Q * H,), jnp.float32),
            pltpu.VMEM((Q * H, L), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Q, H, L), q_eff.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
