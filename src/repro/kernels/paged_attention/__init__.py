"""Fused paged-attention decode kernels (Pallas TPU, interpret on CPU).

The parity oracle is the ``reference`` attention backend
(``repro.models.attn_backend``) — today's gather+attend XLA code — which the
``pallas`` backend must match token-for-token under greedy decode.  The
``*_verify`` entry points are the small-q speculative-decoding twins of the
decode kernels (Q = 1 + K queries per row, per-query causal mask).
"""
from .ops import (mla_paged_attention_decode, mla_paged_attention_verify,
                  paged_attention_decode, paged_attention_verify)

__all__ = ["paged_attention_decode", "mla_paged_attention_decode",
           "paged_attention_verify", "mla_paged_attention_verify"]
