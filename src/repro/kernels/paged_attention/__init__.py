"""Fused paged-attention decode kernels (Pallas TPU, interpret on CPU).

The parity oracle is the ``reference`` attention backend
(``repro.models.attn_backend``) — today's gather+attend XLA code — which the
``pallas`` backend must match token-for-token under greedy decode.
"""
from .ops import mla_paged_attention_decode, paged_attention_decode

__all__ = ["paged_attention_decode", "mla_paged_attention_decode"]
