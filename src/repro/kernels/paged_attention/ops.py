"""Jit'd public wrappers for the fused paged-attention decode kernels.

On CPU (this container, CI) the kernel bodies execute in interpret mode; on
TPU the same ``pallas_call`` lowers to Mosaic.  The wrappers accept the
model-layout tensors (``q: [B, H, D]``, pools ``[P, ps, K, D]`` /
``[P, ps, L]``) and handle the kernel's grouped-query ``[B, K, G, D]``
layout; see ``src/repro/kernels/README.md`` for the full backend contract
(page-table layout, masking rules, null-page semantics).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import default_interpret
from .kernel import mla_paged_decode_fwd, paged_decode_fwd


@partial(jax.jit, static_argnames=("scale", "softcap", "window", "interpret"))
def paged_attention_decode(q, k_pages, v_pages, tables, pos, *, scale: float,
                           softcap: float = 0.0, window: int = 0,
                           k_scale=None, v_scale=None, interpret: bool = None):
    """One-token GQA decode against the paged KV pool.

    q: [B, H, D] (the step's roped queries, new token already written to its
    page); k_pages/v_pages: [P, ps, K, D] with H % K == 0; tables: [B,
    n_pages] int32 physical page ids (a ring of ``n_pages`` pages when
    ``window > 0``); pos: [B] int32 absolute positions.  Returns [B, H, D].
    When the pool is int8, ``k_scale``/``v_scale`` carry the [P, ps, K] bf16
    absmax scales and the kernel dequantizes in-register.
    """
    B, H, D = q.shape
    K = k_pages.shape[2]
    assert H % K == 0, (H, K)
    qg = q.reshape(B, K, H // K, D)
    o = paged_decode_fwd(qg, k_pages, v_pages,
                         jnp.asarray(tables, jnp.int32),
                         jnp.asarray(pos, jnp.int32), scale=scale,
                         softcap=softcap, window=window,
                         k_scale=k_scale, v_scale=v_scale,
                         interpret=default_interpret(interpret))
    return o.reshape(B, H, D)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def mla_paged_attention_decode(q_eff, q_rope, ckv_pages, krope_pages, tables,
                               pos, *, scale: float, ckv_scale=None,
                               krope_scale=None, interpret: bool = None):
    """One-token absorbed-latent MLA decode against the latent pages.

    q_eff: [B, H, L] (``w_uk``-absorbed queries); q_rope: [B, H, R] (roped);
    ckv_pages: [P, ps, L]; krope_pages: [P, ps, R]; tables: [B, n_pages];
    pos: [B].  Returns the latent context [B, H, L] — the caller up-projects
    it with ``w_uv``.  ``ckv_scale``/``krope_scale``: [P, ps] bf16 scales
    when the latent pages are int8-quantized."""
    return mla_paged_decode_fwd(q_eff, q_rope, ckv_pages, krope_pages,
                                jnp.asarray(tables, jnp.int32),
                                jnp.asarray(pos, jnp.int32), scale=scale,
                                ckv_scale=ckv_scale, krope_scale=krope_scale,
                                interpret=default_interpret(interpret))
