"""Jit'd public wrappers for the fused paged-attention decode kernels.

On CPU (this container, CI) the kernel bodies execute in interpret mode; on
TPU the same ``pallas_call`` lowers to Mosaic.  The wrappers accept the
model-layout tensors (``q: [B, H, D]``, pools ``[P, ps, K, D]`` /
``[P, ps, L]``) and handle the kernel's grouped-query ``[B, K, G, D]``
layout; see ``src/repro/kernels/README.md`` for the full backend contract
(page-table layout, masking rules, null-page semantics).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import default_interpret
from .kernel import (mla_paged_decode_fwd, mla_paged_verify_fwd,
                     paged_decode_fwd, paged_verify_fwd)


@partial(jax.jit, static_argnames=("scale", "softcap", "window", "interpret"))
def paged_attention_decode(q, k_pages, v_pages, tables, pos, *, scale: float,
                           softcap: float = 0.0, window: int = 0,
                           k_scale=None, v_scale=None, interpret: bool = None):
    """One-token GQA decode against the paged KV pool.

    q: [B, H, D] (the step's roped queries, new token already written to its
    page); k_pages/v_pages: [P, ps, K, D] with H % K == 0; tables: [B,
    n_pages] int32 physical page ids (a ring of ``n_pages`` pages when
    ``window > 0``); pos: [B] int32 absolute positions.  Returns [B, H, D].
    When the pool is int8, ``k_scale``/``v_scale`` carry the [P, ps, K] bf16
    absmax scales and the kernel dequantizes in-register.
    """
    B, H, D = q.shape
    K = k_pages.shape[2]
    assert H % K == 0, (H, K)
    qg = q.reshape(B, K, H // K, D)
    o = paged_decode_fwd(qg, k_pages, v_pages,
                         jnp.asarray(tables, jnp.int32),
                         jnp.asarray(pos, jnp.int32), scale=scale,
                         softcap=softcap, window=window,
                         k_scale=k_scale, v_scale=v_scale,
                         interpret=default_interpret(interpret))
    return o.reshape(B, H, D)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def mla_paged_attention_decode(q_eff, q_rope, ckv_pages, krope_pages, tables,
                               pos, *, scale: float, ckv_scale=None,
                               krope_scale=None, interpret: bool = None):
    """One-token absorbed-latent MLA decode against the latent pages.

    q_eff: [B, H, L] (``w_uk``-absorbed queries); q_rope: [B, H, R] (roped);
    ckv_pages: [P, ps, L]; krope_pages: [P, ps, R]; tables: [B, n_pages];
    pos: [B].  Returns the latent context [B, H, L] — the caller up-projects
    it with ``w_uv``.  ``ckv_scale``/``krope_scale``: [P, ps] bf16 scales
    when the latent pages are int8-quantized."""
    return mla_paged_decode_fwd(q_eff, q_rope, ckv_pages, krope_pages,
                                jnp.asarray(tables, jnp.int32),
                                jnp.asarray(pos, jnp.int32), scale=scale,
                                ckv_scale=ckv_scale, krope_scale=krope_scale,
                                interpret=default_interpret(interpret))


@partial(jax.jit, static_argnames=("scale", "softcap", "window", "interpret"))
def paged_attention_verify(q, k_pages, v_pages, tables, pos, n_q, *,
                           scale: float, softcap: float = 0.0,
                           window: int = 0, k_scale=None, v_scale=None,
                           interpret: bool = None):
    """Small-q GQA verify against the paged KV pool (speculative decoding).

    q: [B, Q, H, D] — per row the last emitted token plus its draft, roped
    at positions ``pos + j`` and already written to their pages; pos: [B]
    base positions; n_q: [B] live query counts (1 + draft length).  Pool /
    table / ring / int8-scale layout as ``paged_attention_decode``.  Returns
    [B, Q, H, D]; dead query rows (j >= n_q) are exact zeros."""
    B, Q, H, D = q.shape
    K = k_pages.shape[2]
    assert H % K == 0, (H, K)
    qg = q.reshape(B, Q, K, H // K, D).transpose(0, 2, 1, 3, 4)
    o = paged_verify_fwd(qg, k_pages, v_pages,
                         jnp.asarray(tables, jnp.int32),
                         jnp.asarray(pos, jnp.int32),
                         jnp.asarray(n_q, jnp.int32), scale=scale,
                         softcap=softcap, window=window,
                         k_scale=k_scale, v_scale=v_scale,
                         interpret=default_interpret(interpret))
    return o.transpose(0, 2, 1, 3, 4).reshape(B, Q, H, D)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def mla_paged_attention_verify(q_eff, q_rope, ckv_pages, krope_pages, tables,
                               pos, n_q, *, scale: float, ckv_scale=None,
                               krope_scale=None, interpret: bool = None):
    """Small-q absorbed-latent MLA verify against the latent pages.

    q_eff: [B, Q, H, L]; q_rope: [B, Q, H, R]; pos/n_q as in
    ``paged_attention_verify``.  Returns the latent context [B, Q, H, L]
    (dead query rows exact zeros) — the caller up-projects with ``w_uv``."""
    return mla_paged_verify_fwd(q_eff, q_rope, ckv_pages, krope_pages,
                                jnp.asarray(tables, jnp.int32),
                                jnp.asarray(pos, jnp.int32),
                                jnp.asarray(n_q, jnp.int32), scale=scale,
                                ckv_scale=ckv_scale,
                                krope_scale=krope_scale,
                                interpret=default_interpret(interpret))
