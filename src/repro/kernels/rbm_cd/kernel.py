"""Fused GEMM + bias + sigmoid — the RBM CD hot loop as a Pallas TPU kernel.

The paper's mapper spends its time in ``sigmoid(v @ W + b)`` (positive phase)
and the transposed GEMM of the negative phase.  On TPU the win is fusing the
bias+sigmoid epilogue into the blocked matmul so hidden probabilities never
round-trip to HBM in fp32: the kernel tiles (M, N, K) into MXU-aligned VMEM
blocks, accumulates in fp32 scratch over the K ("arbitrary") grid dimension,
and applies the epilogue on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params


def _gemm_sigmoid_kernel(x_ref, w_ref, b_ref, o_ref, acc_scr):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        z = acc_scr[...] + b_ref[...].astype(jnp.float32)[None, :]
        o_ref[...] = jax.nn.sigmoid(z).astype(o_ref.dtype)


def gemm_sigmoid_fwd(x: jax.Array, w: jax.Array, b: jax.Array, *,
                     block_m: int = 128, block_n: int = 128, block_k: int = 128,
                     interpret: bool = False) -> jax.Array:
    """sigmoid(x @ w + b).  x: [M, K]; w: [K, N]; b: [N]."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and b.shape == (N,)
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    pm, pn, pk = (-M) % block_m, (-N) % block_n, (-K) % block_k
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pn:
        b = jnp.pad(b, (0, pn))
    Mp, Kp = x.shape
    Np = w.shape[1]
    grid = (Mp // block_m, Np // block_n, Kp // block_k)
    out = pl.pallas_call(
        _gemm_sigmoid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((block_n,), lambda mi, ni, ki: (ni,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, b)
    return out[:M, :N]
