"""Pure-jnp oracle for the fused RBM GEMM+sigmoid kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_sigmoid_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    z = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    return jax.nn.sigmoid(z).astype(x.dtype)
