from .ops import gemm_sigmoid  # noqa: F401
from .ref import gemm_sigmoid_ref  # noqa: F401
