"""Jit'd wrapper: fused hidden/visible-probability GEMM for RBM CD."""
from __future__ import annotations

from functools import partial

import jax

from .. import default_interpret
from .kernel import gemm_sigmoid_fwd


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def gemm_sigmoid(x, w, b, *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128, interpret: bool = None):
    return gemm_sigmoid_fwd(x, w, b, block_m=block_m, block_n=block_n,
                            block_k=block_k,
                            interpret=default_interpret(interpret))
