"""Jit'd wrapper: fused hidden/visible-probability GEMM for RBM CD."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import gemm_sigmoid_fwd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def gemm_sigmoid(x, w, b, *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128, interpret: bool = None):
    if interpret is None:
        interpret = _on_cpu()
    return gemm_sigmoid_fwd(x, w, b, block_m=block_m, block_n=block_n,
                            block_k=block_k, interpret=interpret)
