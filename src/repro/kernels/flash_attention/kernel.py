"""Causal GQA flash attention — Pallas TPU kernel.

TPU-native adaptation (see DESIGN.md §7): instead of a CUDA warp-level softmax,
the kernel tiles Q into ``block_q`` x ``head_dim`` VMEM blocks (MXU-aligned,
multiples of 128 recommended), streams K/V in ``block_k`` tiles along the
innermost ("arbitrary") grid dimension, and keeps the online-softmax state
(running max ``m``, normalizer ``l``, accumulator ``acc``) in fp32 VMEM scratch
across the K sweep.  GQA is expressed in the BlockSpec index maps: the K/V
block index maps divide the query-head index by the group size, so no KV
replication is materialized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import tpu_compiler_params

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    # with causal masking, blocks strictly above the diagonal contribute nothing
    q_lo = qi * block_q
    k_lo = ki * block_k
    run = (not causal) or (k_lo <= q_lo + block_q - 1)

    @pl.when(k_lo <= q_lo + block_q - 1 if causal else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)                  # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq, bk]
        if causal:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (m_new == -inf)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[:, None])
        p = jnp.where(jnp.isfinite(m_new)[:, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-20)[:, None]).astype(
            o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: [B, H, S, D]; k, v: [B, K, S, D] with H % K == 0. Returns [B, H, S, D]."""
    B, H, S, D = q.shape
    K = k.shape[1]
    assert H % K == 0, (H, K)
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    grid = (B, H, nq, nk)
    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, causal=causal, seq_len=S)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
