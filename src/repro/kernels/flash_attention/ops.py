"""Jit'd public wrapper for the flash attention kernel.

On CPU (this container) the kernel body executes in interpret mode; on TPU the
same ``pallas_call`` lowers to Mosaic.  The wrapper accepts the model-layout
tensors ([B, S, H, D]) and handles the kernel's [B, H, S, D] layout.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import default_interpret
from .kernel import flash_attention_fwd


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = None):
    """q: [B, S, H, D]; k, v: [B, S, K, D] -> [B, S, H, D]."""
    interpret = default_interpret(interpret)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = flash_attention_fwd(qt, kt, vt, causal=causal, block_q=block_q,
                            block_k=block_k, interpret=interpret)
    return jnp.swapaxes(o, 1, 2)
