"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: [B, H, S, D]; k, v: [B, K, S, D]. Full-softmax reference."""
    B, H, S, D = q.shape
    K = k.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, S, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        pos = jnp.arange(S)
        mask = pos[None, :] <= pos[:, None]
        s = jnp.where(mask, s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", a, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)
