# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared Pallas-TPU helpers (version compat + interpret-mode fallback).

Every kernel package in this tree (``flash_attention``, ``rbm_cd``,
``paged_attention``, ``ragged_prefill``) follows the same shape: ``kernel.py`` holds the
``pallas_call`` body, ``ops.py`` the jit'd public wrapper.  The wrappers
share one backend rule, hosted here: on CPU (this container, CI) the kernel
body executes in Pallas interpret mode — bit-accurate to the TPU lowering's
semantics — and on TPU the same call lowers to Mosaic.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` on new jax, ``pltpu.TPUCompilerParams`` on old."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def default_interpret(interpret: Optional[bool]) -> bool:
    """The one interpret-mode rule every kernel wrapper applies: an explicit
    caller choice wins; otherwise interpret exactly when jax has no TPU/GPU
    backend to compile for."""
    return on_cpu() if interpret is None else interpret
