# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared Pallas-TPU helpers (version compat)."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` on new jax, ``pltpu.TPUCompilerParams`` on old."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
