"""Diversity-based data sampling (paper §III-A-1).

"One idea is to remove the similar items by using diversity-based data sampling
... the frequency of input data will be counted, and those duplicated data is
eliminated."  Implemented as a hash-count pass (exact duplicates) plus an
optional LSH-style coarse-similarity cap (quantized-pixel signature).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Optional, Tuple

import numpy as np


def _signatures(X: np.ndarray, quant: Optional[int]) -> np.ndarray:
    if quant is None:
        data = X
    else:
        data = np.round(X * quant).astype(np.int16)
    return np.asarray([hash(row.tobytes()) for row in data], np.int64)


def dedup(X: np.ndarray, y: Optional[np.ndarray] = None, *, max_dup: int = 1,
          quant: Optional[int] = None) -> Tuple[np.ndarray, ...]:
    """Keep at most ``max_dup`` copies of each (near-)identical sample.

    ``quant=None`` removes exact duplicates; ``quant=k`` first quantizes pixels
    to k levels so near-identical noisy copies also collapse."""
    sigs = _signatures(X, quant)
    counts: dict = defaultdict(int)
    keep = np.zeros(len(X), bool)
    for i, s in enumerate(sigs):
        counts[s] += 1
        if counts[s] <= max_dup:
            keep[i] = True
    if y is None:
        return (X[keep],)
    return X[keep], y[keep]


def duplicate_stats(X: np.ndarray, quant: Optional[int] = None) -> dict:
    sigs = _signatures(X, quant)
    uniq, cnt = np.unique(sigs, return_counts=True)
    return {"n": len(X), "unique": len(uniq),
            "dup_frac": 1.0 - len(uniq) / max(1, len(X)),
            "max_multiplicity": int(cnt.max()) if len(cnt) else 0}
