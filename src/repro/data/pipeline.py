"""Deterministic, shardable, resumable data pipeline.

Design points for the 1000-node regime:
  * **determinism**: batch ``t`` is a pure function of (seed, t) — after a
    restart the loop skips to the checkpointed cursor and sees exactly the same
    stream (MapReduce's re-execution guarantee at job granularity).
  * **sharding**: each host materializes only its slice of the global batch.
  * **prefetch**: a one-slot background thread hides host-side latency
    (the place stragglers actually appear on real fleets).
  * **dedup stage**: optional diversity sampling (paper §III-A-1).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional, Tuple

import numpy as np

from .dedup import dedup as _dedup


class ShardedBatches:
    def __init__(self, X: np.ndarray, y: Optional[np.ndarray], *,
                 global_batch: int, seed: int = 0,
                 shard_index: int = 0, shard_count: int = 1,
                 drop_duplicates: bool = False, max_dup: int = 1,
                 start_step: int = 0):
        if drop_duplicates:
            out = _dedup(X, y, max_dup=max_dup)
            X = out[0]
            y = out[1] if y is not None else None
        assert global_batch % shard_count == 0
        self.X, self.y = X, y
        self.global_batch = global_batch
        self.local_batch = global_batch // shard_count
        self.seed = seed
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.step = start_step
        self.n = len(X)

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.RandomState((self.seed * 1_000_003 + epoch) % (2**31))
        return rng.permutation(self.n)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step — the resumability contract."""
        per_epoch = self.n // self.global_batch
        epoch = step // max(1, per_epoch)
        within = step % max(1, per_epoch)
        perm = self._perm(epoch)
        lo = within * self.global_batch
        idx = perm[lo:lo + self.global_batch]
        sl = idx[self.shard_index * self.local_batch:
                 (self.shard_index + 1) * self.local_batch]
        out = {"x": self.X[sl]}
        if self.y is not None:
            out["y"] = self.y[sl]
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict):
        self.step = int(state["step"])
        assert int(state["seed"]) == self.seed, "seed mismatch on resume"


class Prefetcher:
    """One-slot background prefetch (double buffering)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def token_batches(vocab: int, global_batch: int, seq_len: int, *, seed: int = 0,
                  shard_index: int = 0, shard_count: int = 1,
                  start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic LM token stream with in-context structure (Zipfian bigram
    chains) — deterministic per step, for the end-to-end LM driver."""
    local = global_batch // shard_count
    step = start_step
    # fixed random bigram successor table gives learnable structure
    rng0 = np.random.RandomState(seed)
    succ = rng0.randint(0, vocab, (vocab, 4))
    while True:
        rng = np.random.RandomState((seed * 7_777_777 + step * shard_count
                                     + shard_index) % (2**31))
        toks = np.empty((local, seq_len), np.int32)
        toks[:, 0] = rng.randint(0, vocab, local)
        choice = rng.randint(0, 4, (local, seq_len))
        noise = rng.random((local, seq_len)) < 0.1
        rand_tok = rng.randint(0, vocab, (local, seq_len))
        for t in range(1, seq_len):
            nxt = succ[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(noise[:, t], rand_tok[:, t], nxt)
        yield {"tokens": toks}
        step += 1
