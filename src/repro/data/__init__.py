from .synthetic_mnist import dataset, train_test  # noqa: F401
from .dedup import dedup, duplicate_stats  # noqa: F401
from .pipeline import Prefetcher, ShardedBatches, token_batches  # noqa: F401
