"""Procedural MNIST-like digits (offline container: no downloads).

Digits are rendered as anti-aliased 7-segment-style strokes on a 28x28 canvas
with random shift/scale/noise, giving a deterministic, labeled, linearly-
non-separable dataset that exercises the same pipeline the paper ran on MNIST.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

# 7-segment encoding per digit: (top, top-left, top-right, middle, bottom-left,
# bottom-right, bottom)
_SEGMENTS = {
    0: (1, 1, 1, 0, 1, 1, 1),
    1: (0, 0, 1, 0, 0, 1, 0),
    2: (1, 0, 1, 1, 1, 0, 1),
    3: (1, 0, 1, 1, 0, 1, 1),
    4: (0, 1, 1, 1, 0, 1, 0),
    5: (1, 1, 0, 1, 0, 1, 1),
    6: (1, 1, 0, 1, 1, 1, 1),
    7: (1, 0, 1, 0, 0, 1, 0),
    8: (1, 1, 1, 1, 1, 1, 1),
    9: (1, 1, 1, 1, 0, 1, 1),
}

# segment endpoints on a 20x12 glyph box (row0, col0, row1, col1)
_SEG_LINES = (
    (0, 0, 0, 11),      # top
    (0, 0, 9, 0),       # top-left
    (0, 11, 9, 11),     # top-right
    (9, 0, 9, 11),      # middle
    (9, 0, 19, 0),      # bottom-left
    (9, 11, 19, 11),    # bottom-right
    (19, 0, 19, 11),    # bottom
)


def _draw_line(img, r0, c0, r1, c1, thickness=1.6):
    n = max(abs(r1 - r0), abs(c1 - c0)) * 3 + 1
    rr = np.linspace(r0, r1, n)
    cc = np.linspace(c0, c1, n)
    H, W = img.shape
    ri, ci = np.mgrid[0:H, 0:W]
    for r, c in zip(rr, cc):
        d2 = (ri - r) ** 2 + (ci - c) ** 2
        img += np.exp(-d2 / (2 * (thickness / 2.35) ** 2))
    return img


_GLYPHS = None


def _glyphs():
    global _GLYPHS
    if _GLYPHS is None:
        out = np.zeros((10, 20, 12), np.float32)
        for d, segs in _SEGMENTS.items():
            img = np.zeros((20, 12), np.float32)
            for on, line in zip(segs, _SEG_LINES):
                if on:
                    _draw_line(img, *line)
            out[d] = np.clip(img, 0, 1)
        _GLYPHS = out
    return _GLYPHS


def dataset(n: int, seed: int = 0, noise: float = 0.12,
            duplicate_frac: float = 0.0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X [n, 784] float32 in [0,1], y [n] int32).

    ``duplicate_frac`` injects exact duplicates (the paper's redundant-data
    concern) so the dedup stage has something to remove."""
    rng = np.random.RandomState(seed)
    glyphs = _glyphs()
    X = np.zeros((n, 28, 28), np.float32)
    y = rng.randint(0, 10, n).astype(np.int32)
    for i in range(n):
        g = glyphs[y[i]]
        sr = rng.uniform(0.8, 1.1)
        sc = rng.uniform(0.8, 1.1)
        h, w = int(20 * sr), int(12 * sc)
        h, w = max(10, min(26, h)), max(6, min(20, w))
        rs = np.clip((np.arange(h) / h * 20).astype(int), 0, 19)
        cs = np.clip((np.arange(w) / w * 12).astype(int), 0, 11)
        gl = g[np.ix_(rs, cs)]
        r0 = rng.randint(0, 28 - h)
        c0 = rng.randint(0, 28 - w)
        X[i, r0:r0 + h, c0:c0 + w] = gl
        X[i] += rng.randn(28, 28).astype(np.float32) * noise
    X = np.clip(X, 0, 1).reshape(n, 784)
    if duplicate_frac > 0:
        k = int(n * duplicate_frac)
        src = rng.randint(0, n, k)
        dst = rng.randint(0, n, k)
        X[dst] = X[src]
        y[dst] = y[src]
    return X, y


def train_test(n_train: int = 6000, n_test: int = 1000, seed: int = 0,
               **kw) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    Xtr, ytr = dataset(n_train, seed=seed, **kw)
    Xte, yte = dataset(n_test, seed=seed + 10_000, **kw)
    return Xtr, ytr, Xte, yte
