"""Compiled-artifact analysis: collective parsing + three-term roofline.

``compiled.cost_analysis()`` on the CPU backend reports **per-device** (post-SPMD-
partitioning) FLOPs and bytes; collective tensor shapes in the HLO are likewise
per-device.  Roofline terms are therefore seconds-per-chip directly:

    compute    = HLO_FLOPs / PEAK_FLOPS_BF16
    memory     = HLO_bytes / HBM_BW
    collective = wire_bytes / ICI_BW

Wire bytes use ring-algorithm factors: all-reduce 2(n-1)/n, all-gather /
reduce-scatter / all-to-all (n-1)/n, collective-permute 1.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

# e.g. "%all-gather.3 = bf16[8,128]{1,0} all-gather(..." or tuple results
_LINE_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s*(?:,\s*[a-z0-9]+\[[0-9,]*\][^ ]*\s*)*(?:\))?\s*"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\b")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _nelems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def parse_collectives(hlo_text: str) -> List[Dict]:
    """Extract every collective op with per-device tensor + wire bytes."""
    out = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _LINE_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        op = op.replace("-start", "")
        if op not in _COLL:
            continue
        # result may be a tuple (e.g. all-reduce of several tensors): sum all
        head = line.split(op)[0]
        shapes = _SHAPE_RE.findall(head)
        nbytes = sum(_nelems(d) * _DTYPE_BYTES.get(t, 4) for t, d in shapes)
        gm = _GROUPS_RE.search(line)
        if gm:
            group = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            group = int(gi.group(2)) if gi else 1
        if group <= 1:
            factor = 0.0
        elif op == "all-reduce":
            factor = 2.0 * (group - 1) / group
        elif op == "collective-permute":
            factor = 1.0
        else:
            factor = (group - 1) / group
        out.append({"op": op, "bytes": nbytes, "group": group,
                    "wire_bytes": nbytes * factor})
    return out


def collective_summary(hlo_text: str) -> Dict:
    colls = parse_collectives(hlo_text)
    by_op: Dict[str, Dict] = {}
    for c in colls:
        d = by_op.setdefault(c["op"], {"count": 0, "bytes": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += c["bytes"]
        d["wire_bytes"] += c["wire_bytes"]
    return {"ops": by_op,
            "total_bytes": sum(c["bytes"] for c in colls),
            "total_wire_bytes": sum(c["wire_bytes"] for c in colls),
            "count": len(colls)}


def roofline(flops_per_dev: float, bytes_per_dev: float,
             wire_bytes_per_dev: float) -> Dict:
    compute_s = flops_per_dev / PEAK_FLOPS_BF16
    memory_s = bytes_per_dev / HBM_BW
    coll_s = wire_bytes_per_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    total = max(terms.values())
    return {**terms, "dominant": dom.replace("_s", ""),
            "step_lower_bound_s": total,
            "compute_fraction": compute_s / total if total else 0.0}


def attn_score_traffic(cfg, shape, mesh_axes: Dict[str, int]) -> float:
    """Per-device HBM bytes attributable to materialized attention-score
    tensors in the XLA (non-flash) attention path.  The Pallas flash kernel
    (kernels/flash_attention) keeps these blocks in VMEM, so the 'with flash'
    roofline subtracts this traffic.  Factors: train ≈ 6 passes over the score
    tensor (fwd write+read, remat re-fwd, bwd dS write+read), prefill ≈ 2.
    """
    if not cfg.n_heads:
        return 0.0
    if shape.kind == "decode":
        return 0.0                      # one q row; negligible and streamed
    S, B = shape.seq_len, shape.global_batch
    model = mesh_axes.get("model", 1)
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh_axes.get(a, 1)
    H = cfg.n_heads
    h_local = H // model if H % model == 0 else H   # non-divisible -> replicated
    b_local = max(1, B // dp)
    L = cfg.n_layers if not cfg.enc_dec else cfg.n_enc_layers + cfg.n_dec_layers
    if cfg.family == "hybrid":
        L = max(1, cfg.n_layers // len(cfg.block_pattern or (1,)))
    win = cfg.attn_window if cfg.family == "hybrid" else cfg.sliding_window
    pairs = (S * min(win, S)) if win else (S * S * 0.5)
    passes = 6.0 if shape.kind == "train" else 2.0
    return passes * 4.0 * b_local * h_local * pairs * L


def model_flops(cfg, shape) -> float:
    """Useful-work FLOPs: 6·N·D for training, 2·N·D for inference forward, with
    the quadratic attention term added explicitly.  MoE counts active params."""
    N = cfg.param_count(active_only=True)
    S, B = shape.seq_len, shape.global_batch
    if shape.kind == "train":
        D = S * B
        base = 6.0 * N * D
        mult = 3.0          # fwd + 2x bwd
    elif shape.kind == "prefill":
        D = S * B
        base = 2.0 * N * D
        mult = 1.0
    else:  # decode: one token per sequence
        D = B
        base = 2.0 * N * D
        mult = 1.0
    attn = 0.0
    if cfg.n_heads and not cfg.use_mla:
        hd = cfg.head_dim_
        H = cfg.n_heads
        L = cfg.n_layers if not cfg.enc_dec else cfg.n_enc_layers + cfg.n_dec_layers
        if shape.kind == "decode":
            ctx = min(S, cfg.attn_window or S) if cfg.family == "hybrid" else S
            attn = 4.0 * B * ctx * H * hd * L * mult
            if cfg.family == "hybrid":
                n_g, tail, n_attn = 0, 0, 0
                attn *= (cfg.n_layers // 3) / cfg.n_layers  # only attn layers
        else:
            causal = 0.5
            win = cfg.attn_window if cfg.family == "hybrid" else (cfg.sliding_window or 0)
            if win:
                ctx_pairs = min(win, S) * S
            else:
                ctx_pairs = S * S * causal
            n_attn_layers = L if cfg.family != "hybrid" else max(1, cfg.n_layers // 3)
            attn = 4.0 * B * ctx_pairs * H * hd * n_attn_layers * mult
    elif cfg.use_mla:
        L = cfg.n_layers
        qk = cfg.nope_head_dim + cfg.rope_head_dim
        H = cfg.n_heads
        if shape.kind == "decode":
            attn = 2.0 * B * S * (cfg.kv_lora_rank + cfg.rope_head_dim) * H * 2
        else:
            attn = 4.0 * B * S * S * 0.5 * H * (qk + cfg.v_head_dim) / 2 * L * \
                (3.0 if shape.kind == "train" else 1.0)
    return base + attn
