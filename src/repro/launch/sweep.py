"""Dry-run sweep driver: one subprocess per (arch x shape x mesh) cell.

Each cell gets a fresh process (fresh XLA device state, bounded RSS) and writes
its JSON record under --out.  Already-completed cells are skipped, so the sweep
is resumable — the same property the training loop gets from checkpoints.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def cell_list(archs, shapes, meshes):
    for a in archs:
        for s in shapes:
            for mp in meshes:
                yield a, s, mp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--remat", default=None)
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
    from repro.configs import ARCHS, SHAPES

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    os.makedirs(args.out, exist_ok=True)
    results = []
    t00 = time.time()
    for a, s, mp in cell_list(archs, shapes, [False, True]):
        mesh = "2x16x16" if mp else "16x16"
        path = os.path.join(args.out, f"{a}__{s}__{mesh}.json")
        if os.path.exists(path):
            rec = json.load(open(path))
            results.append(rec)
            print(f"[sweep] cached {a} {s} {mesh}: {rec['status']}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--out", args.out]
        if mp:
            cmd.append("--multipod")
        if args.remat:
            cmd += ["--remat", args.remat]
        t0 = time.time()
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..")),
             env.get("PYTHONPATH", "")])
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=args.timeout, env=env)
            ok = proc.returncode == 0
            tail = (proc.stdout + proc.stderr).strip().splitlines()[-3:]
        except subprocess.TimeoutExpired:
            ok, tail = False, ["TIMEOUT"]
        if os.path.exists(path):
            rec = json.load(open(path))
        else:
            rec = {"arch": a, "shape": s, "mesh": mesh, "status": "error",
                   "error": "; ".join(tail)}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        results.append(rec)
        print(f"[sweep] {a:24s} {s:12s} {mesh:8s} -> {rec['status']:5s} "
              f"({time.time() - t0:.0f}s, total {time.time() - t00:.0f}s)",
              flush=True)
    n = {"ok": 0, "skip": 0, "error": 0}
    for r in results:
        n[r["status"]] = n.get(r["status"], 0) + 1
    print(f"[sweep] done: {n}")


if __name__ == "__main__":
    main()
