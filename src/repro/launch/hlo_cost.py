"""Loop-aware HLO cost model.

``compiled.cost_analysis()`` visits each ``while`` body **once**, so any program
that scans over layers (which every production LM must, for compile time)
under-reports FLOPs/bytes/collectives by ~n_layers.  This module walks the
optimized HLO text, computes per-computation costs, parses each while loop's
trip count from its condition, and accumulates ``entry + Σ trip_i × body_i``
(handling nesting multiplicatively).

Costs per op:
  * ``dot``: 2 × |result| × K  (K = product of lhs contracting dims)
  * ``convolution``: 2 × |result| × K_window
  * elementwise/other: |result| FLOPs (1 op/element; softmax/norm/scan honesty)
  * bytes: |result| + Σ|operands|, counted only for *materializing* ops
    (dot/conv/fusion/reduce/gather/scatter/copy/transpose/...).  Standalone
    elementwise ops are skipped: on the TPU target XLA fuses them into
    neighbors, so charging their operands as HBM traffic would bake the CPU
    backend's weak fusion into the roofline.  This mirrors XLA:TPU's
    post-fusion accounting, conservatively.
  * collectives: wire-byte model (ring factors)

Validated against cost_analysis() on fully-unrolled programs in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([a-z][\w\-]*)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops whose operands/results genuinely hit HBM on the TPU target; everything
# else is assumed fused into a neighbor (elementwise, broadcast, compare, ...)
_MATERIALIZING = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "copy", "transpose",
    "concatenate", "pad", "sort", "rng", "rng-bit-generator", "slice",
    "reverse", "iota", "custom-call",
}


def _shape_bytes(typestr: str) -> int:
    total = 0
    for t, dims in _SHAPE_RE.findall(typestr):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(t, 4)
    return total


def _shape_elems(typestr: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(typestr):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    typestr: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    shapes: Dict[str, str]        # symbol -> type string (incl. params)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            if line and not line.startswith(" ") and "{" in line and "->" in line:
                m = _COMP_RE.match(line)
                if m:
                    current = Computation(m.group(2), bool(m.group(1)), [], {})
                    # parameter shapes from the signature
                    sig = line[line.find("(") + 1:line.rfind("->")]
                    for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^,)]*))", sig):
                        current.shapes[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _DEF_RE.match(line)
        if m:
            name, typestr, opcode = m.group(1), m.group(2), m.group(3)
            current.shapes[name] = typestr
            current.ops.append(Op(name, typestr, opcode, line.strip()))
    return comps


def _trip_count(cond: Computation) -> int:
    consts = [int(c) for op in cond.ops for c in _CONST_RE.findall(op.line)]
    sig_consts = [int(c) for c in _CONST_RE.findall(
        " ".join(o.line for o in cond.ops))]
    allc = consts + sig_consts
    return max(allc) if allc else 1


def _collective_wire(op: Op) -> Tuple[int, float, int]:
    nbytes = _shape_bytes(op.typestr)
    gm = _GROUPS_RE.search(op.line)
    if gm:
        group = len(gm.group(1).split(","))
    else:
        gi = _GROUPS_IOTA_RE.search(op.line)
        group = int(gi.group(2)) if gi else 1
    kind = op.opcode.replace("-start", "").replace("-done", "")
    if group <= 1:
        factor = 0.0
    elif kind == "all-reduce":
        factor = 2.0 * (group - 1) / group
    elif kind == "collective-permute":
        factor = 1.0
    else:
        factor = (group - 1) / group
    return nbytes, nbytes * factor, group


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Counter = dataclasses.field(default_factory=Counter)
    coll_wire_by_op: Counter = dataclasses.field(default_factory=Counter)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.wire_bytes += other.wire_bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += v * mult
        for k, v in other.coll_wire_by_op.items():
            self.coll_wire_by_op[k] += v * mult


def _fusion_flops(comp: Computation, comps) -> float:
    """dot/conv FLOPs inside a fused computation (elementwise excluded; the
    fusion's output element count is charged at the call site)."""
    fl = 0.0
    for op in comp.ops:
        if op.opcode in ("dot", "convolution"):
            fl += _dot_flops(op, comp)
        elif op.opcode == "fusion":
            cm = _CALLS_RE.search(op.line)
            if cm and cm.group(1) in comps:
                fl += _fusion_flops(comps[cm.group(1)], comps)
    return fl


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _shape_elems(op.typestr)
    operands = _OPERAND_RE.findall(op.line.split("(", 1)[1])
    k = 1
    cm = _CONTRACT_RE.search(op.line)
    if cm and operands:
        lhs_shape = comp.shapes.get(operands[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",")]
            for ci in cm.group(1).split(","):
                if ci != "" and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               memo: Dict[str, Cost]) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    c = Cost()
    for op in comp.ops:
        kind = op.opcode.replace("-start", "").replace("-done", "")
        if op.opcode.endswith("-done"):
            continue                      # async pair: count at -start
        if kind in _COLLECTIVES:
            nbytes, wire, group = _collective_wire(op)
            c.coll_bytes += nbytes
            c.wire_bytes += wire
            c.coll_counts[kind] += 1
            c.coll_wire_by_op[kind] += wire
            c.bytes += 2 * nbytes
            continue
        if op.opcode == "while":
            m = _WHILE_RE.search(op.line)
            if m:
                cond, body = m.group(1), m.group(2)
                tm = _TRIP_RE.search(op.line)   # XLA's own annotation, if present
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = _trip_count(comps[cond]) if cond in comps else 1
                if body in comps:
                    c.add(_comp_cost(comps[body], comps, memo), max(1, trip))
            continue
        if op.opcode in ("call", "conditional", "async-start"):
            for name in _CALLS_RE.findall(op.line):
                if name in comps:
                    c.add(_comp_cost(comps[name], comps, memo))
            continue
        # ---- plain op ----
        out_bytes = _shape_bytes(op.typestr)
        in_bytes = 0
        if "(" in op.line:
            for o in _OPERAND_RE.findall(op.line.split("(", 1)[1]):
                if o in comp.shapes:
                    in_bytes += _shape_bytes(comp.shapes[o])
        if op.opcode in ("dot", "convolution"):
            c.flops += _dot_flops(op, comp)
            c.bytes += out_bytes + in_bytes
        elif op.opcode == "fusion":
            cm = _CALLS_RE.search(op.line)
            if cm and cm.group(1) in comps:
                c.flops += _fusion_flops(comps[cm.group(1)], comps)
            c.flops += _shape_elems(op.typestr)      # elementwise estimate
            # result-only: XLA:CPU fuses far less than XLA:TPU, so charging
            # fusion *operands* as HBM reads would bake the CPU backend's
            # fine fusion boundaries into the roofline (they dominated 92%
            # of bytes before this fix).  Each tensor is charged once, as
            # the write of whatever op produced it.
            c.bytes += out_bytes
        elif op.opcode in ("parameter", "constant", "get-tuple-element",
                           "tuple", "bitcast", "copy-start", "copy-done"):
            pass
        else:
            c.flops += _shape_elems(op.typestr)
            if op.opcode in ("scatter", "gather", "dynamic-slice",
                             "dynamic-update-slice", "sort", "rng",
                             "rng-bit-generator"):
                c.bytes += out_bytes + in_bytes
            elif op.opcode in _MATERIALIZING:
                c.bytes += 2 * out_bytes             # read + write of a copy
    memo[comp.name] = c
    return c


def module_cost(hlo_text: str) -> Cost:
    comps = parse_module(hlo_text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:                    # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.ops))
    return _comp_cost(entry, comps, {})
