from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_host_mesh, make_production_mesh  # noqa: F401
