"""HTTP/SSE streaming server over the continuous-batching engine.

  # serve an arch on :8080 (SSE streaming, overlapped pipeline)
  PYTHONPATH=src python -m repro.launch.serve_http --arch qwen2-0.5b \
      --reduced --port 8080

  # self-contained smoke run (CI): start the server on an ephemeral port,
  # stream N requests through real HTTP, verify the streamed tokens are
  # token-exact vs the static single-request baseline, write the trace
  PYTHONPATH=src python -m repro.launch.serve_http --arch qwen2-0.5b \
      --reduced --smoke 4 --trace trace.json

API (deliberately tiny, stdlib-only on both ends):

* ``POST /generate`` — body ``{"prompt": [ids...], "max_new_tokens": n}``;
  responds ``text/event-stream``, one ``data: {json}`` frame per token as
  it decodes plus a terminal ``done`` (tokens, ttft_s, tpot_s) or ``error``
  frame.  A client disconnect mid-stream cancels the request — its slot and
  pages free at the next engine iteration.
* ``GET /metrics`` — full metrics-registry snapshot as JSON (every serving
  layer: pool, radix cache, scheduler, engine, overlap counters).
* ``GET /health`` — the real health state machine (``starting → healthy →
  degraded/draining → drained`` with transition history) plus live-slot and
  queue-depth gauges.  Load balancers key off ``state``.
* ``POST /drain`` — begin a graceful drain: new work is shed with a 503,
  in-flight requests run to completion, ``/health`` reports ``drained``
  once the engine is idle.

Overload behaviour (``--admission-control``): requests may carry
``deadline_s`` / ``ttft_deadline_s``; when the predicted queue wait blows
the deadline (or the server is draining) the request is refused **before**
its SSE stream opens — 503 with a JSON body ``{"error": "overloaded",
"reason": ..., "retry_after_s": ...}`` and a ``Retry-After`` header whose
value is a jittered backoff hint (so a retrying fleet decorrelates).

The HTTP layer is hand-rolled over ``asyncio.start_server`` (request line +
headers + Content-Length body; no chunked uploads, no keep-alive) so the
serving stack stays dependency-free — the point is the engine behind it,
not the framework in front.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..configs import ServeConfig, get_arch, reduced as make_reduced
from ..serving import Engine, ServingLoop, Tracer, generate_static

MAX_BODY = 1 << 20      # 1 MiB request-body cap


def _json_response(payload: Any, status: str = "200 OK",
                   headers: Optional[Dict[str, str]] = None) -> bytes:
    body = json.dumps(payload).encode()
    extra = "".join(f"{k}: {v}\r\n" for k, v in (headers or {}).items())
    return (f"HTTP/1.1 {status}\r\nContent-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n{extra}Connection: close\r\n\r\n"
            ).encode() + body


SSE_HEADER = (b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n"
              b"Cache-Control: no-cache\r\nConnection: close\r\n\r\n")


async def _read_request(reader: asyncio.StreamReader
                        ) -> Optional[Tuple[str, str, bytes]]:
    """Parse one HTTP/1.1 request: (method, path, body) or None on EOF."""
    line = await reader.readline()
    if not line:
        return None
    parts = line.decode("latin1").split()
    if len(parts) < 2:
        return None
    method, path = parts[0], parts[1]
    n_body = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin1").partition(":")
        if k.strip().lower() == "content-length":
            n_body = min(int(v.strip()), MAX_BODY)
    body = await reader.readexactly(n_body) if n_body else b""
    return method, path, body


class HttpFrontend:
    """Routes HTTP requests into a ``ServingLoop``."""

    def __init__(self, serving: ServingLoop, default_max_new: int = 16):
        self.serving = serving
        self.default_max_new = default_max_new
        self.n_streams = 0

    async def handle(self, reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        try:
            req = await _read_request(reader)
            if req is None:
                return
            method, path, body = req
            if method == "POST" and path == "/generate":
                await self._generate(writer, body)
            elif method == "GET" and path == "/metrics":
                writer.write(_json_response(
                    self.serving.engine.metrics_snapshot()))
            elif method == "GET" and path == "/health":
                m = self.serving.engine.metrics
                payload = self.serving.engine.health.to_dict()
                payload.update(
                    slots_live=m.value("sched.slots_live"),
                    queue_depth=m.value("sched.queue_depth"))
                writer.write(_json_response(payload))
            elif method == "POST" and path == "/drain":
                self.serving.drain()
                writer.write(_json_response(
                    self.serving.engine.health.to_dict()))
            else:
                writer.write(_json_response({"error": "not found"},
                                            "404 Not Found"))
            await writer.drain()
        except (ConnectionResetError, asyncio.IncompleteReadError,
                BrokenPipeError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        try:
            payload = json.loads(body or b"{}")
            prompt = [int(t) for t in payload["prompt"]]
            max_new = int(payload.get("max_new_tokens", self.default_max_new))
            deadline_s = payload.get("deadline_s")
            ttft_deadline_s = payload.get("ttft_deadline_s")
            deadline_s = float(deadline_s) if deadline_s is not None else None
            ttft_deadline_s = (float(ttft_deadline_s)
                               if ttft_deadline_s is not None else None)
        except (KeyError, TypeError, ValueError) as e:
            writer.write(_json_response({"error": f"bad request: {e}"},
                                        "400 Bad Request"))
            return
        shed = self.serving.admission_check(deadline_s, ttft_deadline_s)
        if shed is not None:
            reason, retry_after = shed
            writer.write(_json_response(
                {"error": "overloaded", "reason": reason,
                 "retry_after_s": retry_after},
                "503 Service Unavailable",
                headers={"Retry-After": f"{retry_after:.3f}"}))
            return
        rid, q = self.serving.submit(prompt, max_new,
                                     deadline_s=deadline_s,
                                     ttft_deadline_s=ttft_deadline_s)
        self.n_streams += 1
        writer.write(SSE_HEADER)
        try:
            while True:
                ev = await q.get()
                writer.write(b"data: " + json.dumps(ev).encode() + b"\n\n")
                await writer.drain()     # disconnect surfaces here
                if ev["type"] in ("done", "error"):
                    return
        except (ConnectionResetError, BrokenPipeError):
            self.serving.cancel(rid)     # client went away: free the slot
        finally:
            self.serving.forget(rid)


# --------------------------------------------------------------- smoke mode


async def _sse_client(host: str, port: int, prompt, max_new: int,
                      deadline_s: Optional[float] = None,
                      ttft_deadline_s: Optional[float] = None,
                      disconnect_after: int = 0) -> Dict[str, Any]:
    """Minimal stdlib SSE client: POST /generate, collect every event.

    Understands the 503 shed path (returns ``status``, ``retry_after`` and
    the JSON body instead of a stream) and can abandon the connection after
    ``disconnect_after`` tokens to exercise mid-stream client disconnects.
    """
    reader, writer = await asyncio.open_connection(host, port)
    req: Dict[str, Any] = {"prompt": prompt, "max_new_tokens": max_new}
    if deadline_s is not None:
        req["deadline_s"] = deadline_s
    if ttft_deadline_s is not None:
        req["ttft_deadline_s"] = ttft_deadline_s
    body = json.dumps(req).encode()
    writer.write((f"POST /generate HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    t_submit = time.perf_counter()

    status_line = await reader.readline()
    status = int(status_line.split()[1]) if status_line else 0
    retry_after = None
    n_header_body = 0
    while True:                          # response headers
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin1").partition(":")
        k = k.strip().lower()
        if k == "retry-after":
            retry_after = float(v.strip())
        elif k == "content-length":
            n_header_body = int(v.strip())
    if status != 200:                    # shed / error: JSON body, no stream
        raw = await reader.readexactly(n_header_body) if n_header_body else b""
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass
        return {"status": status, "retry_after": retry_after,
                "body": json.loads(raw or b"{}"), "events": [],
                "streamed": [], "final": {"type": "shed"},
                "client_ttft_s": time.perf_counter() - t_submit}

    events = []
    t_first = None
    while True:
        line = await reader.readline()
        if not line:
            raise RuntimeError("server closed the stream mid-request")
        if not line.startswith(b"data: "):
            continue                     # keep-alive blank lines
        ev = json.loads(line[6:])
        if ev["type"] == "token" and t_first is None:
            t_first = time.perf_counter()
        events.append(ev)
        if ev["type"] in ("done", "error"):
            break
        if disconnect_after and len(events) >= disconnect_after:
            break                        # abandon mid-stream (hard close)
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    streamed = [e["token"] for e in events if e["type"] == "token"]
    final = events[-1]
    return {"status": status, "retry_after": retry_after, "events": events,
            "streamed": streamed, "final": final,
            "client_ttft_s": (t_first or time.perf_counter()) - t_submit}


async def _http_json(host: str, port: int, method: str, path: str
                     ) -> Tuple[int, Dict[str, Any]]:
    """One non-streaming request (GET /health, POST /drain, ...)."""
    reader, writer = await asyncio.open_connection(host, port)
    writer.write((f"{method} {path} HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Length: 0\r\n\r\n").encode())
    await writer.drain()
    status_line = await reader.readline()
    status = int(status_line.split()[1]) if status_line else 0
    n_body = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin1").partition(":")
        if k.strip().lower() == "content-length":
            n_body = int(v.strip())
    raw = await reader.readexactly(n_body) if n_body else b""
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    return status, json.loads(raw or b"{}")


async def _overload_smoke(host: str, port: int, args, cfg,
                          service_hint_s: float) -> int:
    """Burst 3N deadline-carrying clients (≈2× what the calibrated slots
    can absorb) and assert the overload contract: nobody hangs, every
    client reaches a terminal state, and at least one shed carries a 503
    with a positive Retry-After backoff hint."""
    rng = np.random.RandomState(args.seed + 1)
    n = 3 * args.smoke
    deadline_s = max(1.2 * service_hint_s, 0.05)
    prompts = [rng.randint(1, cfg.vocab,
                           size=int(rng.randint(4, args.prompt_len + 1))
                           ).tolist() for _ in range(n)]
    outs = await asyncio.wait_for(
        asyncio.gather(*[_sse_client(host, port, p, args.gen,
                                     deadline_s=deadline_s)
                         for p in prompts]),
        timeout=120.0)               # the no-hang assertion
    done = [o for o in outs if o["final"]["type"] == "done"]
    shed_503 = [o for o in outs if o["status"] == 503]
    # engine-side sheds / deadline evictions surface as stream errors
    errs = [o for o in outs if o["final"]["type"] == "error"]
    bad = []
    for o in shed_503:
        if o["retry_after"] is None or o["retry_after"] <= 0:
            bad.append(f"503 without positive Retry-After: {o['body']}")
        elif o["body"].get("reason") not in ("overloaded", "draining"):
            bad.append(f"503 with unexpected reason: {o['body']}")
    if not shed_503:
        bad.append(f"2x-overload burst of {n} produced no front-door 503 "
                   f"(deadline {deadline_s:.3f}s)")
    if len(done) + len(shed_503) + len(errs) != n:
        bad.append("some client reached no terminal state")
    print(f"[serve_http] overload: {n} burst clients, deadline "
          f"{deadline_s * 1e3:.0f} ms -> {len(done)} served, "
          f"{len(shed_503)} shed at front door (503), {len(errs)} failed "
          f"in-engine")
    for why in bad:
        print(f"[serve_http] OVERLOAD SMOKE FAILED: {why}", file=sys.stderr)
    return 1 if bad else 0


async def _drain_smoke(host: str, port: int) -> int:
    """Drive the health machine through a graceful drain over HTTP and
    assert healthy → draining → drained plus 503s for late arrivals."""
    bad = []
    _, health = await _http_json(host, port, "GET", "/health")
    if health.get("state") != "healthy":
        bad.append(f"pre-drain state {health.get('state')!r} != 'healthy'")
    _, health = await _http_json(host, port, "POST", "/drain")
    if health.get("state") not in ("draining", "drained"):
        bad.append(f"post-drain state {health.get('state')!r}")
    late = await _sse_client(host, port, [1, 2, 3], 4)
    if late["status"] != 503 or late["body"].get("reason") != "draining":
        bad.append(f"late submit not shed with 503/draining: "
                   f"status={late['status']} body={late.get('body')}")
    deadline = time.perf_counter() + 10.0
    while time.perf_counter() < deadline:
        _, health = await _http_json(host, port, "GET", "/health")
        if health.get("state") == "drained":
            break
        await asyncio.sleep(0.05)
    if health.get("state") != "drained":
        bad.append(f"never reached 'drained' (stuck at {health.get('state')!r})")
    hist = health.get("history", [])
    for a, b in (("healthy", "draining"), ("draining", "drained")):
        if a in hist and b in hist and hist.index(a) < hist.index(b):
            continue
        bad.append(f"history missing transition {a} -> {b}: {hist}")
    print(f"[serve_http] drain: health history {' -> '.join(hist)}")
    for why in bad:
        print(f"[serve_http] DRAIN SMOKE FAILED: {why}", file=sys.stderr)
    return 1 if bad else 0


async def _smoke(frontend: HttpFrontend, host: str, port: int, args,
                 cfg, scfg) -> int:
    """Stream ``--smoke N`` requests through real HTTP and verify the
    streamed tokens byte-for-byte against the static baseline.  With
    ``--overload`` a 2x burst phase follows; a graceful-drain phase always
    runs last (it leaves the server refusing work)."""
    rng = np.random.RandomState(args.seed)
    prompts = [rng.randint(1, cfg.vocab,
                           size=int(rng.randint(4, args.prompt_len + 1))
                           ).tolist()
               for _ in range(args.smoke)]
    t0 = time.perf_counter()
    outs = await asyncio.gather(*[
        _sse_client(host, port, p, args.gen) for p in prompts])
    elapsed_s = time.perf_counter() - t0
    ref, _ = generate_static(cfg, frontend.serving.engine.params, prompts,
                             args.gen, scfg, batch_size=1, seed=args.seed)
    bad = []
    for i, (out, expect) in enumerate(zip(outs, ref)):
        if out["final"]["type"] != "done":
            bad.append((i, f"terminal {out['final']}"))
        elif out["streamed"] != expect:
            bad.append((i, f"streamed {out['streamed']} != {expect}"))
        elif out["final"]["tokens"] != expect:
            bad.append((i, "done-frame tokens mismatch"))
    eng = frontend.serving.engine
    print(f"[serve_http] smoke: {len(outs)} requests streamed over HTTP; "
          f"client ttft p50 "
          f"{np.median([o['client_ttft_s'] for o in outs])*1e3:.1f} ms; "
          f"overlap staged/used/dropped "
          f"{eng._m_overlap_staged.value}/{eng._m_overlap_used.value}/"
          f"{eng._m_overlap_dropped.value}")
    if bad:
        for i, why in bad:
            print(f"[serve_http] SMOKE FAILED request {i}: {why}",
                  file=sys.stderr)
        return 1
    print(f"[serve_http] smoke verify OK: streamed tokens exact vs "
          f"single-request static baseline for all {len(outs)} requests")
    rc = 0
    if args.overload:
        # phase-1 wall time for N concurrent clients ≈ one admission wave's
        # service time — the deadline calibration for the burst
        rc |= await _overload_smoke(host, port, args, cfg, elapsed_s)
    rc |= await _drain_smoke(host, port)
    return rc


# --------------------------------------------------------------------- main


def build_engine(args) -> Tuple[Engine, Any, ServeConfig]:
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    cfg = dataclasses.replace(cfg, remat="none")
    ps = args.page_size
    max_len = args.max_len or ((args.prompt_len + args.gen + ps - 1)
                               // ps) * ps
    scfg = ServeConfig(page_size=ps, max_slots=args.slots, max_len=max_len,
                       prefix_cache=args.prefix_cache,
                       attn_backend=args.attn_backend,
                       prefill_chunk_tokens=args.prefill_chunk_tokens,
                       admission_control=(args.admission_control
                                          or args.overload))
    tracer = Tracer()
    eng = Engine(cfg, scfg, seed=args.seed, tracer=tracer)
    return eng, cfg, scfg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="TCP port (0 = ephemeral; --smoke defaults to 0)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="per-request length cap (0 -> fitted to workload)")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="workload sizing hint (max_len fit + smoke prompts)")
    ap.add_argument("--gen", type=int, default=16,
                    help="default max_new_tokens per request")
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--attn-backend", choices=("auto", "reference", "pallas"),
                    default="auto")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=0)
    ap.add_argument("--no-overlap", action="store_true",
                    help="drive the synchronous step() instead of the "
                         "overlapped pump()")
    ap.add_argument("--queue-size", type=int, default=256,
                    help="bounded collect-queue size (the backpressure knob)")
    ap.add_argument("--smoke", type=int, default=0, metavar="N",
                    help="self-test: stream N requests through HTTP, verify "
                         "tokens vs the static baseline, then drive a "
                         "graceful drain; exit")
    ap.add_argument("--admission-control", action="store_true",
                    help="enable deadline-aware admission shedding "
                         "(503 + Retry-After)")
    ap.add_argument("--overload", action="store_true",
                    help="with --smoke: add a 2x burst phase asserting the "
                         "shed contract (implies --admission-control)")
    ap.add_argument("--watchdog-s", type=float, default=0.0,
                    help="fail pending streams if the engine makes no "
                         "progress for this long (0 = off)")
    ap.add_argument("--trace", metavar="PATH", default="",
                    help="write the lifecycle trace (incl. host-pipeline "
                         "dispatch/stage/collect spans) on exit")
    ap.add_argument("--metrics-json", metavar="PATH", default="",
                    help="write the metrics-registry snapshot on exit")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    eng, cfg, scfg = build_engine(args)
    serving = ServingLoop(eng, overlap=not args.no_overlap,
                          collect_queue_size=args.queue_size,
                          watchdog_s=args.watchdog_s)
    frontend = HttpFrontend(serving, default_max_new=args.gen)
    port = args.port if not args.smoke else (args.port if args.port != 8080
                                             else 0)

    async def run() -> int:
        await serving.start()
        server = await asyncio.start_server(frontend.handle, args.host, port)
        bound = server.sockets[0].getsockname()[1]
        print(f"[serve_http] {cfg.name} on http://{args.host}:{bound} "
              f"(slots={scfg.max_slots}, max_len={scfg.max_len}, "
              f"overlap={'off' if args.no_overlap else 'on'}) — "
              f"POST /generate, GET /metrics, GET /health, POST /drain")
        rc = 0
        try:
            if args.smoke:
                rc = await _smoke(frontend, args.host, bound, args, cfg, scfg)
            else:
                async with server:
                    await server.serve_forever()
        finally:
            server.close()
            await server.wait_closed()
            await serving.stop()
        return rc

    try:
        rc = asyncio.run(run())
    except KeyboardInterrupt:
        rc = 0
    if args.trace:
        eng.tracer.save(args.trace)
        print(f"[serve_http] trace: {len(eng.tracer.events)} events -> "
              f"{args.trace}")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(eng.metrics_snapshot(), f, indent=2, sort_keys=True)
        print(f"[serve_http] metrics -> {args.metrics_json}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
