"""HTTP/SSE streaming server over the continuous-batching engine.

  # serve an arch on :8080 (SSE streaming, overlapped pipeline)
  PYTHONPATH=src python -m repro.launch.serve_http --arch qwen2-0.5b \
      --reduced --port 8080

  # self-contained smoke run (CI): start the server on an ephemeral port,
  # stream N requests through real HTTP, verify the streamed tokens are
  # token-exact vs the static single-request baseline, write the trace
  PYTHONPATH=src python -m repro.launch.serve_http --arch qwen2-0.5b \
      --reduced --smoke 4 --trace trace.json

API (deliberately tiny, stdlib-only on both ends):

* ``POST /generate`` — body ``{"prompt": [ids...], "max_new_tokens": n}``;
  responds ``text/event-stream``, one ``data: {json}`` frame per token as
  it decodes plus a terminal ``done`` (tokens, ttft_s, tpot_s) or ``error``
  frame.  A client disconnect mid-stream cancels the request — its slot and
  pages free at the next engine iteration.
* ``GET /metrics`` — full metrics-registry snapshot as JSON (every serving
  layer: pool, radix cache, scheduler, engine, overlap counters).
* ``GET /health`` — liveness + live-slot/queue-depth gauges.

The HTTP layer is hand-rolled over ``asyncio.start_server`` (request line +
headers + Content-Length body; no chunked uploads, no keep-alive) so the
serving stack stays dependency-free — the point is the engine behind it,
not the framework in front.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..configs import ServeConfig, get_arch, reduced as make_reduced
from ..serving import Engine, ServingLoop, Tracer, generate_static

MAX_BODY = 1 << 20      # 1 MiB request-body cap


def _json_response(payload: Any, status: str = "200 OK") -> bytes:
    body = json.dumps(payload).encode()
    return (f"HTTP/1.1 {status}\r\nContent-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n"
            ).encode() + body


SSE_HEADER = (b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n"
              b"Cache-Control: no-cache\r\nConnection: close\r\n\r\n")


async def _read_request(reader: asyncio.StreamReader
                        ) -> Optional[Tuple[str, str, bytes]]:
    """Parse one HTTP/1.1 request: (method, path, body) or None on EOF."""
    line = await reader.readline()
    if not line:
        return None
    parts = line.decode("latin1").split()
    if len(parts) < 2:
        return None
    method, path = parts[0], parts[1]
    n_body = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin1").partition(":")
        if k.strip().lower() == "content-length":
            n_body = min(int(v.strip()), MAX_BODY)
    body = await reader.readexactly(n_body) if n_body else b""
    return method, path, body


class HttpFrontend:
    """Routes HTTP requests into a ``ServingLoop``."""

    def __init__(self, serving: ServingLoop, default_max_new: int = 16):
        self.serving = serving
        self.default_max_new = default_max_new
        self.n_streams = 0

    async def handle(self, reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        try:
            req = await _read_request(reader)
            if req is None:
                return
            method, path, body = req
            if method == "POST" and path == "/generate":
                await self._generate(writer, body)
            elif method == "GET" and path == "/metrics":
                writer.write(_json_response(
                    self.serving.engine.metrics_snapshot()))
            elif method == "GET" and path == "/health":
                m = self.serving.engine.metrics
                writer.write(_json_response({
                    "ok": True,
                    "slots_live": m.value("sched.slots_live"),
                    "queue_depth": m.value("sched.queue_depth")}))
            else:
                writer.write(_json_response({"error": "not found"},
                                            "404 Not Found"))
            await writer.drain()
        except (ConnectionResetError, asyncio.IncompleteReadError,
                BrokenPipeError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        try:
            payload = json.loads(body or b"{}")
            prompt = [int(t) for t in payload["prompt"]]
            max_new = int(payload.get("max_new_tokens", self.default_max_new))
        except (KeyError, TypeError, ValueError) as e:
            writer.write(_json_response({"error": f"bad request: {e}"},
                                        "400 Bad Request"))
            return
        rid, q = self.serving.submit(prompt, max_new)
        self.n_streams += 1
        writer.write(SSE_HEADER)
        try:
            while True:
                ev = await q.get()
                writer.write(b"data: " + json.dumps(ev).encode() + b"\n\n")
                await writer.drain()     # disconnect surfaces here
                if ev["type"] in ("done", "error"):
                    return
        except (ConnectionResetError, BrokenPipeError):
            self.serving.cancel(rid)     # client went away: free the slot
        finally:
            self.serving.forget(rid)


# --------------------------------------------------------------- smoke mode


async def _sse_client(host: str, port: int, prompt, max_new: int
                      ) -> Dict[str, Any]:
    """Minimal stdlib SSE client: POST /generate, collect every event."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps({"prompt": prompt, "max_new_tokens": max_new}).encode()
    writer.write((f"POST /generate HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    events = []
    t_submit = time.perf_counter()
    t_first = None
    while True:
        line = await reader.readline()
        if not line:
            raise RuntimeError("server closed the stream mid-request")
        if not line.startswith(b"data: "):
            continue                     # headers / keep-alive blank lines
        ev = json.loads(line[6:])
        if ev["type"] == "token" and t_first is None:
            t_first = time.perf_counter()
        events.append(ev)
        if ev["type"] in ("done", "error"):
            break
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    streamed = [e["token"] for e in events if e["type"] == "token"]
    final = events[-1]
    return {"events": events, "streamed": streamed, "final": final,
            "client_ttft_s": (t_first or time.perf_counter()) - t_submit}


async def _smoke(frontend: HttpFrontend, host: str, port: int, args,
                 cfg, scfg) -> int:
    """Stream ``--smoke N`` requests through real HTTP and verify the
    streamed tokens byte-for-byte against the static baseline."""
    rng = np.random.RandomState(args.seed)
    prompts = [rng.randint(1, cfg.vocab,
                           size=int(rng.randint(4, args.prompt_len + 1))
                           ).tolist()
               for _ in range(args.smoke)]
    outs = await asyncio.gather(*[
        _sse_client(host, port, p, args.gen) for p in prompts])
    ref, _ = generate_static(cfg, frontend.serving.engine.params, prompts,
                             args.gen, scfg, batch_size=1, seed=args.seed)
    bad = []
    for i, (out, expect) in enumerate(zip(outs, ref)):
        if out["final"]["type"] != "done":
            bad.append((i, f"terminal {out['final']}"))
        elif out["streamed"] != expect:
            bad.append((i, f"streamed {out['streamed']} != {expect}"))
        elif out["final"]["tokens"] != expect:
            bad.append((i, "done-frame tokens mismatch"))
    eng = frontend.serving.engine
    print(f"[serve_http] smoke: {len(outs)} requests streamed over HTTP; "
          f"client ttft p50 "
          f"{np.median([o['client_ttft_s'] for o in outs])*1e3:.1f} ms; "
          f"overlap staged/used/dropped "
          f"{eng._m_overlap_staged.value}/{eng._m_overlap_used.value}/"
          f"{eng._m_overlap_dropped.value}")
    if bad:
        for i, why in bad:
            print(f"[serve_http] SMOKE FAILED request {i}: {why}",
                  file=sys.stderr)
        return 1
    print(f"[serve_http] smoke verify OK: streamed tokens exact vs "
          f"single-request static baseline for all {len(outs)} requests")
    return 0


# --------------------------------------------------------------------- main


def build_engine(args) -> Tuple[Engine, Any, ServeConfig]:
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    cfg = dataclasses.replace(cfg, remat="none")
    ps = args.page_size
    max_len = args.max_len or ((args.prompt_len + args.gen + ps - 1)
                               // ps) * ps
    scfg = ServeConfig(page_size=ps, max_slots=args.slots, max_len=max_len,
                       prefix_cache=args.prefix_cache,
                       attn_backend=args.attn_backend,
                       prefill_chunk_tokens=args.prefill_chunk_tokens)
    tracer = Tracer()
    eng = Engine(cfg, scfg, seed=args.seed, tracer=tracer)
    return eng, cfg, scfg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="TCP port (0 = ephemeral; --smoke defaults to 0)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="per-request length cap (0 -> fitted to workload)")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="workload sizing hint (max_len fit + smoke prompts)")
    ap.add_argument("--gen", type=int, default=16,
                    help="default max_new_tokens per request")
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--attn-backend", choices=("auto", "reference", "pallas"),
                    default="auto")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=0)
    ap.add_argument("--no-overlap", action="store_true",
                    help="drive the synchronous step() instead of the "
                         "overlapped pump()")
    ap.add_argument("--queue-size", type=int, default=256,
                    help="bounded collect-queue size (the backpressure knob)")
    ap.add_argument("--smoke", type=int, default=0, metavar="N",
                    help="self-test: stream N requests through HTTP, verify "
                         "tokens vs the static baseline, exit")
    ap.add_argument("--trace", metavar="PATH", default="",
                    help="write the lifecycle trace (incl. host-pipeline "
                         "dispatch/stage/collect spans) on exit")
    ap.add_argument("--metrics-json", metavar="PATH", default="",
                    help="write the metrics-registry snapshot on exit")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    eng, cfg, scfg = build_engine(args)
    serving = ServingLoop(eng, overlap=not args.no_overlap,
                          collect_queue_size=args.queue_size)
    frontend = HttpFrontend(serving, default_max_new=args.gen)
    port = args.port if not args.smoke else (args.port if args.port != 8080
                                             else 0)

    async def run() -> int:
        await serving.start()
        server = await asyncio.start_server(frontend.handle, args.host, port)
        bound = server.sockets[0].getsockname()[1]
        print(f"[serve_http] {cfg.name} on http://{args.host}:{bound} "
              f"(slots={scfg.max_slots}, max_len={scfg.max_len}, "
              f"overlap={'off' if args.no_overlap else 'on'}) — "
              f"POST /generate, GET /metrics, GET /health")
        rc = 0
        try:
            if args.smoke:
                rc = await _smoke(frontend, args.host, bound, args, cfg, scfg)
            else:
                async with server:
                    await server.serve_forever()
        finally:
            server.close()
            await server.wait_closed()
            await serving.stop()
        return rc

    try:
        rc = asyncio.run(run())
    except KeyboardInterrupt:
        rc = 0
    if args.trace:
        eng.tracer.save(args.trace)
        print(f"[serve_http] trace: {len(eng.tracer.events)} events -> "
              f"{args.trace}")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(eng.metrics_snapshot(), f, indent=2, sort_keys=True)
        print(f"[serve_http] metrics -> {args.metrics_json}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
