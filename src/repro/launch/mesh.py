"""Production mesh factory (TPU v5e pods).

Single pod: (data=16, model=16) = 256 chips.  Multi-pod adds a leading ``pod``
axis: (pod=2, data=16, model=16) = 512 chips.  A function (not a module-level
constant) so importing never touches jax device state.
"""
from __future__ import annotations

import jax

from ..models.shardings import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh for tests/examples on whatever devices exist."""
    axes, shape = [], []
    if pod > 1:
        axes.append("pod"); shape.append(pod)
    axes.append("data"); shape.append(data)
    if model > 1:
        axes.append("model"); shape.append(model)
    return make_mesh_compat(shape, axes)


# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
