"""Serving CLI — a thin front-end over ``repro.serving``.

  # continuous batching (paged KV pool + request scheduler)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --engine continuous --requests 16 --mixed --gen 16

  # static batching (contiguous caches, the pre-paging path)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --engine static --requests 4 --prompt-len 32 --gen 16

  # radix prefix cache: share KV pages across requests with common prefixes
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --engine continuous --requests 16 --shared-prefix 4 --prefix-cache \
      --verify

``--verify`` additionally replays every request through the static
single-request baseline and checks the greedy tokens agree per request.

Chaos mode (``--inject``) runs the same workload under a deterministic
fault plan (see ``serving.faults``) and — with ``--verify`` — checks the
**exact-survivor contract**: every non-targeted request's tokens are
byte-identical to the fault-free static baseline, targeted requests fail
terminally with the expected error (their partial tokens a strict prefix
of the baseline), every planned fault actually fired, and the page pool
balances after drain::

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 6 --mixed --gen 8 --verify \
      --inject "nan_logits:rid=2,at=3;pool_pressure:at=2,pages=8,steps=3"

Observability (continuous engine only)::

  # Chrome-trace JSON for Perfetto + full metrics-registry snapshot
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --engine continuous --requests 16 --mixed --verify \
      --trace trace.json --metrics-json metrics.json

then ``python -m repro.launch.trace_report trace.json`` for a time-in-phase
breakdown and per-request TTFT/TPOT table.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from ..configs import ServeConfig, get_arch, reduced as make_reduced
from ..models.registry import build_model
from ..serving import Engine, Tracer, generate_static


def make_prompts(args, vocab: int):
    """Deterministic synthetic prompts; ``--mixed`` varies length + budget,
    ``--shared-prefix F`` draws each prompt as one of F family prefixes plus
    a unique suffix (the workload a prefix cache pays off on)."""
    rng = np.random.RandomState(args.seed)
    fams = [rng.randint(1, vocab, size=max(args.prompt_len // 2, 1)).tolist()
            for _ in range(args.shared_prefix)] if args.shared_prefix else []
    prompts, budgets = [], []
    for i in range(args.requests):
        if args.mixed:
            n = int(rng.randint(args.min_prompt_len, args.prompt_len + 1))
            g = int(rng.randint(max(1, args.gen // 4), args.gen + 1))
        else:
            n, g = args.prompt_len, args.gen
        if fams:
            fam = fams[i % len(fams)]
            tail = max(n - len(fam), 1)
            prompts.append(fam + rng.randint(1, vocab, size=tail).tolist())
        else:
            prompts.append(rng.randint(1, vocab, size=n).tolist())
        budgets.append(g)
    return prompts, budgets


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", choices=("auto", "static", "continuous"),
                    default="auto",
                    help="auto: continuous when the arch's cache is pageable "
                         "(dense/GQA/MoE), else the static contiguous path")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of requests (static: also the batch size)")
    ap.add_argument("--batch", type=int, default=0,
                    help="static batch size / continuous max_slots "
                         "(0 -> min(requests, 8))")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--min-prompt-len", type=int, default=4)
    ap.add_argument("--mixed", action="store_true",
                    help="mixed prompt lengths and token budgets")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="F",
                    help="draw prompts from F shared prefix families "
                         "(0: every prompt independent)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix cache: share KV pages across "
                         "requests with common prompt prefixes")
    ap.add_argument("--cache-eviction", choices=("lru", "none"),
                    default="lru")
    ap.add_argument("--attn-backend", choices=("auto", "reference", "pallas"),
                    default="auto",
                    help="paged-attention backend for the continuous engine: "
                         "reference = XLA gather+attend, pallas = fused "
                         "paged-attention decode kernel (interpret mode on "
                         "CPU); auto picks pallas exactly on TPU")
    ap.add_argument("--kv-dtype", choices=("bf16", "int8"), default="bf16",
                    help="paged-KV storage dtype: int8 stores absmax-"
                         "quantized pages + per-token scale pools and "
                         "dequantizes inside the attend (half the decode "
                         "HBM bytes); --verify then checks the bounded-"
                         "error + high-margin dual gate instead of exact "
                         "token match")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=0,
                    help="per-step prefill token budget: long prompts split "
                         "into page-aligned chunks that interleave with "
                         "decode steps (0 = one monolithic prefill per "
                         "admission)")
    ap.add_argument("--speculate-tokens", type=int, default=0, metavar="K",
                    help="speculative decoding: draft up to K tokens per "
                         "slot from the request's own history (n-gram "
                         "prompt lookup) and verify them in one small-q "
                         "step; greedy accept keeps tokens identical to "
                         "non-speculative decode (0 = off)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="per-request length cap (0 -> fitted to workload)")
    ap.add_argument("--overlap", action="store_true",
                    help="drive the overlapped host/device pipeline "
                         "(Engine.pump(): step N+1's host plan staged while "
                         "step N runs on device) instead of the synchronous "
                         "step loop; tokens are identical either way")
    ap.add_argument("--verify", action="store_true",
                    help="check tokens against the static single-request path")
    ap.add_argument("--trace", metavar="PATH", default="",
                    help="write the request-lifecycle trace as Chrome-trace-"
                         "event JSON (open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-json", metavar="PATH", default="",
                    help="write the run metrics + full metrics-registry "
                         "snapshot as JSON")
    ap.add_argument("--jax-annotations", action="store_true",
                    help="wrap jitted prefill/decode steps in jax.profiler "
                         "TraceAnnotations (visible when a jax profiler "
                         "trace is also being captured)")
    ap.add_argument("--inject", metavar="SPEC", default="",
                    help="deterministic fault plan, e.g. "
                         "'nan_logits:rid=2,at=3;step_error:rid=0,at=2'; "
                         "kinds: nan_logits, step_error, pool_pressure, "
                         "client_disconnect, detok_stall (continuous engine "
                         "only; combine with --verify for the exact-survivor "
                         "chaos check)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    cfg = dataclasses.replace(cfg, remat="none")

    slots = args.batch or min(args.requests, 8)
    ps = args.page_size
    max_len = args.max_len or ((args.prompt_len + args.gen + ps - 1) // ps) * ps
    scfg = ServeConfig(page_size=ps, max_slots=slots, max_len=max_len,
                       prefix_cache=args.prefix_cache,
                       cache_eviction=args.cache_eviction,
                       attn_backend=args.attn_backend,
                       prefill_chunk_tokens=args.prefill_chunk_tokens,
                       kv_dtype=args.kv_dtype,
                       speculate_tokens=args.speculate_tokens)

    prompts, budgets = make_prompts(args, cfg.vocab)

    engine = args.engine
    if engine == "auto":
        # every registered cache family pages now (see models.cache_spec);
        # auto is continuous across the board
        ok, _ = build_model(cfg).supports_paged_decode()
        engine = "continuous" if ok else "static"
    if engine == "static" and args.prefix_cache:
        print("[serve] WARNING: --prefix-cache only applies to the "
              "continuous engine; the static path serves without it")
    if engine == "static" and args.attn_backend != "auto":
        print("[serve] WARNING: --attn-backend only applies to the "
              "continuous engine; the static path uses contiguous caches")
    if engine == "static" and args.kv_dtype != "bf16":
        print("[serve] WARNING: --kv-dtype only applies to the continuous "
              "engine's paged pool; the static path serves bf16")
    if engine == "static" and (args.trace or args.jax_annotations):
        print("[serve] WARNING: --trace/--jax-annotations only apply to the "
              "continuous engine; no trace will be written")
    if engine == "static" and args.speculate_tokens:
        print("[serve] WARNING: --speculate-tokens only applies to the "
              "continuous engine; the static path decodes one token a step")
    plan = None
    if args.inject:
        if engine != "continuous":
            raise SystemExit("[serve] --inject requires the continuous "
                             "engine (faults target its seams)")
        from ..serving import FaultPlan
        plan = FaultPlan.parse(args.inject, seed=args.seed)
    eng = None
    if engine == "continuous":
        tracer = Tracer(jax_annotations=args.jax_annotations)
        eng = Engine(cfg, scfg, seed=args.seed,   # init_params inside
                     tracer=tracer, faults=plan)
        params = eng.params
        results, metrics = eng.run_offline(prompts, budgets,
                                           overlap=args.overlap)
        tokens = [r.tokens for r in results]
        ttft = [r.ttft for r in results]
        print(f"[serve] attention backend: {metrics['attn_backend']} "
              f"(decode step p50 {metrics['decode_step_ms_p50']:.1f} ms)")
        if args.overlap:
            print(f"[serve] overlap: "
                  f"{eng.metrics.value('engine.overlap_staged')} plans "
                  f"staged, {eng.metrics.value('engine.overlap_used')} used, "
                  f"{eng.metrics.value('engine.overlap_dropped')} dropped "
                  f"(host meta build hidden behind device steps)")
        if args.speculate_tokens and not eng.spec_k:
            print(f"[serve] WARNING: speculation disabled for {cfg.name}: "
                  f"cache family {eng.spec.describe()} has no paged small-q "
                  f"verify step; serving non-speculatively")
        elif eng.spec_k:
            print(f"[serve] speculation: K={eng.spec_k}, "
                  f"{metrics['spec_proposed']} drafted, "
                  f"{metrics['spec_accepted']} accepted "
                  f"(accept rate {metrics['spec_accept_rate']:.2f})")
        if args.prefill_chunk_tokens:
            print(f"[serve] chunked prefill: budget "
                  f"{scfg.chunk_tokens} tokens, "
                  f"{metrics['chunked_prefill_steps']} continuation chunks, "
                  f"padding waste {metrics['prefill_padding_waste']:.2f}, "
                  f"decode stall max "
                  f"{metrics['decode_stall_ms_max']:.1f} ms")
        print(f"[serve] {cfg.name} continuous: {metrics['n_requests']} reqs, "
              f"{metrics['new_tokens']} toks in {metrics['wall_s']*1e3:.1f} ms "
              f"({metrics['tokens_per_s']:.1f} tok/s, "
              f"{metrics['requests_per_s']:.2f} req/s); "
              f"latency p50 {metrics['latency_p50_s']*1e3:.1f} / "
              f"p95 {metrics['latency_p95_s']*1e3:.1f} ms; "
              f"ttft p50 {np.percentile(ttft, 50)*1e3:.1f} ms")
        if args.prefix_cache:
            print(f"[serve] prefix cache: {metrics['cached_tokens']}/"
                  f"{metrics['prompt_tokens']} prompt tokens served from "
                  f"cache (hit rate {metrics['cache_hit_rate']:.2f}, "
                  f"prefilled {metrics['prefill_tokens']})")
    else:
        from ..models.registry import init_params
        import jax
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        tokens, metrics = generate_static(cfg, params, prompts, budgets, scfg,
                                          batch_size=slots, seed=args.seed)
        print(f"[serve] {cfg.name} static(batch={slots}): "
              f"{metrics['n_requests']} reqs, {metrics['new_tokens']} toks in "
              f"{metrics['wall_s']*1e3:.1f} ms "
              f"({metrics['tokens_per_s']:.1f} tok/s)")
    print("[serve] sample generations:", [t[:8] for t in tokens[:2]])

    # write artifacts before --verify so a failed verify still leaves the
    # trace around for diagnosis
    if args.trace and eng is not None:
        eng.tracer.save(args.trace)
        print(f"[serve] trace: {len(eng.tracer.events)} events -> "
              f"{args.trace} (load in https://ui.perfetto.dev)")
    if args.metrics_json:
        out = {"arch": cfg.name, "engine": engine, "metrics": metrics}
        if eng is not None:
            out["registry"] = eng.metrics_snapshot()
        with open(args.metrics_json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"[serve] metrics -> {args.metrics_json}")

    if plan is not None:
        fired = [f.describe() for f in plan.faults if f.fired]
        print(f"[serve] chaos: {len(fired)}/{len(plan.faults)} planned "
              f"faults fired; quarantined="
              f"{eng.metrics.value('engine.quarantined')} cancelled="
              f"{eng.metrics.value('engine.cancelled')} pages_scrubbed="
              f"{eng.metrics.value('pool.pages_scrubbed')}")

    if args.verify and plan is not None:
        if args.kv_dtype == "int8":
            raise SystemExit("[serve] --inject --verify needs the token-"
                             "exact bf16 path; int8 verify is a bounded-"
                             "error gate")
        expected = {}      # rid -> substring expected in the terminal error
        for f in plan.faults:
            if f.kind in ("nan_logits", "step_error") and f.rid >= 0:
                expected[f.rid] = f.kind
            elif f.kind == "client_disconnect" and f.rid >= 0:
                expected[f.rid] = "cancelled"
        ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                                 batch_size=1, seed=args.seed)
        bad = []
        for why in plan.unfired():     # already human-readable descriptions
            bad.append(f"planned fault never fired: {why}")
        for i, res in enumerate(results):
            if i in expected:
                if not res.failed or expected[i] not in (res.error or ""):
                    bad.append(f"request {i}: expected terminal "
                               f"{expected[i]!r}, got error={res.error!r}")
                elif res.tokens != ref[i][:len(res.tokens)]:
                    bad.append(f"request {i}: partial tokens are not a "
                               f"prefix of the clean baseline")
            elif res.failed:
                bad.append(f"request {i}: survivor failed: {res.error!r}")
            elif res.tokens != ref[i]:
                bad.append(f"request {i}: survivor tokens diverge from the "
                           f"fault-free baseline")
        if not eng.pool.conservation_ok():
            bad.append("page-pool conservation violated after drain")
        if bad:
            for why in bad:
                print(f"[serve] CHAOS VERIFY FAILED: {why}")
            raise SystemExit(f"[serve] CHAOS VERIFY FAILED "
                             f"({len(bad)} violations)")
        n_surv = len(results) - len(expected)
        print(f"[serve] chaos verify OK: {n_surv} survivors byte-identical "
              f"to the fault-free baseline, {len(expected)} targeted "
              f"requests quarantined with clean terminals, pool conserved")
        return tokens

    if args.verify and args.kv_dtype == "int8" and engine == "continuous":
        # quantized pages are not token-exact vs the bf16 static baseline;
        # the contract is the bounded-error + high-margin dual gate
        from ..serving import dual_gate_verify, format_report
        report = dual_gate_verify(cfg, scfg, params, prompts, tokens,
                                  attn_backend=scfg.attn_backend)
        print(format_report(report))
        if not report["ok"]:
            raise SystemExit("[serve] QUANT VERIFY FAILED: max logit err "
                             f"{report['max_logit_err']:.4f} (tol "
                             f"{report['tol']:.4f}), "
                             f"{report['high_margin_mismatches']} high-"
                             "margin mismatches, "
                             f"{report['replay_failures']} replay failures")
        print(f"[serve] verify OK: dual gate passed for {len(tokens)} "
              "requests (bounded logit error + high-margin greedy match)")
        return tokens

    if args.verify:
        lens = {len(p) for p in prompts}
        length_bound = cfg.family in ("ssm", "hybrid") or cfg.sliding_window
        if engine == "static" and length_bound and len(lens) > 1 and slots > 1:
            # recurrent state absorbs pad tokens and the sliding-window ring
            # is filled from the padded sequence end, so batched static
            # output is approximate for mixed lengths — exact comparison
            # would be unfair
            print("[serve] verify skipped: batched static serving of mixed-"
                  "length prompts is approximate for recurrent/sliding-"
                  "window families (padding enters the state/ring); rerun "
                  "with --batch 1")
            return tokens
        ref, _ = generate_static(cfg, params, prompts, budgets, scfg,
                                 batch_size=1, seed=args.seed)
        bad = [i for i, (a, b) in enumerate(zip(tokens, ref)) if a != b]
        if bad:
            raise SystemExit(f"[serve] VERIFY FAILED for requests {bad}")
        print(f"[serve] verify OK: {len(tokens)} requests match the "
              f"single-request static baseline exactly")
    return tokens


if __name__ == "__main__":
    main()
