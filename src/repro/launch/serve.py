"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch, reduced as make_reduced
from ..models.registry import build_model, init_cache, init_params
from ..models.steps import make_serve_step


def pad_cache_to(cache, max_len, model, cfg):
    """Grow the prefill cache's sequence dim to max_len (zero-padded)."""
    fresh = init_cache(cfg, cache["pos"].shape[0], max_len)

    def merge(f, c):
        if f.shape == c.shape:
            return c
        pad = [(0, fs - cs) for fs, cs in zip(f.shape, c.shape)]
        return jnp.pad(c, pad)
    return jax.tree.map(merge, fresh, cache)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    cfg = dataclasses.replace(cfg, remat="none")
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    B = args.batch
    toks = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)
    if cfg.enc_dec:
        batch = {"frames": jax.random.normal(
            key, (B, args.prompt_len, cfg.frontend_dim), jnp.bfloat16),
            "tokens": toks}
    elif cfg.n_image_tokens:
        batch = {"tokens": toks,
                 "image_embeds": jax.random.normal(
                     key, (B, cfg.n_image_tokens, cfg.frontend_dim), jnp.bfloat16)}
    else:
        batch = {"tokens": toks}

    prefill = jax.jit(make_serve_step(cfg, None, "prefill"))
    decode = jax.jit(make_serve_step(cfg, None, "decode"))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    cache = pad_cache_to(cache, max_len, model, cfg)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    out_tokens = [np.asarray(nxt)]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        nxt, cache = decode(params, cache, nxt)
        out_tokens.append(np.asarray(nxt))
    jax.block_until_ready(nxt)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"[serve] {cfg.name}: prefill {args.prompt_len} tok x{B} in "
          f"{t_prefill*1e3:.1f} ms; {args.gen-1} decode steps in "
          f"{t_decode*1e3:.1f} ms ({(args.gen-1)*B/max(t_decode,1e-9):.1f} tok/s)")
    print("[serve] sample generations:", gen[:2, :8].tolist())
    return gen


if __name__ == "__main__":
    main()
