import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the production
meshes, with ShapeDtypeStruct stand-ins (zero allocation), and record the
memory / cost / collective analysis that feeds EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from ..configs import ARCHS, SHAPES, get_arch, get_shape, supports   # noqa: E402
from ..models.params import sharded_bytes   # noqa: E402
from ..models.registry import build_model, input_defs   # noqa: E402
from ..models.steps import (abstract_serve_args, abstract_train_args,   # noqa: E402
                            make_serve_step, make_train_step,
                            serve_shardings, train_shardings)
from ..optim import OptConfig, opt_state_defs   # noqa: E402
from . import analysis, hlo_cost   # noqa: E402
from .mesh import make_production_mesh   # noqa: E402


def dryrun_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
                opt_name: str = "adamw", remat: str = None,
                unroll: bool = False, overrides: dict = None,
                engine: str = "pjit", reduce_mode: str = "allreduce",
                verbose: bool = True) -> dict:
    import dataclasses
    cfg = get_arch(arch_name)
    # the compiled program keeps its layer scans (realistic compile times &
    # buffers); roofline terms come from the loop-aware HLO walker
    # (launch.hlo_cost), which multiplies while bodies by their trip counts.
    cfg = dataclasses.replace(cfg, unroll=unroll, **(overrides or {}))
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = get_shape(shape_name)
    ok, why = supports(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skip", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    opt_cfg = OptConfig(name=opt_name)
    t0 = time.perf_counter()

    if shape.kind == "train":
        step = make_train_step(cfg, mesh, opt_cfg, engine=engine,
                               reduce_mode=reduce_mode)
        args = abstract_train_args(cfg, shape, mesh, opt_cfg)
        shards = train_shardings(cfg, shape, mesh, opt_cfg)
        fn = jax.jit(step, in_shardings=shards,
                     out_shardings=(shards[0], shards[1], None))
    elif shape.kind == "prefill":
        step = make_serve_step(cfg, mesh, "prefill")
        p_sh, b_sh = serve_shardings(cfg, shape, mesh)
        args = abstract_serve_args(cfg, shape, mesh)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh), out_shardings=None)
    else:  # decode
        step = make_serve_step(cfg, mesh, "decode")
        p_sh, c_sh, t_sh = serve_shardings(cfg, shape, mesh)
        args = abstract_serve_args(cfg, shape, mesh)
        fn = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                     out_shardings=(t_sh, c_sh))

    with jax.sharding.set_mesh(mesh):
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    hc = hlo_cost.module_cost(hlo)
    flops_dev = hc.flops
    bytes_dev = hc.bytes
    roof = analysis.roofline(flops_dev, bytes_dev, hc.wire_bytes)
    mflops = analysis.model_flops(cfg, shape)
    # "with Pallas flash attention" variant: score blocks stay in VMEM
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    score_traffic = analysis.attn_score_traffic(cfg, shape, mesh_axes)
    roof_flash = analysis.roofline(
        flops_dev, max(bytes_dev - score_traffic, flops_dev / 500.0),
        hc.wire_bytes)

    # analytic steady-state memory (CPU buffer assignment over-reports: XLA:CPU
    # schedules for thread parallelism, not memory; see EXPERIMENTS.md)
    model = build_model(cfg)
    pdefs = model.param_defs()
    p_bytes = sharded_bytes(pdefs, mesh)
    if shape.kind == "train":
        o_bytes = sharded_bytes(opt_state_defs(pdefs, opt_cfg), mesh)
        g_bytes = p_bytes                           # bf16 grad transient (n_micro=1)
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                dp *= mesh.shape[a]
        n_layers = (cfg.n_layers if not cfg.enc_dec
                    else cfg.n_enc_layers + cfg.n_dec_layers)
        resid = (n_layers * (shape.global_batch // dp) * shape.seq_len
                 * cfg.d_model * 2)                 # remat residuals (bf16)
        if cfg.seq_parallel and "model" in mesh.shape:
            resid //= mesh.shape["model"]           # SP shards the residuals
        analytic = p_bytes + o_bytes + g_bytes + resid
    else:
        c_bytes = (sharded_bytes(model.cache_defs(shape.global_batch,
                                                  shape.seq_len), mesh)
                   if shape.kind == "decode" else 0)
        analytic = p_bytes + c_bytes
        o_bytes = 0
    analytic_gb = analytic / 1e9

    per_dev_bytes = {
        "argument": mem.argument_size_in_bytes,
        "output": mem.output_size_in_bytes,
        "temp": mem.temp_size_in_bytes,
        "alias": mem.alias_size_in_bytes,
        "code": mem.generated_code_size_in_bytes,
    }
    live = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "per_device_bytes": per_dev_bytes,
        "live_bytes_per_device": live,
        "analytic_bytes_per_device": analytic,
        "params_bytes_per_device": p_bytes,
        "opt_bytes_per_device": o_bytes,
        "fits_16GB": bool(analytic < 16e9),
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "xla_cost_flops_loop_blind": float(cost.get("flops", 0.0)),
        "collectives": {"ops": {k: {"count": v,
                                    "wire_bytes": hc.coll_wire_by_op[k]}
                                for k, v in hc.coll_counts.items()},
                        "total_bytes": hc.coll_bytes,
                        "total_wire_bytes": hc.wire_bytes},
        "roofline": roof,
        "attn_score_bytes_per_device": score_traffic,
        "roofline_flash": roof_flash,
        "model_flops_global": mflops,
        "model_flops_per_device": mflops / n_chips,
        "useful_flops_ratio": (mflops / n_chips) / flops_dev if flops_dev else 0.0,
    }
    if verbose:
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "status", "compile_s",
                           "analytic_bytes_per_device", "fits_16GB")}, indent=None))
        print("  memory_analysis:", per_dev_bytes)
        print("  cost(loop-aware): flops/dev=%.3e bytes/dev=%.3e" % (flops_dev, bytes_dev))
        print("  collectives:", json.dumps(rec["collectives"]["ops"]))
        print("  roofline:", json.dumps(roof))
        print("  useful_flops_ratio: %.3f" % rec["useful_flops_ratio"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multipod]

    results = []
    for a in archs:
        for s in shapes:
            for mp in meshes:
                try:
                    rec = dryrun_cell(a, s, multi_pod=mp, opt_name=args.opt,
                                      remat=args.remat,
                                      unroll=args.unroll)
                except Exception as e:  # a failed cell is a bug: record it
                    traceback.print_exc()
                    rec = {"arch": a, "shape": s,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                results.append(rec)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = os.path.join(
                        args.out, f"{a}__{s}__{rec['mesh']}.json")
                    with open(fn, "w") as f:
                        json.dump(rec, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skip, {n_err} error "
          f"of {len(results)} cells ==")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
