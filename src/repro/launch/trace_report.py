"""Offline analyzer for serving traces written by ``serve --trace``.

  PYTHONPATH=src python -m repro.launch.trace_report trace.json
  PYTHONPATH=src python -m repro.launch.trace_report trace.json --validate

Reads the Chrome-trace-event JSON emitted by ``serving.telemetry.Tracer``
and prints:

* a time-in-phase breakdown over the engine step track — prefill /
  chunked-prefill / restore / decode device time, the host-scheduling gap
  (wall clock not covered by any step span), and the decode-stall share
  (non-decode steps that ran while decode-ready slots were parked behind
  them, i.e. step spans carrying ``decode_waiting=True``);
* a per-request table (TTFT, total latency, TPOT, tokens, prefill chunks,
  preemptions) read from each request's terminal ``finished`` instant;
* a failure summary — terminal errors (quarantine, cancel, deadline) and
  rejections (admission sheds, no_budget) counted by cause — when any
  request did not finish cleanly.

``--validate`` additionally runs the well-formedness checker
(``telemetry.validate_trace``: monotonic finite timestamps, proper span
nesting per track, every admitted request reaching a terminal event) and
exits nonzero if anything is off — CI runs it on every trace artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from ..serving.telemetry import ENGINE_PID, HOST_TID, REQUEST_PID, \
    percentile, validate_trace

# engine phases in display order; anything else lands in "other".
# "verify" is the speculative small-q decode step (draft + bonus token in
# one launch) — it *serves* decode-ready slots, so the stall computation
# below exempts it exactly like plain decode
PHASES = ("prefill", "prefill_chunk", "restore", "decode", "verify")
# overlapped host-pipeline phases (ENGINE_PID, tid=HOST_TID), Engine.pump()
HOST_PHASES = ("dispatch", "stage", "collect")


def load(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def phase_breakdown(trace: Dict[str, Any]) -> Dict[str, Any]:
    """Time-in-phase sums (seconds) over the engine step track.

    ``wall_s`` spans first event start to last event end; ``host_s`` is the
    wall time no step span covers (scheduler decisions, admission matching,
    host-side bookkeeping); ``stall_s`` is the part of non-decode phases
    that ran with decode-ready slots waiting."""
    spans = [e for e in trace.get("traceEvents", [])
             if e.get("ph") == "X" and e.get("pid") == ENGINE_PID
             and e.get("tid", 0) == 0]    # step track only: the overlapped
                                          # host pipeline reports separately
    per = {p: 0.0 for p in PHASES}
    counts = {p: 0 for p in PHASES}
    stall = other = 0.0
    lo, hi = float("inf"), 0.0
    for e in spans:
        dur = e.get("dur", 0.0) / 1e6
        name = e.get("name")
        lo = min(lo, e["ts"] / 1e6)
        hi = max(hi, (e["ts"] + e.get("dur", 0.0)) / 1e6)
        if name in per:
            per[name] += dur
            counts[name] += 1
        else:
            other += dur
        if name not in ("decode", "verify") \
                and e.get("args", {}).get("decode_waiting"):
            stall += dur
    wall = (hi - lo) if spans else 0.0
    stepped = sum(per.values()) + other
    return {"wall_s": wall, "per_phase_s": per, "counts": counts,
            "other_s": other, "host_s": max(wall - stepped, 0.0),
            "stall_s": stall, "n_steps": len(spans)}


def host_pipeline(trace: Dict[str, Any]) -> Dict[str, Any]:
    """Overlapped host-pipeline sums (``Engine.pump()``): time in the
    dispatch / stage / collect halves on the (ENGINE_PID, HOST_TID) track.
    Empty dict when the run was synchronous (no host track emitted)."""
    per = {p: 0.0 for p in HOST_PHASES}
    counts = {p: 0 for p in HOST_PHASES}
    for e in trace.get("traceEvents", []):
        if e.get("ph") == "X" and e.get("pid") == ENGINE_PID \
                and e.get("tid") == HOST_TID and e.get("name") in per:
            per[e["name"]] += e.get("dur", 0.0) / 1e6
            counts[e["name"]] += 1
    if not any(counts.values()):
        return {}
    return {"per_phase_s": per, "counts": counts}


def request_rows(trace: Dict[str, Any]) -> List[Dict[str, Any]]:
    rows = []
    for e in trace.get("traceEvents", []):
        if e.get("ph") == "i" and e.get("name") == "finished" \
                and e.get("pid") == REQUEST_PID:
            rows.append({"rid": e.get("tid"), **e.get("args", {})})
    rows.sort(key=lambda r: r["rid"])
    return rows


def failure_summary(trace: Dict[str, Any]) -> Dict[str, int]:
    """Terminal failures by cause: ``finished`` instants carrying an
    ``error`` arg (quarantine/cancel/deadline) and ``rejected`` instants by
    reason (admission sheds, no_budget, deadline_exceeded in queue)."""
    counts: Dict[str, int] = {}
    for e in trace.get("traceEvents", []):
        if e.get("ph") != "i" or e.get("pid") != REQUEST_PID:
            continue
        args = e.get("args", {})
        if e.get("name") == "finished" and args.get("error"):
            key = f"failed:{args['error']}"
        elif e.get("name") == "rejected":
            key = f"rejected:{args.get('reason', 'unknown')}"
        else:
            continue
        counts[key] = counts.get(key, 0) + 1
    return counts


def report(trace: Dict[str, Any]) -> str:
    out = []
    bd = phase_breakdown(trace)
    wall = bd["wall_s"] or 1e-12
    out.append(f"engine steps: {bd['n_steps']}   "
               f"wall {bd['wall_s']*1e3:.1f} ms")
    out.append("time in phase:")
    for p in PHASES:
        s = bd["per_phase_s"][p]
        out.append(f"  {p:<14} {s*1e3:9.1f} ms  {s/wall*100:5.1f}%  "
                   f"({bd['counts'][p]} steps)")
    if bd["other_s"]:
        out.append(f"  {'other':<14} {bd['other_s']*1e3:9.1f} ms  "
                   f"{bd['other_s']/wall*100:5.1f}%")
    out.append(f"  {'host-sched':<14} {bd['host_s']*1e3:9.1f} ms  "
               f"{bd['host_s']/wall*100:5.1f}%  (wall not in any step)")
    out.append(f"  {'decode-stall':<14} {bd['stall_s']*1e3:9.1f} ms  "
               f"{bd['stall_s']/wall*100:5.1f}%  "
               f"(non-decode steps with decode ready)")

    hp = host_pipeline(trace)
    if hp:
        out.append("host pipeline (overlapped dispatch/stage/collect):")
        for p in HOST_PHASES:
            s = hp["per_phase_s"][p]
            out.append(f"  {p:<14} {s*1e3:9.1f} ms  {s/wall*100:5.1f}%  "
                       f"({hp['counts'][p]} spans)")

    rows = request_rows(trace)
    if rows:
        ttfts = [r.get("ttft_s", 0.0) for r in rows]
        tpots = [r.get("tpot_s", 0.0) for r in rows]
        out.append("")
        out.append(f"requests: {len(rows)}   "
                   f"ttft p50 {percentile(ttfts, 50)*1e3:.1f} / "
                   f"p95 {percentile(ttfts, 95)*1e3:.1f} ms   "
                   f"tpot p50 {percentile(tpots, 50)*1e3:.2f} ms")
        out.append(f"  {'rid':>4} {'ttft_ms':>9} {'finish_ms':>10} "
                   f"{'tpot_ms':>8} {'toks':>5} {'chunks':>6} {'preempt':>7}")
        for r in rows:
            out.append(
                f"  {r['rid']:>4} {r.get('ttft_s', 0.0)*1e3:>9.1f} "
                f"{r.get('finish_s', 0.0)*1e3:>10.1f} "
                f"{r.get('tpot_s', 0.0)*1e3:>8.2f} "
                f"{r.get('n_tokens', 0):>5} "
                f"{r.get('n_prefill_chunks', 0):>6} "
                f"{r.get('n_preemptions', 0):>7}")

    failures = failure_summary(trace)
    if failures:
        total = sum(failures.values())
        detail = ", ".join(f"{k}={v}" for k, v in sorted(failures.items()))
        out.append("")
        out.append(f"failures: {total} requests did not finish cleanly "
                   f"({detail})")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome-trace JSON from serve --trace")
    ap.add_argument("--validate", action="store_true",
                    help="run the well-formedness checker; exit nonzero on "
                         "any problem")
    args = ap.parse_args(argv)

    trace = load(args.trace)
    print(report(trace))
    if args.validate:
        problems = validate_trace(trace)
        if problems:
            print(f"\n[trace_report] INVALID trace "
                  f"({len(problems)} problems):", file=sys.stderr)
            for p in problems:
                print(f"  - {p}", file=sys.stderr)
            return 1
        print(f"\n[trace_report] trace valid "
              f"({len(trace.get('traceEvents', []))} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
