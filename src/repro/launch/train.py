"""End-to-end training driver.

Runs any assigned architecture (reduced or full geometry) with either engine on
the available devices, with checkpoint/restart fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 50 --engine mapreduce --reduce-mode hierarchical
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch, reduced as make_reduced
from ..data.pipeline import Prefetcher, token_batches
from ..models.params import specs_tree
from ..models.registry import build_model, init_params
from ..models.steps import make_train_step
from ..optim import OptConfig, init_opt_state, opt_state_defs
from ..runtime import LoopConfig, TrainLoop
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0, help="override n_layers")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--engine", default="pjit", choices=["pjit", "mapreduce"])
    ap.add_argument("--reduce-mode", default="allreduce")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--data-parallel", type=int, default=0,
                    help="#devices for the data axis (default: all)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    over = {}
    if args.layers:
        over["n_layers"] = args.layers
    if args.d_model:
        over["d_model"] = args.d_model
    over["remat"] = "none"
    cfg = dataclasses.replace(cfg, **over)

    ndev = len(jax.devices())
    dp = args.data_parallel or ndev
    need_mesh = ndev > 1 or args.engine == "mapreduce"
    mesh = make_host_mesh(data=dp, model=ndev // dp) if need_mesh else None

    opt_cfg = OptConfig(name=args.opt, lr=args.lr, schedule="linear_warmup_cosine",
                        warmup=max(1, args.steps // 10), total_steps=args.steps)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt_state = init_opt_state(params, opt_cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, engine={args.engine}, "
          f"devices={ndev}, batch={args.global_batch}x{args.seq_len}")

    step_fn = make_train_step(cfg, mesh, opt_cfg, engine=args.engine,
                              reduce_mode=args.reduce_mode, n_micro=args.n_micro)
    jitted = jax.jit(step_fn)

    def loop_step(state, batch):
        params, opt_state = state
        b = {"tokens": jnp.asarray(batch["tokens"])}
        params, opt_state, metrics = jitted(params, opt_state, b)
        return (params, opt_state), metrics

    data = token_batches(cfg.vocab, args.global_batch, args.seq_len,
                         seed=args.seed)
    loop = TrainLoop(loop_step, (params, opt_state), data,
                     LoopConfig(ckpt_dir=args.ckpt_dir,
                                ckpt_every=args.ckpt_every, log_every=5))
    out = loop.run(args.steps)
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"after {out['steps']} steps")
    return out


if __name__ == "__main__":
    main()
