"""Minitron-4B [arXiv:2407.14679; hf] — pruned Nemotron: GQA, squared-relu plain MLP."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    mlp_gated=False,          # nemotron family: plain MLP with relu^2
    act="relu2",
    qkv_bias=False,
    rope_theta=1e4,
    norm="layernorm",
    source="arXiv:2407.14679; hf:nvidia/Minitron-4B-Base",
)
