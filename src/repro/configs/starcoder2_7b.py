"""StarCoder2-7B [arXiv:2402.19173; hf] — dense GQA + RoPE code LM."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    mlp_gated=False,          # starcoder2 uses a plain gelu MLP (c_fc/c_proj)
    act="gelu",
    qkv_bias=True,            # starcoder2 uses bias on attention + mlp
    rope_theta=1e5,
    sliding_window=4096,      # starcoder2 attends within a 4k sliding window
    norm="layernorm",
    source="arXiv:2402.19173; hf:bigcode/starcoder2-7b",
)
