"""The paper's own network: MNIST deep-belief autoencoder (Hinton 784-1000-500-250-30)
pre-trained layer-wise with RBM CD-1, then unrolled + fine-tuned (Figs. 6/10/12); the
classifier variant appends a 10-way softmax (Figs. 7/9/11)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mnist-dbn",
    family="dbn",
    n_layers=4,
    d_model=784,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=10,
    norm="none",
    source="paper §IV + Hinton & Salakhutdinov 2006",
)

# layer widths of the stack (input -> code)
STACK = (784, 1000, 500, 250, 30)
N_CLASSES = 10
