"""LLaVA-NeXT-34B backbone [hf:llava-hf; unverified] — dense GQA LM; vision frontend
stubbed as precomputed patch embeddings + projector (anyres tiling out of backbone scope)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    mlp_gated=True,
    act="silu",
    qkv_bias=False,
    rope_theta=5e6,
    norm="rmsnorm",
    n_image_tokens=576,
    frontend_dim=1024,        # CLIP-L patch-embedding dim (stub)
    source="hf:llava-hf/llava-v1.6-34b-hf; unverified",
)
