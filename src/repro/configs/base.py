"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload shape is a
``ShapeConfig``.  A (arch x shape) pair is a *cell* of the dry-run / roofline matrix.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

BF16 = "bfloat16"


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- MLP ---
    mlp_gated: bool = True
    act: str = "silu"                # silu | gelu | relu | relu2

    # --- attention ---
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0
    sliding_window: int = 0          # 0 = full attention

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0           # leading dense layers (deepseek style)
    d_ff_dense: int = 0              # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- MLA (deepseek) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- hybrid (recurrentgemma / griffin) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0                   # RG-LRU width
    conv_width: int = 4
    attn_window: int = 0             # local-attention window in hybrid blocks

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_n_groups: int = 1

    # --- encoder-decoder ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    cross_attn: bool = False

    # --- modality frontends (stubs per assignment) ---
    n_image_tokens: int = 0          # vlm: number of patch-embedding tokens
    frontend_dim: int = 0            # dim of precomputed patch/frame embeddings
    audio_frontend: bool = False     # audio: encoder consumes frame embeddings

    # --- misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = BF16
    remat: str = "full"              # full | dots | none
    unroll: bool = False             # unroll layer/chunk scans (dry-run accounting)
    loss_chunk: int = 512            # CE loss sequence-chunk size
    attn_q_block: int = 512          # chunked-attention query-block size
    pad_heads_to: int = 0            # pad q-heads for TP divisibility (perf knob;
                                     # padded heads are zero-inert at deploy)
    seq_parallel: bool = False       # Megatron-SP style: residual stream (and
                                     # remat residuals) sequence-sharded over
                                     # the model axis between blocks
    norm_fp32: bool = True           # False: norm elementwise math stays bf16
                                     # (fp32 only for mean/var stats) so the
                                     # TP gradient all-reduces stay bf16
    source: str = ""                 # provenance note

    # ---------------- derived ----------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def n_heads_padded(self) -> int:
        return max(self.pad_heads_to, self.n_heads) if self.pad_heads_to else self.n_heads

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, 256)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state does not grow linearly with an *unbounded* full-
        attention KV cache (SSM state / bounded local window)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:        # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # -------- parameter counts (for MODEL_FLOPS = 6 N D) --------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count. ``active_only`` counts MoE experts at top_k."""
        d, v = self.d_model, self.vocab_padded
        n = v * d                                   # embedding
        if not self.tie_embeddings:
            n += d * v                              # lm head

        def attn_params() -> int:
            if self.use_mla:
                h = self.n_heads
                qd = h * (self.nope_head_dim + self.rope_head_dim)
                p = 0
                if self.q_lora_rank:
                    p += d * self.q_lora_rank + self.q_lora_rank * qd
                else:
                    p += d * qd
                p += d * (self.kv_lora_rank + self.rope_head_dim)
                p += self.kv_lora_rank * self.n_heads * (self.nope_head_dim + self.v_head_dim)
                p += self.n_heads * self.v_head_dim * d
                return p
            hd = self.head_dim_
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o

        def mlp_params(ff: int) -> int:
            m = (3 if self.mlp_gated else 2) * d * ff
            return m

        if self.family == "ssm":
            # mamba2 block: in_proj (z,x,B,C,dt) + conv + A,D + norm + out_proj
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_n_heads
            proj_in = d * (2 * di + 2 * self.ssm_n_groups * ns + nh)
            conv = self.conv_width * (di + 2 * self.ssm_n_groups * ns)
            block = proj_in + conv + 2 * nh + di + di * d + d
            return n + self.n_layers * block

        if self.family == "hybrid":
            pat = self.block_pattern or ("rec",)
            n_attn = sum(1 for i in range(self.n_layers) if pat[i % len(pat)] == "attn")
            n_rec = self.n_layers - n_attn
            dr = self.d_rnn or d
            rec = d * dr * 2 + dr * d + self.conv_width * dr + 4 * dr  # branches+proj+conv+lru
            blk_mlp = mlp_params(self.d_ff)
            return n + n_attn * (attn_params() + blk_mlp) + n_rec * (rec + blk_mlp)

        layers = self.n_layers if not self.enc_dec else (self.n_enc_layers + self.n_dec_layers)
        per_layer = attn_params()
        if self.enc_dec:
            per_layer += attn_params() // 2          # rough: cross-attn on decoder half
        if self.is_moe:
            n_dense = self.first_k_dense
            n_moe = self.n_layers - n_dense
            e = self.top_k if active_only else self.n_experts
            moe_ff = e * mlp_params(self.d_ff_expert)
            moe_ff += self.n_shared_experts * mlp_params(self.d_ff_expert)
            router = self.d_model * self.n_experts
            total = n + self.n_layers * per_layer
            total += n_dense * mlp_params(self.d_ff_dense or self.d_ff)
            total += n_moe * (moe_ff + router)
            return total
        return n + layers * (per_layer + mlp_params(self.d_ff))


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching serving knobs (consumed by ``repro.serving``).

    The engine decodes at a single fixed batch shape (``max_slots``) and
    prefills at a small set of bucketed prompt lengths, so the whole serving
    loop compiles a handful of programs regardless of traffic mix.  KV lives
    in a paged pool: ``num_pages`` fixed-size pages of ``page_size`` tokens,
    with physical page 0 reserved as a write sink for idle slots.
    """
    page_size: int = 16          # tokens per KV page
    max_slots: int = 8           # concurrent decode slots (fixed jit batch dim)
    max_len: int = 96            # per-request prompt + generation cap (tokens)
    num_pages: int = 0           # 0 -> auto, family-aware: max_slots *
                                 # table_width + 1 (see PagedKVPool)
    prefill_buckets: Tuple[int, ...] = ()   # () -> pow2 multiples of page_size
    eos_id: int = -1             # -1: no EOS; requests run to max_new tokens
    prefix_cache: bool = False   # radix-tree prompt-prefix KV sharing
    cache_eviction: str = "lru"  # lru | none (no eviction under pressure)
    enc_len: int = 16            # enc-dec: synthetic encoder frames per request
                                 # (fixed so results are batch-shape independent)
    attn_backend: str = "auto"   # paged-attention backend (models.attn_backend
                                 # registry): auto -> fused pallas kernel on
                                 # TPU, XLA reference gather+attend elsewhere
    prefill_chunk_tokens: int = 0  # per-step prefill token budget: 0 = one
                                 # monolithic (bucketed) prefill per admission;
                                 # > 0 = long prompts split into page-aligned
                                 # chunks of at most this many tokens that
                                 # interleave with decode steps (Sarathi-style)
    kv_dtype: str = "bf16"       # paged-KV storage dtype: bf16 (token-exact
                                 # vs static) or int8 (absmax-quantized pages
                                 # with per-token-per-head bf16 scales and
                                 # in-kernel dequant; parity contract becomes
                                 # bounded logit error + high-margin greedy
                                 # match, see serving/quant_verify)
    speculate_tokens: int = 0    # n-gram speculative decoding: draft length K
                                 # per verify step (0 = off).  Each step checks
                                 # K drafted tokens plus the usual next token
                                 # in one fixed-shape launch; accepted tokens
                                 # stay token-exact vs the non-speculative
                                 # greedy stream (serving/speculate)
    admission_control: bool = False  # deadline-aware shedding + mid-flight
                                 # deadline eviction (serving/admission).  Off:
                                 # deadlines attached to requests are inert
                                 # metadata, nothing is shed or evicted
    default_deadline_s: float = 0.0   # default total deadline applied to
                                 # requests that don't carry one (0 = none)
    default_ttft_deadline_s: float = 0.0  # default TTFT deadline (0 = none)

    def __post_init__(self):
        assert self.page_size > 0 and self.max_slots > 0
        assert self.max_len % self.page_size == 0, \
            "max_len must be a multiple of page_size (page-table geometry)"
        assert self.cache_eviction in ("lru", "none"), self.cache_eviction
        assert self.attn_backend in ("auto", "reference", "pallas"), \
            self.attn_backend
        assert self.prefill_chunk_tokens >= 0, self.prefill_chunk_tokens
        assert self.kv_dtype in ("bf16", "int8"), self.kv_dtype
        assert 0 <= self.speculate_tokens < self.page_size, \
            "speculate_tokens must fit inside one page (windowed-ring slack)"
        assert self.default_deadline_s >= 0, self.default_deadline_s
        assert self.default_ttft_deadline_s >= 0, self.default_ttft_deadline_s

    @property
    def chunk_tokens(self) -> int:
        """Effective page-aligned per-step prefill budget (0 = chunking off).

        An unaligned ``prefill_chunk_tokens`` is rounded down to a whole
        number of pages, never below one page — chunk boundaries always land
        on page boundaries so the radix cache can publish completed pages
        mid-prefill."""
        if not self.prefill_chunk_tokens:
            return 0
        return max(self.page_size,
                   (self.prefill_chunk_tokens // self.page_size)
                   * self.page_size)

    @property
    def pages_per_request(self) -> int:
        return self.max_len // self.page_size

    @property
    def total_pages(self) -> int:
        """Pool size for a plain token-addressable KV family (+1 reserved
        null page).  ``PagedKVPool.total_pages`` is the authoritative,
        family-aware figure — it caps the per-request table at the sliding-
        window ring horizon and widens it for the vlm image prefix."""
        # +1 for the reserved null page
        return self.num_pages or self.max_slots * self.pages_per_request + 1

    @property
    def buckets(self) -> Tuple[int, ...]:
        """Prefill length buckets (each a multiple of page_size, <= max_len).

        User-supplied buckets are rounded up to page multiples, clamped to
        max_len, and max_len itself is always present so every admissible
        prompt (< max_len) has a bucket."""
        if self.prefill_buckets:
            bs = {min(round_up(b, self.page_size), self.max_len)
                  for b in self.prefill_buckets}
            bs.add(self.max_len)
            return tuple(sorted(bs))
        out, b = [], self.page_size
        while b < self.max_len:
            out.append(b)
            b *= 2
        out.append(self.max_len)
        return tuple(out)

    def bucket_of(self, n: int) -> int:
        """Smallest prefill bucket covering ``n`` tokens."""
        for b in self.buckets:
            if b >= n:
                return b
        raise ValueError(f"prompt len {n} exceeds largest bucket "
                         f"{self.buckets[-1]}")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def supports(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether the (arch, shape) cell is runnable; else a skip reason."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention; arch is full-attention"
    return True, ""


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 2 if not cfg.block_pattern else len(cfg.block_pattern) or 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else cfg.n_kv_heads,
        head_dim=32 if cfg.head_dim else 0,
        d_ff=256,
        vocab=512,
        remat="none",
    )
    if cfg.sliding_window:
        small.update(sliding_window=32)   # window binds within CPU-size prompts
    if cfg.is_moe:
        small.update(n_experts=4, top_k=2, d_ff_expert=64,
                     n_shared_experts=min(cfg.n_shared_experts, 1),
                     first_k_dense=min(cfg.first_k_dense, 1), d_ff_dense=256)
    if cfg.use_mla:
        small.update(kv_lora_rank=32, q_lora_rank=64, rope_head_dim=16,
                     nope_head_dim=32, v_head_dim=32, head_dim=0)
    if cfg.family == "ssm":
        small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32, n_heads=1, n_kv_heads=0,
                     d_ff=0, head_dim=0)
    if cfg.family == "hybrid":
        small.update(d_rnn=128, attn_window=32, n_layers=len(cfg.block_pattern) or 3,
                     n_kv_heads=1, head_dim=32)
    if cfg.enc_dec:
        small.update(n_enc_layers=2, n_dec_layers=2, n_layers=2)
    if cfg.audio_frontend:
        small.update(frontend_dim=small["d_model"])
    if cfg.n_image_tokens:
        small.update(n_image_tokens=8, frontend_dim=64)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
