"""Config registry: one module per assigned architecture (+ the paper's own DBN)."""
from __future__ import annotations

from .base import (ArchConfig, ServeConfig, ShapeConfig, SHAPES,  # noqa: F401
                   supports, reduced)

from . import (  # noqa: E402
    starcoder2_7b,
    command_r_plus_104b,
    qwen2_0_5b,
    minitron_4b,
    dbrx_132b,
    deepseek_v2_236b,
    seamless_m4t_large_v2,
    llava_next_34b,
    recurrentgemma_2b,
    mamba2_780m,
    mnist_dbn,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        starcoder2_7b,
        command_r_plus_104b,
        qwen2_0_5b,
        minitron_4b,
        dbrx_132b,
        deepseek_v2_236b,
        seamless_m4t_large_v2,
        llava_next_34b,
        recurrentgemma_2b,
        mamba2_780m,
    )
}

MNIST_DBN = mnist_dbn.CONFIG


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]
