"""RecurrentGemma-2B [arXiv:2402.19427; hf] — Griffin: RG-LRU + local attention, 1:2."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,             # MQA
    head_dim=256,
    d_ff=7680,                # GeGLU expanded width (3 * d_model)
    vocab=256000,
    mlp_gated=True,
    act="gelu",
    qkv_bias=False,
    rope_theta=1e4,
    norm="rmsnorm",
    block_pattern=("rec", "rec", "attn"),   # 1 attention per 2 recurrent blocks
    d_rnn=2560,               # lru width
    conv_width=4,
    attn_window=2048,         # local sliding-window attention
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
)
