"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-plus; unverified] — dense GQA, no bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    mlp_gated=True,
    act="silu",
    qkv_bias=False,
    rope_theta=75e6,
    sliding_window=4096,      # interleaved local attention (modeled uniformly)
    norm="layernorm",
    tie_embeddings=True,      # cohere ties input/output embeddings
    source="hf:CohereForAI/c4ai-command-r-plus; unverified",
)
