"""SeamlessM4T-large-v2 backbone [arXiv:2308.11596; hf] — enc-dec transformer; audio
frontend stubbed (``input_specs`` provides precomputed frame embeddings)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,              # 24 enc + 24 dec of this geometry
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,            # MHA
    d_ff=8192,
    vocab=256206,
    mlp_gated=False,
    act="relu",
    qkv_bias=True,
    rope_theta=1e4,
    norm="layernorm",
    enc_dec=True,
    n_enc_layers=24,
    n_dec_layers=24,
    cross_attn=True,
    audio_frontend=True,
    frontend_dim=1024,
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large",
)
