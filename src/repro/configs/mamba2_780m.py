"""Mamba2-780M [arXiv:2405.21060; unverified] — attention-free SSD (state-space duality)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,                # attention-free
    n_kv_heads=0,
    d_ff=0,                   # no separate MLP; SSD block carries the capacity
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_n_groups=1,
    conv_width=4,
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2405.21060; state-spaces/mamba2-780m",
)
