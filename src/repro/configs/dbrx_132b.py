"""DBRX-132B [hf:databricks/dbrx-base; unverified] — fine-grained MoE, 16 experts top-4."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,               # per-expert ffn hidden
    vocab=100352,
    mlp_gated=True,
    act="silu",
    qkv_bias=False,
    rope_theta=5e5,
    norm="layernorm",
    n_experts=16,
    top_k=4,
    d_ff_expert=10752,
    capacity_factor=1.25,
    source="hf:databricks/dbrx-base; unverified",
)
