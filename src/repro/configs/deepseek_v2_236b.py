"""DeepSeek-V2 236B [arXiv:2405.04434; hf] — MLA (kv_lora=512) + 2 shared / 160 routed top-6."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,           # MLA: per-assignment annotation; realized via compressed KV
    d_ff=1536,                # routed-expert hidden
    vocab=102400,
    mlp_gated=True,
    act="silu",
    qkv_bias=False,
    rope_theta=1e4,
    norm="rmsnorm",
    # MoE
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1536,
    first_k_dense=1,
    d_ff_dense=12288,
    capacity_factor=1.25,
    # MLA
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2",
)
