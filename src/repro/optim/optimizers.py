"""Optimizers with mixed precision and mesh-sharded (ZeRO-style) states.

The fp32 master copy + moments are the paper's "reducer owns the weight" made
literal: each device owns a shard of the optimizer keyspace.  State sharding is
derived from the param defs: the fp32 states reuse the param's own sharding and
additionally shard a leading replicated dim over the ``data`` axis when divisible
(``zero`` logical axis), so optimizer memory/chip stays ~constant as pods grow.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.params import ParamDef, _is_def


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | sgdm | adafactor-lite
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    grad_clip: float = 1.0
    schedule: str = "const"      # const | cosine | linear_warmup_cosine
    warmup: int = 100
    total_steps: int = 10000


def _zero_logical(d: ParamDef) -> ParamDef:
    """fp32 state def: same shape; shard the first *unsharded* dim over 'zero'."""
    logical = list(d.logical)
    for i, ax in enumerate(logical):
        if ax is None or ax in ("embed", "layers", "conv", "head_dim", "lora", "state"):
            if ax != "layers":
                logical[i] = "zero"
                break
    return ParamDef(d.shape, tuple(logical), jnp.float32, "zeros")


def opt_state_defs(param_defs, cfg: OptConfig):
    """ParamDef tree of the optimizer state (for abstract/init/sharding)."""
    def per(d: ParamDef):
        z = _zero_logical(d)
        master = ParamDef(d.shape, z.logical, jnp.float32, "zeros")
        if cfg.name == "sgdm":
            return {"master": master, "mu": z}
        return {"master": master, "mu": z, "nu": z}
    state = jax.tree.map(per, param_defs, is_leaf=_is_def)
    return {"step": ParamDef((), (), jnp.int32, "zeros"), "params": state}


def init_opt_state(params, cfg: OptConfig):
    def per(p):
        st = {"master": p.astype(jnp.float32), "mu": jnp.zeros(p.shape, jnp.float32)}
        if cfg.name != "sgdm":
            st["nu"] = jnp.zeros(p.shape, jnp.float32)
        return st
    return {"step": jnp.zeros((), jnp.int32),
            "params": jax.tree.map(per, params)}


def lr_at(cfg: OptConfig, step):
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule == "const":
        return lr
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup))
    if cfg.schedule == "linear_warmup_cosine" or cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup) / max(1, cfg.total_steps - cfg.warmup), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * warm * cos
    return lr * warm


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params, grads, opt_state, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics). Grads may be bf16; the update
    runs in fp32 against the master copy and re-casts to the param dtype."""
    step = opt_state["step"]
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.where(gnorm > cfg.grad_clip,
                      cfg.grad_clip / jnp.maximum(gnorm, 1e-12), 1.0) \
        if cfg.grad_clip else jnp.float32(1.0)

    def per(p, g, st):
        g = g.astype(jnp.float32) * scale
        m = st["master"]
        if cfg.name == "sgdm":
            mu = cfg.momentum * st["mu"] + g
            new_m = m - lr * mu
            new_st = {"master": new_m, "mu": mu}
        else:  # adamw
            mu = cfg.b1 * st["mu"] + (1 - cfg.b1) * g
            nu = cfg.b2 * st["nu"] + (1 - cfg.b2) * jnp.square(g)
            t = (step + 1).astype(jnp.float32)
            mu_hat = mu / (1 - cfg.b1 ** t)
            nu_hat = nu / (1 - cfg.b2 ** t)
            upd = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
            if cfg.weight_decay:
                upd = upd + cfg.weight_decay * m
            new_m = m - lr * upd
            new_st = {"master": new_m, "mu": mu, "nu": nu}
        return new_m.astype(p.dtype), new_st

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = treedef.flatten_up_to(opt_state["params"])
    out = [per(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_states = treedef.unflatten([o[1] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step + 1, "params": new_states}, metrics
