from .optimizers import (OptConfig, apply_updates, global_norm, init_opt_state,
                         lr_at, opt_state_defs)  # noqa: F401
from . import compression  # noqa: F401
