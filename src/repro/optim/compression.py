"""Gradient compression for the cross-pod reduce (distributed-optimization trick).

Int8 block-quantization with *error feedback*: the quantization residual is kept
locally and added to the next step's gradient, so compression error does not
accumulate (Seide et al. 1-bit SGD / EF-SGD).  Used by the ``compressed`` reduce
mode of the MapReduce engine: pod-local reduction runs at full precision; only
the (slow, cross-pod DCI) all-reduce sees int8 — a 4x wire-byte cut exactly where
the paper's Hadoop shuffle was the bottleneck.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. Returns (q int8, scale fp32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_tree(grads):
    return jax.tree.map(lambda g: quantize_int8(g), grads)


def decompress_tree(qtree, like):
    return jax.tree.map(
        lambda qs, g: dequantize_int8(qs[0], qs[1], g.shape, g.dtype),
        qtree, like, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def ef_compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback compression of one tensor.

    Returns (dequantized_g, new_error, wire_bytes_est)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale, g.shape, jnp.float32)
    new_err = corrected - deq
    wire = jnp.int32(q.size + scale.size * 4)
    return deq.astype(g.dtype), new_err, wire
