"""Restricted Boltzmann Machine with CD-k — the paper's Algorithm 2/3 mapper/reducer.

Function names follow the paper's pseudo-code (`getposphase`, `getnegphase`,
`update`).  The mapper computes the CD statistics for its (micro)batch; the
reducer is the cross-device mean delivered by the MapReduce engine.  Following
Hinton's practical guide: hidden *probabilities* are used for statistics, hidden
*samples* drive the negative phase, and the reconstruction uses probabilities.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from .mapreduce import map_reduce_job


@dataclasses.dataclass(frozen=True)
class RBMConfig:
    n_vis: int
    n_hid: int
    lr: float = 0.1
    momentum: float = 0.5
    final_momentum: float = 0.9
    momentum_switch: int = 5          # epoch at which momentum increases
    weight_decay: float = 2e-4
    cd_k: int = 1
    use_kernel: bool = False          # fused Pallas hidden-probs (interpret on CPU)


def rbm_init(key, cfg: RBMConfig) -> Dict[str, jax.Array]:
    w = 0.1 * jax.random.normal(key, (cfg.n_vis, cfg.n_hid), jnp.float32)
    return {"W": w,
            "bv": jnp.zeros((cfg.n_vis,), jnp.float32),
            "bh": jnp.zeros((cfg.n_hid,), jnp.float32)}


def hidden_probs(p, v, use_kernel: bool = False):
    if use_kernel:
        from ..kernels.rbm_cd import ops as _ops
        return _ops.gemm_sigmoid(v, p["W"], p["bh"])
    return jax.nn.sigmoid(v @ p["W"] + p["bh"])


def visible_probs(p, h, use_kernel: bool = False):
    if use_kernel:
        from ..kernels.rbm_cd import ops as _ops
        return _ops.gemm_sigmoid(h, p["W"].T, p["bv"])
    return jax.nn.sigmoid(h @ p["W"].T + p["bv"])


def getposphase(p, v, key, use_kernel=False):
    """Positive phase: hidden probabilities + samples for one batch."""
    h_prob = hidden_probs(p, v, use_kernel)
    h_sample = (jax.random.uniform(key, h_prob.shape) < h_prob).astype(v.dtype)
    return h_prob, h_sample


def getnegphase(p, h_sample, key, cd_k: int = 1, use_kernel=False):
    """Negative (reconstruction) phase, CD-k."""
    h = h_sample
    for i in range(cd_k):
        v_prob = visible_probs(p, h, use_kernel)
        h_prob = hidden_probs(p, v_prob, use_kernel)
        if i < cd_k - 1:
            h = (jax.random.uniform(jax.random.fold_in(key, i), h_prob.shape)
                 < h_prob).astype(v_prob.dtype)
    return v_prob, h_prob


def cd_statistics(p, v, key, cfg: RBMConfig):
    """The mapper: per-batch CD statistics (already combiner-aggregated)."""
    k1, k2 = jax.random.split(key)
    h_prob, h_sample = getposphase(p, v, k1, cfg.use_kernel)
    v_neg, h_neg = getnegphase(p, h_sample, k2, cfg.cd_k, cfg.use_kernel)
    B = v.shape[0]
    dW = (v.T @ h_prob - v_neg.T @ h_neg) / B
    dbv = jnp.mean(v - v_neg, axis=0)
    dbh = jnp.mean(h_prob - h_neg, axis=0)
    err = jnp.mean(jnp.square(v - v_neg))
    return {"W": dW, "bv": dbv, "bh": dbh, "err": err}


def update(p, vel, stats, cfg: RBMConfig, epoch):
    """Momentum update from reduced statistics (the paper's weight update)."""
    mom = jnp.where(jnp.asarray(epoch) >= cfg.momentum_switch,
                    cfg.final_momentum, cfg.momentum)
    new_vel = {
        "W": mom * vel["W"] + cfg.lr * (stats["W"] - cfg.weight_decay * p["W"]),
        "bv": mom * vel["bv"] + cfg.lr * stats["bv"],
        "bh": mom * vel["bh"] + cfg.lr * stats["bh"],
    }
    new_p = {k: p[k] + new_vel[k] for k in p}
    return new_p, new_vel


def make_rbm_step(cfg: RBMConfig, mesh: Optional[Mesh]):
    """Jitted MapReduce CD step: (params, vel, batch, key, epoch) -> (p, vel, err)."""
    job = map_reduce_job(
        lambda pk, batch: cd_statistics(pk[0], batch, pk[1], cfg),
        mesh, reduce="mean")

    @jax.jit
    def step(p, vel, batch, key, epoch):
        stats = job((p, key), batch)
        err = stats.pop("err")
        new_p, new_vel = update(p, vel, stats, cfg, epoch)
        return new_p, new_vel, err

    return step


def free_energy(p, v):
    """RBM free energy (diagnostic; decreasing on train data = learning)."""
    wx = v @ p["W"] + p["bh"]
    return -v @ p["bv"] - jnp.sum(jax.nn.softplus(wx), axis=-1)
