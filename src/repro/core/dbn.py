"""Deep-belief-network driver — the paper's Algorithm 1 (`DeepLearningDriver`).

Greedy layer-wise loop: for each layer, run ``maxEpoch`` epochs of MapReduce RBM
jobs (Algorithms 2/3), then one forward-propagation MapReduce job (Algorithm 4)
whose output becomes the next layer's "data".  The learned stack unrolls into a
deep autoencoder (``core.autoencoder``) or a classifier (``core.finetune``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .mapreduce import map_reduce_job
from .rbm import RBMConfig, hidden_probs, make_rbm_step, rbm_init


@dataclasses.dataclass(frozen=True)
class DBNConfig:
    stack: Sequence[int]              # e.g. (784, 1000, 500, 250, 30)
    max_epoch: int = 10
    batch_size: int = 100
    lr: float = 0.1
    momentum: float = 0.5
    cd_k: int = 1
    weight_decay: float = 2e-4
    use_kernel: bool = False
    log_every: int = 0


def train_dbn(
    data: np.ndarray,                 # [N, stack[0]] in [0, 1]
    cfg: DBNConfig,
    key,
    mesh: Optional[Mesh] = None,
    callback: Optional[Callable] = None,
) -> List[dict]:
    """Algorithm 1. Returns the trained RBM stack (list of param dicts)."""
    layer_input = jnp.asarray(data, jnp.float32)
    stack_params: List[dict] = []
    n = layer_input.shape[0]

    for layer in range(len(cfg.stack) - 1):
        rcfg = RBMConfig(n_vis=cfg.stack[layer], n_hid=cfg.stack[layer + 1],
                         lr=cfg.lr, momentum=cfg.momentum, cd_k=cfg.cd_k,
                         weight_decay=cfg.weight_decay, use_kernel=cfg.use_kernel)
        key, sub = jax.random.split(key)
        p = rbm_init(sub, rcfg)
        vel = jax.tree.map(jnp.zeros_like, p)
        step = make_rbm_step(rcfg, mesh)

        nb = n // cfg.batch_size
        for epoch in range(cfg.max_epoch):
            key, sub = jax.random.split(key)
            perm = jax.random.permutation(sub, n)[: nb * cfg.batch_size]
            errs = []
            for b in range(nb):
                idx = perm[b * cfg.batch_size:(b + 1) * cfg.batch_size]
                batch = layer_input[idx]
                key, sub = jax.random.split(key)
                p, vel, err = step(p, vel, batch, sub, epoch)
                errs.append(float(err))
            if callback:
                callback(layer=layer, epoch=epoch, recon_err=float(np.mean(errs)))
            if cfg.log_every and epoch % cfg.log_every == 0:
                print(f"[dbn] layer {layer} epoch {epoch} recon_err {np.mean(errs):.5f}")
        stack_params.append(jax.device_get(p))

        # Algorithm 4: forward-propagation job to produce the next layer's input
        prop = map_reduce_job(
            lambda pp, batch: hidden_probs(pp, batch, cfg.use_kernel),
            mesh, reduce="concat")
        layer_input = jax.jit(prop)(
            {k: jnp.asarray(v) for k, v in stack_params[-1].items()}, layer_input)

    return stack_params


def forward_stack(stack_params: Sequence[dict], v: jax.Array) -> jax.Array:
    """Encode data through the trained stack (all sigmoid layers)."""
    h = v
    for p in stack_params:
        h = jax.nn.sigmoid(h @ p["W"] + p["bh"])
    return h


def progressive_stack_lm(train_fn, grow_schedule: Sequence[int]):
    """Beyond-paper: the greedy layer-wise idea carried to LM pre-training
    (progressive stacking).  ``train_fn(n_layers, init_params) -> params`` is
    invoked per stage; each stage initializes the deeper model by duplicating
    the shallower stage's stacked layer params.

    Returns the final params.  (Carries the paper's layer-wise-init insight to
    architectures where RBM pre-training is inapplicable — see DESIGN.md §5.)"""
    params = None
    for n_layers in grow_schedule:
        params = train_fn(n_layers, params)
    return params


def grow_stacked_params(params, n_new: int):
    """Duplicate stacked [L, ...] block params to depth ``n_new`` (cycled)."""
    def grow(x):
        if x.ndim == 0:
            return x
        L = x.shape[0]
        reps = [x[i % L] for i in range(n_new)]
        return jnp.stack(reps)
    return jax.tree.map(grow, params)
