# The paper's primary contribution: MapReduce-decomposed deep learning.
from .mapreduce import (REDUCE_MODES, map_reduce_job, mapreduce_value_and_grad,
                        reduce_tree)  # noqa: F401
from .rbm import RBMConfig, cd_statistics, free_energy, make_rbm_step, rbm_init  # noqa: F401
from .dbn import DBNConfig, forward_stack, train_dbn  # noqa: F401
from . import adaboost, autoencoder, finetune  # noqa: F401
