"""Deep autoencoder: DBN unroll + MapReduce back-propagation fine-tuning.

This is the paper's unsupervised pipeline (Figs. 6/10/12): the RBM stack is
unrolled into encoder+decoder (decoder weights = transposed encoder weights as
*initialization*, then trained independently) and fine-tuned with the MapReduce
BP job minimizing the sigmoid cross-entropy reconstruction loss (Hinton &
Salakhutdinov 2006).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .mapreduce import mapreduce_value_and_grad


def unroll(stack_params: Sequence[dict]) -> Dict[str, list]:
    """RBM stack -> autoencoder params {enc_W, enc_b, dec_W, dec_b} lists."""
    enc_W = [jnp.asarray(p["W"]) for p in stack_params]
    enc_b = [jnp.asarray(p["bh"]) for p in stack_params]
    dec_W = [jnp.asarray(p["W"]).T for p in reversed(stack_params)]
    dec_b = [jnp.asarray(p["bv"]) for p in reversed(stack_params)]
    return {"enc_W": enc_W, "enc_b": enc_b, "dec_W": dec_W, "dec_b": dec_b}


def encode(params, v, linear_code: bool = True):
    h = v
    n = len(params["enc_W"])
    for i, (w, b) in enumerate(zip(params["enc_W"], params["enc_b"])):
        z = h @ w + b
        h = z if (linear_code and i == n - 1) else jax.nn.sigmoid(z)
    return h


def decode(params, code):
    h = code
    n = len(params["dec_W"])
    for i, (w, b) in enumerate(zip(params["dec_W"], params["dec_b"])):
        z = h @ w + b
        h = jax.nn.sigmoid(z)  # final layer sigmoid: pixels in [0,1]
    return h


def reconstruct(params, v):
    return decode(params, encode(params, v))


def recon_loss(params, batch):
    """Sigmoid cross-entropy reconstruction loss (per Hinton's fine-tuning)."""
    v = batch["x"]
    r = jnp.clip(reconstruct(params, v), 1e-6, 1 - 1e-6)
    ce = -jnp.mean(jnp.sum(v * jnp.log(r) + (1 - v) * jnp.log(1 - r), axis=-1))
    mse = jnp.mean(jnp.sum(jnp.square(v - r), axis=-1))
    return ce, {"mse": mse}


def make_finetune_step(mesh: Optional[Mesh], lr: float = 0.05,
                       reduce_mode: str = "allreduce", n_micro: int = 1):
    """MapReduce BP fine-tuning step with plain SGD-momentum."""
    if mesh is None:
        vg = jax.value_and_grad(recon_loss, has_aux=True)

        @jax.jit
        def step(params, vel, batch):
            (loss, aux), grads = vg(params, batch)
            vel = jax.tree.map(lambda v, g: 0.9 * v - lr * g, vel, grads)
            params = jax.tree.map(lambda p, v: p + v, params, vel)
            return params, vel, loss, aux
        return step

    mr = mapreduce_value_and_grad(recon_loss, mesh, reduce_mode=reduce_mode,
                                  n_micro=n_micro)

    @jax.jit
    def step(params, vel, batch):
        loss, grads, _, aux = mr(params, batch, None)
        vel = jax.tree.map(lambda v, g: 0.9 * v - lr * g, vel, grads)
        params = jax.tree.map(lambda p, v: p + v, params, vel)
        return params, vel, loss, aux

    return step


def reconstruction_error(params, data: np.ndarray, batch: int = 1000) -> float:
    """Mean per-image squared reconstruction error (the paper's Fig. 6 metric)."""
    tot, n = 0.0, 0
    f = jax.jit(lambda p, v: jnp.sum(jnp.square(v - reconstruct(p, v))))
    for i in range(0, len(data), batch):
        v = jnp.asarray(data[i:i + batch], jnp.float32)
        tot += float(f(params, v))
        n += v.shape[0]
    return tot / max(1, n)
