"""AdaBoost precision refinement (paper §IV-C) — multiclass SAMME variant.

The paper sketches Adaboosting as the third stage ("get one weak classifier from
part of the training set; get more using different parts ...; assemble them").
We implement SAMME (the standard multiclass AdaBoost) over small MLP weak
learners: each round trains on a weighted resample of the data, the ensemble
votes with log((1-eps)/eps) + log(K-1) weights — with K=10 classes the weak-
learning condition is eps < 0.9 rather than M1's eps < 0.5.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class BoostConfig:
    n_rounds: int = 5
    n_hidden: int = 64
    n_classes: int = 10
    epochs: int = 3
    batch_size: int = 100
    lr: float = 0.5
    sample_frac: float = 1.0


def _mlp_init(key, n_in, n_hid, n_out):
    k1, k2 = jax.random.split(key)
    return {"W1": 0.1 * jax.random.normal(k1, (n_in, n_hid), jnp.float32),
            "b1": jnp.zeros((n_hid,), jnp.float32),
            "W2": 0.1 * jax.random.normal(k2, (n_hid, n_out), jnp.float32),
            "b2": jnp.zeros((n_out,), jnp.float32)}


def _mlp_logits(p, x):
    h = jax.nn.sigmoid(x @ p["W1"] + p["b1"])
    return h @ p["W2"] + p["b2"]


@jax.jit
def _sgd_step(p, x, y, lr):
    def loss(p):
        lg = _mlp_logits(p, x)
        return jnp.mean(jax.nn.logsumexp(lg, -1)
                        - jnp.take_along_axis(lg, y[:, None], -1)[:, 0])
    g = jax.grad(loss)(p)
    return jax.tree.map(lambda a, b: a - lr * b, p, g)


def _train_weak(key, X, y, cfg: BoostConfig):
    p = _mlp_init(key, X.shape[1], cfg.n_hidden, cfg.n_classes)
    n = X.shape[0]
    nb = max(1, n // cfg.batch_size)
    for e in range(cfg.epochs):
        key, sub = jax.random.split(key)
        perm = np.asarray(jax.random.permutation(sub, n))
        for b in range(nb):
            idx = perm[b * cfg.batch_size:(b + 1) * cfg.batch_size]
            p = _sgd_step(p, jnp.asarray(X[idx]), jnp.asarray(y[idx]), cfg.lr)
    return p


def fit(X: np.ndarray, y: np.ndarray, cfg: BoostConfig, key) -> Tuple[List[dict], List[float]]:
    """Returns (weak learners, vote weights alpha)."""
    n = X.shape[0]
    w = np.full(n, 1.0 / n)
    learners, alphas = [], []
    predict_one = jax.jit(lambda p, x: jnp.argmax(_mlp_logits(p, x), -1))
    K = cfg.n_classes
    for t in range(cfg.n_rounds):
        key, k1, k2 = jax.random.split(key, 3)
        # weighted resample ("different parts of the training set")
        m = int(cfg.sample_frac * n)
        idx = np.asarray(jax.random.choice(k1, n, (m,), p=jnp.asarray(w / w.sum())))
        p = _train_weak(k2, X[idx], y[idx], cfg)
        pred = np.asarray(predict_one(p, jnp.asarray(X)))
        miss = (pred != y)
        eps = float(np.sum(w * miss) / np.sum(w))
        # SAMME multiclass condition: better than random guessing (1 - 1/K)
        if eps >= 1.0 - 1.0 / K:
            break
        eps = max(eps, 1e-10)
        alpha = float(np.log((1.0 - eps) / eps) + np.log(K - 1.0))
        w = w * np.exp(alpha * miss)         # up-weight mistakes (SAMME)
        w = w / w.sum()
        learners.append(jax.device_get(p))
        alphas.append(alpha)
    return learners, alphas


def predict(learners: List[dict], alphas: List[float], X: np.ndarray,
            n_classes: int = 10) -> np.ndarray:
    votes = np.zeros((X.shape[0], n_classes))
    f = jax.jit(lambda p, x: jnp.argmax(_mlp_logits(p, x), -1))
    for p, a in zip(learners, alphas):
        pred = np.asarray(f({k: jnp.asarray(v) for k, v in p.items()},
                            jnp.asarray(X, jnp.float32)))
        votes[np.arange(len(pred)), pred] += a
    return votes.argmax(-1)


def error_rate(learners, alphas, X, y, n_classes: int = 10) -> float:
    return float((predict(learners, alphas, X, n_classes) != y).mean())
