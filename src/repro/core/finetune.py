"""Supervised fine-tuning (paper §IV-B): the DBN stack + softmax head trained
with MapReduce back-propagation — the hand-written-digit recognizer of Figs. 7/9/11."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .mapreduce import mapreduce_value_and_grad


def classifier_init(stack_params: Sequence[dict], n_classes: int, key) -> Dict:
    """Encoder layers initialized from the pre-trained RBM stack (the paper's
    'well-initialized weights'), plus a fresh softmax head."""
    Ws = [jnp.asarray(p["W"]) for p in stack_params]
    bs = [jnp.asarray(p["bh"]) for p in stack_params]
    head = 0.01 * jax.random.normal(key, (Ws[-1].shape[1], n_classes), jnp.float32)
    return {"W": Ws, "b": bs, "head_W": head,
            "head_b": jnp.zeros((n_classes,), jnp.float32)}


def logits_fn(params, v):
    h = v
    for w, b in zip(params["W"], params["b"]):
        h = jax.nn.sigmoid(h @ w + b)
    return h @ params["head_W"] + params["head_b"]


def ce_loss(params, batch):
    lg = logits_fn(params, batch["x"])
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, batch["y"][:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(lg, -1) == batch["y"]).astype(jnp.float32))
    return loss, {"acc": acc}


def make_classifier_step(mesh: Optional[Mesh], lr: float = 0.1,
                         reduce_mode: str = "allreduce", n_micro: int = 1):
    if mesh is None:
        vg = jax.value_and_grad(ce_loss, has_aux=True)

        @jax.jit
        def step(params, vel, batch):
            (loss, aux), grads = vg(params, batch)
            vel = jax.tree.map(lambda v, g: 0.9 * v - lr * g, vel, grads)
            params = jax.tree.map(lambda p, v: p + v, params, vel)
            return params, vel, loss, aux
        return step

    mr = mapreduce_value_and_grad(ce_loss, mesh, reduce_mode=reduce_mode,
                                  n_micro=n_micro)

    @jax.jit
    def step(params, vel, batch):
        loss, grads, _, aux = mr(params, batch, None)
        vel = jax.tree.map(lambda v, g: 0.9 * v - lr * g, vel, grads)
        params = jax.tree.map(lambda p, v: p + v, params, vel)
        return params, vel, loss, aux

    return step


def error_rate(params, X: np.ndarray, y: np.ndarray, batch: int = 1000) -> float:
    """Misclassification rate (the paper's Fig. 7 metric)."""
    wrong, n = 0, 0
    f = jax.jit(lambda p, v: jnp.argmax(logits_fn(p, v), -1))
    for i in range(0, len(X), batch):
        pred = np.asarray(f(params, jnp.asarray(X[i:i + batch], jnp.float32)))
        wrong += int((pred != y[i:i + batch]).sum())
        n += len(pred)
    return wrong / max(1, n)
