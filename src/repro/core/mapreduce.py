"""The paper's contribution as a composable JAX module: MapReduce training.

Roles (paper -> here):
  * **mapper**   — per-example/microbatch update computation (``jax.grad`` or an
    explicit statistic fn like RBM CD), running on each device's local data shard.
  * **combiner** — on-device accumulation across the local microbatches
    (``lax.scan`` grad accumulation) — Hadoop's combiner, free of network cost.
  * **reducer**  — the cross-device per-weight sum.  One ``psum`` IS the
    shuffle+reduce: the weight index is the key, the collective delivers every
    reducer's output back to every mapper (the paper's distributed-cache broadcast
    folded into the same op).

Reduce modes (selectable, all numerically equivalent up to quantization):
  * ``allreduce``    — single psum over all data axes (the XLA-native baseline).
  * ``hierarchical`` — psum over intra-pod ``data`` first, then over ``pod``:
    the Hadoop combiner analogy at pod granularity; confines the slow cross-pod
    hop to one already-reduced tensor.
  * ``compressed``   — intra-pod full-precision psum, then int8 error-feedback
    quantization for the cross-pod hop (4x wire bytes), dequant+sum locally.

Engine mechanics: ``jax.shard_map`` manual over the data axes only; the ``model``
axis stays *auto* so tensor-parallel sharding of params flows through unchanged —
MapReduce DP composes with TP/EP.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models import shardings
from ..optim import compression

REDUCE_MODES = ("allreduce", "hierarchical", "compressed")


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ------------------------------------------------------------------ reducers

def reduce_tree(grads, mesh: Mesh, mode: str, err=None):
    """Cross-device reduce of a gradient pytree (call inside shard_map).

    Returns (reduced_grads, new_err).  ``err`` is the error-feedback state for
    ``compressed`` mode (pytree of fp32 like grads, or None)."""
    dp = _dp_axes(mesh)
    if not dp:
        return grads, err
    if mode == "allreduce" or len(dp) == 1:
        return jax.tree.map(lambda g: jax.lax.psum(g, dp), grads), err
    if mode == "hierarchical":
        g = jax.tree.map(lambda g: jax.lax.psum(g, "data"), grads)
        g = jax.tree.map(lambda g: jax.lax.psum(g, "pod"), g)
        return g, err

    # compressed: full-precision intra-pod, int8+EF across pods
    assert mode == "compressed", mode
    local = jax.tree.map(lambda g: jax.lax.psum(g, "data"), grads)
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), local)

    def xpod(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = compression.quantize_int8(corrected)
        deq_own = compression.dequantize_int8(q, scale, g.shape, jnp.float32)
        new_e = corrected - deq_own
        # the wire carries int8 + fp32 block scales
        q_all = jax.lax.all_gather(q, "pod")           # [n_pod, blocks, BLOCK] int8
        s_all = jax.lax.all_gather(scale, "pod")
        summed = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
        n = 1
        for s in g.shape:
            n *= s
        out = summed.reshape(-1)[:n].reshape(g.shape).astype(g.dtype)
        return out, new_e

    flat_g, tdef = jax.tree.flatten(local)
    flat_e = tdef.flatten_up_to(err)
    outs = [xpod(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


# ----------------------------------------------------------- gradient mapper

def mapreduce_value_and_grad(
    loss_fn: Callable,            # (params, microbatch) -> (loss, aux)
    mesh: Mesh,
    *,
    reduce_mode: str = "allreduce",
    n_micro: int = 1,
):
    """Build the paper's full map/combine/reduce step for a differentiable loss.

    Returns ``step(params, batch, err) -> (loss, grads, new_err, aux)`` where
    ``batch`` is globally-sharded over the data axes, grads come back fully
    reduced (mean over the global batch) and replicated over data axes."""
    dp = _dp_axes(mesh)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def local(params, batch, err):
        # --- mapper + combiner: microbatch scan over the local shard ---
        def to_micro(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
        mb = jax.tree.map(to_micro, batch)

        def acc(carry, m):
            gsum, lsum = carry
            (l, aux), g = vg(params, m)
            gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
            return (gsum, lsum + l), aux

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), auxs = jax.lax.scan(
            acc, (g0, jnp.zeros((), jnp.float32)), mb)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        loss = lsum / n_micro

        # --- reducer: cross-device per-weight mean ---
        grads, new_err = reduce_tree(grads, mesh, reduce_mode, err)
        nshards = 1
        for a in dp:
            nshards *= mesh.shape[a]
        grads = jax.tree.map(lambda g: g / nshards, grads)
        loss = jax.lax.pmean(loss, dp)
        return loss, grads, new_err, jax.tree.map(lambda a: a[-1], auxs)

    batch_spec = P(dp if len(dp) > 1 else dp[0])

    def step(params, batch, err):
        in_specs = (
            jax.tree.map(lambda _: P(), params),
            jax.tree.map(lambda _: batch_spec, batch),
            None if err is None else jax.tree.map(lambda _: P(), err),
        )
        out_specs = (P(), jax.tree.map(lambda _: P(), params),
                     None if err is None else jax.tree.map(lambda _: P(), err),
                     P())
        # err=None needs static handling: split the two signatures
        if err is None:
            def local2(params, batch):
                l, g, _, a = local(params, batch, None)
                return l, g, a
            fm = shardings.shard_map_compat(
                local2, mesh,
                in_specs=in_specs[:2],
                out_specs=(P(), jax.tree.map(lambda _: P(), params), P()),
                axis_names=set(dp), check_vma=False)
            l, g, a = fm(params, batch)
            return l, g, None, a
        fm = shardings.shard_map_compat(
            lambda p, b, e: local(p, b, e), mesh,
            in_specs=in_specs, out_specs=out_specs,
            axis_names=set(dp), check_vma=False)
        return fm(params, batch, err)

    return step


# ------------------------------------------------------- generic M/R jobs

def map_reduce_job(
    map_fn: Callable,             # (params, local_batch) -> pytree of statistics
    mesh: Optional[Mesh],
    *,
    reduce: str = "mean",         # mean | sum | concat (concat = identity-reduce)
):
    """The paper's generic MapReduce job (used for RBM CD and the forward-prop
    job between DBN layers).  On a 1-device mesh this degrades to plain eval."""
    if mesh is None:
        def run_local(params, batch):
            return map_fn(params, batch)
        return run_local

    dp = _dp_axes(mesh)
    batch_spec = P(dp if len(dp) > 1 else dp[0])

    def local(params, batch):
        out = map_fn(params, batch)
        if reduce == "sum":
            return jax.tree.map(lambda x: jax.lax.psum(x, dp), out)
        if reduce == "mean":
            return jax.tree.map(lambda x: jax.lax.pmean(x, dp), out)
        return out                               # concat: stays sharded

    def run(params, batch):
        out_spec = P() if reduce in ("sum", "mean") else batch_spec
        fm = shardings.shard_map_compat(
            local, mesh,
            in_specs=(jax.tree.map(lambda _: P(), params),
                      jax.tree.map(lambda _: batch_spec, batch)),
            out_specs=jax.tree.map(lambda _: out_spec, jax.eval_shape(
                lambda p, b: map_fn(p, jax.tree.map(
                    lambda x: x[:max(1, x.shape[0] // max(1, _dp_size(mesh)))], b)),
                params, batch)),
            axis_names=set(dp), check_vma=False)
        return fm(params, batch)

    return run


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in _dp_axes(mesh):
        n *= mesh.shape[a]
    return n
