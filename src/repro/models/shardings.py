"""Divisibility-aware sharding rules.

Rather than hand-wiring a PartitionSpec per tensor per arch, each module asks for a
spec via *logical axes* (e.g. ``("embed", "heads")``); the resolver maps logical axes
to mesh axes and silently drops any assignment that does not divide evenly (e.g.
qwen2's 14 heads over a 16-way model axis -> replicated heads, sharded elsewhere).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ------------------------------------------------------ jax version compat
#
# The repo targets the modern surface (``jax.shard_map`` with axis_names /
# check_vma, ``jax.sharding.AxisType``); older installs (<= 0.4.x) only have
# ``jax.experimental.shard_map.shard_map(..., check_rep=, auto=)`` and no
# AxisType at all.  Everything below resolves to whichever exists so the rest
# of the codebase can stay version-agnostic.

def shard_map_compat(f, mesh: Mesh, *, in_specs, out_specs, axis_names=None,
                     check_vma: bool = False):
    """``jax.shard_map`` if present, else the jax.experimental equivalent.

    ``axis_names`` is the set of mesh axes that go Manual; remaining axes stay
    auto (old API expresses the same thing inverted, via ``auto=``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kwargs["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, **kwargs)


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types when supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))

# logical axis -> preferred mesh axis (in priority order)
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("dp",),            # dp is the compound data axis (pod+data)
    "seq": (),
    "seq_sp": ("model",),
    "embed": (),                 # d_model is replicated by default (TP on other dims)
    "embed_tp": ("model",),      # d_model sharded (used as fallback / ZeRO dim)
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ff": ("model",),
    "experts": ("model",),
    "lora": (),
    "state": (),
    "rnn": ("model",),
    "conv": (),
    "layers": (),
    "zero": ("data",),           # optimizer-state sharding dim (ZeRO-1)
}


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The compound data-parallel axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    *,
    used: Optional[set] = None,
) -> P:
    """Map logical axes to a PartitionSpec, dropping non-dividing assignments.

    Each mesh axis is used at most once per tensor.
    """
    sizes = axis_sizes(mesh)
    taken = set() if used is None else used
    out = []
    for ax, dim in zip(logical, shape):
        assigned = None
        if ax is not None:
            candidates = LOGICAL_RULES.get(ax, ())
            for cand in candidates:
                if cand == "dp":
                    dps = dp_axes(mesh)
                    total = 1
                    for a in dps:
                        total *= sizes[a]
                    if dps and dim % total == 0 and not (set(dps) & taken):
                        assigned = dps if len(dps) > 1 else dps[0]
                        taken.update(dps)
                        break
                elif cand in sizes and dim % sizes[cand] == 0 and cand not in taken:
                    assigned = cand
                    taken.add(cand)
                    break
        out.append(assigned)
    return P(*out)


def named(mesh: Mesh, logical: Sequence[Optional[str]], shape: Sequence[int]) -> NamedSharding:
    return NamedSharding(mesh, resolve(logical, shape, mesh))


def constrain(x, mesh: Mesh, logical: Sequence[Optional[str]]):
    """Apply a with_sharding_constraint using logical axes (inside jit)."""
    spec = resolve(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_specs(defs, mesh: Mesh):
    """defs: pytree of (shape, dtype, logical) -> pytree of NamedSharding."""
    return jax.tree.map(
        lambda d: NamedSharding(mesh, resolve(d[2], d[0], mesh)),
        defs,
        is_leaf=lambda d: isinstance(d, tuple) and len(d) == 3 and isinstance(d[0], tuple),
    )
