"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Training uses ``jax.lax.associative_scan`` over time (log-depth, TPU-friendly);
decode is the exact O(1) per-step recurrence — with the bounded local-attention
window this makes recurrentgemma eligible for the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .params import ParamDef

_C = 8.0  # RG-LRU temperature constant (Griffin §2.4)


def rglru_defs(cfg: ArchConfig):
    d, dr, w = cfg.d_model, cfg.d_rnn or cfg.d_model, cfg.conv_width
    return {
        "w_in": ParamDef((d, dr), ("embed", "rnn")),
        "w_gate": ParamDef((d, dr), ("embed", "rnn")),
        "conv": ParamDef((w, dr), ("conv", "rnn")),
        "w_a": ParamDef((dr, dr), ("rnn", "embed_tp")),
        "b_a": ParamDef((dr,), ("rnn",), init="zeros"),
        "w_i": ParamDef((dr, dr), ("rnn", "embed_tp")),
        "b_i": ParamDef((dr,), ("rnn",), init="zeros"),
        "lam": ParamDef((dr,), ("rnn",), dtype=jnp.float32, init="const:2.0"),
        "w_out": ParamDef((dr, d), ("rnn", "embed")),
    }


def _gates(p, u):
    r = jax.nn.sigmoid(u @ p["w_a"] + p["b_a"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["w_i"] + p["b_i"]).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"]) * r                    # log a_t  (<= 0)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * u.astype(jnp.float32))
    return a, b


def _conv(u, w):
    W = w.shape[0]
    out = u * w[-1]
    for k in range(1, W):
        out = out + jnp.pad(u, ((0, 0), (k, 0), (0, 0)))[:, :-k] * w[-1 - k]
    return out


def rglru_block(cfg: ArchConfig, p, x, *, init_state=None,
                length_mask=None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence recurrent block. x: [B,S,d] -> ([B,S,d], final_state [B,dr]).

    ``length_mask`` ([B, S] bool, optional) marks real positions; masked
    (padding) steps become identities (``a = 1, b = 0``) so the recurrence —
    and therefore ``final_state`` — stops at the last real position.  Serving
    uses this for bucketed right-padded prefill."""
    u = _conv(x @ p["w_in"], p["conv"])
    gate = jax.nn.gelu(x @ p["w_gate"])
    a, b = _gates(p, u)                                            # [B,S,dr] fp32
    if length_mask is not None:
        m = length_mask[..., None]
        a = jnp.where(m, a, 1.0)
        b = jnp.where(m, b, 0.0)
    if init_state is not None:
        # fold the initial state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * init_state.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    final = h[:, -1]
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y, final


def rglru_cache_defs(cfg: ArchConfig, batch: int):
    dr = cfg.d_rnn or cfg.d_model
    return {
        "conv": ParamDef((batch, cfg.conv_width - 1, dr), ("batch", None, "rnn"), init="zeros"),
        "state": ParamDef((batch, dr), ("batch", "rnn"), dtype=jnp.float32, init="zeros"),
    }


def rglru_decode_block(cfg: ArchConfig, p, x, cache):
    """One-token decode. x: [B, d]."""
    u_raw = x @ p["w_in"]
    full = jnp.concatenate([cache["conv"], u_raw[:, None]], axis=1)
    u = jnp.einsum("bwc,wc->bc", full, p["conv"])
    gate = jax.nn.gelu(x @ p["w_gate"])
    a, b = _gates(p, u)
    h = a * cache["state"] + b
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y, {"conv": full[:, 1:], "state": h}
