"""Attention-backend registry: one dispatch point for every paged path.

The serving hot loop is paged attention — prefill writes K/V (or MLA latent)
through per-request page tables, decode reads every live token back per step.
How that read happens is a *backend* choice, orthogonal to the cache family:

* ``reference`` — the XLA gather+attend formulation (``pool[tables]``
  materializes the logical view in HBM, then dense masked attention) — the
  parity oracle every other backend is verified against.  Its decode attends
  keep the probability-weighted sum in fp32 and round to cache dtype only at
  the block output, the same single rounding point as the fused kernel's
  fp32 accumulator, so backends agree to an output ulp and greedy decode
  stays token-exact across them.
* ``pallas`` — the fused ``repro.kernels.paged_attention`` decode kernel:
  the page table rides into the kernel as a scalar-prefetch operand and the
  BlockSpec index maps walk it directly, so the gather never materializes.
  Prefill (and anything a backend does not override) falls back to the
  reference implementation.

A backend implements three *attend cores* — ``decode_attend`` (vanilla GQA +
sliding-window rings), ``mla_decode_attend`` (absorbed-latent), and
``prefill_attend`` (chunked multi-token) — while the family framing (QKV
projection, RoPE, page-table scatter, output projection) is shared code in
``models.attention`` / ``models.mla`` that every backend reuses.  Model code
routes exclusively through ``backend.paged_prefill`` / ``backend.paged_decode``;
future backends (GPU, ragged prefill, speculative verify) plug in by
registering a class and overriding the cores they fuse.

Selection is threaded from ``ServeConfig.attn_backend`` (``auto`` |
``reference`` | ``pallas``) through ``launch/serve.py --attn-backend`` and the
engine's jitted-step cache; ``auto`` picks the fused kernel exactly when jax
has a TPU to compile it for.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import attention, mla
from ..kernels.paged_attention import (mla_paged_attention_decode,
                                       paged_attention_decode)

# ---------------------------------------------------------------- registry

_REGISTRY: Dict[str, "AttentionBackend"] = {}


def register_backend(cls):
    """Class decorator: instantiate and register under ``cls.name``."""
    _REGISTRY[cls.name] = cls()
    return cls


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_backend(name: str) -> str:
    """Resolve a ``ServeConfig.attn_backend`` knob to a concrete backend name.

    ``auto`` picks the fused kernel exactly when jax has a TPU to compile it
    for; elsewhere the XLA reference path is faster than an interpreted
    kernel (parity tests opt into interpret-mode pallas explicitly)."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    if name not in _REGISTRY:
        raise ValueError(f"unknown attention backend {name!r}; "
                         f"available: {available_backends()}")
    if name == "pallas" and jax.default_backend() not in ("tpu", "cpu"):
        # fail at config time with a clear message instead of deep inside a
        # Mosaic lowering attempt (the kernels are TPU-targeted; CPU runs
        # them in interpret mode, other backends have no lowering)
        raise ValueError(
            "attn_backend='pallas' requires a TPU (compiled) or CPU "
            f"(interpret mode); jax backend is {jax.default_backend()!r}")
    return name


def get_backend(name: str) -> "AttentionBackend":
    return _REGISTRY[resolve_backend(name)]


# ----------------------------------------------------- flat decode metadata

def decode_meta(cfg: ArchConfig, page_size: int, tables, pos):
    """Flat per-step decode metadata, computed once instead of re-derived by
    every layer's block inside the scan: the page-table rows, per-row
    absolute positions, and the physical (page, offset) write target of the
    step's new token — ring-aware for sliding-window families.  Works on
    numpy (engine host path) and jnp (traced) arrays alike; values feed the
    jitted ``decode_paged`` step as one pytree."""
    B = tables.shape[0]
    col = pos // page_size
    if cfg.sliding_window:
        from .cache_spec import window_pages
        col = col % min(window_pages(cfg.sliding_window, page_size),
                        tables.shape[1])
    xp = jnp if isinstance(tables, jax.Array) else np
    # live paged rows always have col < table width; the clamp covers rows
    # whose table is a null placeholder (state-slot families, idle slots)
    col = xp.minimum(col, tables.shape[1] - 1)
    return {"tables": tables, "pos": pos,
            "write_page": tables[xp.arange(B), col],
            "write_off": pos % page_size}


# ----------------------------------------------------------- backend classes

class AttentionBackend:
    """Family routing (shared) + attend cores (the extension point)."""

    name = "abstract"

    # -------- public entry points: the only paged-attention call sites

    def paged_prefill(self, cfg: ArchConfig, p, x, cache, tables, start,
                      n_live, freqs, *, q_block: int = 512,
                      unroll: bool = False):
        """Multi-token prefill at an offset into the paged pool.  Routes by
        cache family (MLA latent / sliding-window ring / vanilla KV); returns
        (out [B, T, d], new_cache)."""
        if cfg.use_mla:
            return mla.mla_paged_prefill_block(
                cfg, p, x, cache, tables, start, n_live, freqs, backend=self,
                q_block=q_block, unroll=unroll)
        return attention.paged_prefill_attention_block(
            cfg, p, x, cache, tables, start, n_live, freqs, backend=self,
            q_block=q_block, unroll=unroll)

    def paged_decode(self, cfg: ArchConfig, p, x, cache, meta, freqs):
        """One-token decode against the paged pool.  ``meta`` is the flat
        per-step metadata from ``decode_meta``; returns (out [B, d],
        new_cache)."""
        if cfg.use_mla:
            return mla.mla_paged_decode_block(cfg, p, x, cache, meta, freqs,
                                              backend=self)
        return attention.paged_decode_attention_block(cfg, p, x, cache, meta,
                                                      freqs, backend=self)

    # -------- attend cores (override to fuse)

    def decode_attend(self, q, k_pages, v_pages, tables, pos, *, scale: float,
                      softcap: float = 0.0, window: int = 0):
        """q: [B, H, D]; pools [P, ps, K, D]; tables [B, n] (ring when
        ``window > 0``); pos [B].  Returns [B, H, D]."""
        raise NotImplementedError

    def mla_decode_attend(self, q_eff, q_rope, ckv_pages, krope_pages, tables,
                          pos, *, scale: float):
        """Absorbed-latent scores + latent context: q_eff [B, H, L] /
        q_rope [B, H, R] against [P, ps, L] / [P, ps, R] pages.  Returns the
        latent context [B, H, L]."""
        raise NotImplementedError

    def prefill_attend(self, q, k, v, *, causal: bool = True, window: int = 0,
                       q_block: int = 512, softcap: float = 0.0, q_offset=0,
                       unroll: bool = False):
        """Multi-token attend for prefill.  Default: the chunked XLA
        formulation (a fused ragged-prefill kernel is a future backend's
        override)."""
        return attention.chunked_attention(
            q, k, v, causal=causal, window=window, q_block=q_block,
            softcap=softcap, q_offset=q_offset, unroll=unroll)


@register_backend
class ReferenceBackend(AttentionBackend):
    """Gather+attend via XLA — the parity oracle."""

    name = "reference"

    def decode_attend(self, q, k_pages, v_pages, tables, pos, *, scale: float,
                      softcap: float = 0.0, window: int = 0):
        kg = attention.gather_pages(k_pages, tables)
        vg = attention.gather_pages(v_pages, tables)
        valid = attention.decode_valid_mask(pos, kg.shape[1], window=window)
        return attention.masked_token_attend(q, kg, vg, valid, scale=scale,
                                             softcap=softcap)

    def mla_decode_attend(self, q_eff, q_rope, ckv_pages, krope_pages, tables,
                          pos, *, scale: float):
        ccg = attention.gather_pages(ckv_pages, tables)
        crg = attention.gather_pages(krope_pages, tables)
        valid = attention.decode_valid_mask(pos, ccg.shape[1])
        return mla.mla_latent_attend(q_eff, q_rope, ccg, crg, valid,
                                     scale=scale)


@register_backend
class PallasBackend(ReferenceBackend):
    """Fused paged-attention decode (``repro.kernels.paged_attention``);
    interpret mode on CPU, Mosaic on TPU.  Prefill inherits the reference
    cores."""

    name = "pallas"

    def decode_attend(self, q, k_pages, v_pages, tables, pos, *, scale: float,
                      softcap: float = 0.0, window: int = 0):
        return paged_attention_decode(q, k_pages, v_pages, tables, pos,
                                      scale=scale, softcap=softcap,
                                      window=window)

    def mla_decode_attend(self, q_eff, q_rope, ckv_pages, krope_pages, tables,
                          pos, *, scale: float):
        return mla_paged_attention_decode(q_eff, q_rope, ckv_pages,
                                          krope_pages, tables, pos,
                                          scale=scale)
