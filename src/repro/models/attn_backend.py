"""Attention-backend registry: one dispatch point for every paged path.

The serving hot loop is paged attention — prefill writes K/V (or MLA latent)
through per-request page tables, decode reads every live token back per step.
How that read happens is a *backend* choice, orthogonal to the cache family:

* ``reference`` — the XLA gather+attend formulation (``pool[tables]``
  materializes the logical view in HBM, then dense masked attention) — the
  parity oracle every other backend is verified against.  Its decode attends
  keep the probability-weighted sum in fp32 and round to cache dtype only at
  the block output, the same single rounding point as the fused kernel's
  fp32 accumulator, so backends agree to an output ulp and greedy decode
  stays token-exact across them.
* ``pallas`` — the fused kernels: ``repro.kernels.paged_attention`` for
  decode and ``repro.kernels.ragged_prefill`` for chunk prefill.  In both,
  the page table rides into the kernel as a scalar-prefetch operand and the
  BlockSpec index maps walk it directly, so the gather never materializes.

A backend implements four *attend cores* — ``decode_attend`` (vanilla GQA +
sliding-window rings), ``mla_decode_attend`` (absorbed-latent),
``prefill_attend`` (ragged multi-token chunks against the paged pool), and
``mla_prefill_attend`` (materialized-K chunks against the latent pages) —
while the family framing (QKV projection, RoPE, page-table scatter, output
projection) is shared code in ``models.attention`` / ``models.mla`` that
every backend reuses.  Model code routes exclusively through
``backend.paged_prefill`` / ``backend.paged_decode``; future backends (GPU,
speculative verify) plug in by registering a class and overriding the cores
they fuse.

Selection is threaded from ``ServeConfig.attn_backend`` (``auto`` |
``reference`` | ``pallas``) through ``launch/serve.py --attn-backend`` and the
engine's jitted-step cache; ``auto`` picks the fused kernel exactly when jax
has a TPU to compile it for.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import attention, mla
from ..kernels.paged_attention import (mla_paged_attention_decode,
                                       mla_paged_attention_verify,
                                       paged_attention_decode,
                                       paged_attention_verify)
from ..kernels.ragged_prefill import (mla_ragged_prefill_attend,
                                      ragged_prefill_attend)

# ---------------------------------------------------------------- registry

_REGISTRY: Dict[str, "AttentionBackend"] = {}


def register_backend(cls):
    """Class decorator: instantiate and register under ``cls.name``."""
    _REGISTRY[cls.name] = cls()
    return cls


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_backend(name: str) -> str:
    """Resolve a ``ServeConfig.attn_backend`` knob to a concrete backend name.

    ``auto`` picks the fused kernel exactly when jax has a TPU to compile it
    for; elsewhere the XLA reference path is faster than an interpreted
    kernel (parity tests opt into interpret-mode pallas explicitly)."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    if name not in _REGISTRY:
        raise ValueError(f"unknown attention backend {name!r}; "
                         f"available: {available_backends()}")
    if name == "pallas" and jax.default_backend() not in ("tpu", "cpu"):
        # fail at config time with a clear message instead of deep inside a
        # Mosaic lowering attempt (the kernels are TPU-targeted; CPU runs
        # them in interpret mode, other backends have no lowering)
        raise ValueError(
            "attn_backend='pallas' requires a TPU (compiled) or CPU "
            f"(interpret mode); jax backend is {jax.default_backend()!r}")
    return name


def get_backend(name: str) -> "AttentionBackend":
    return _REGISTRY[resolve_backend(name)]


# ----------------------------------------------------- flat decode metadata

def decode_meta(cfg: ArchConfig, page_size: int, tables, pos):
    """Flat per-step decode metadata, computed once instead of re-derived by
    every layer's block inside the scan: the page-table rows, per-row
    absolute positions, and the physical (page, offset) write target of the
    step's new token — ring-aware for sliding-window families.  Works on
    numpy (engine host path) and jnp (traced) arrays alike; values feed the
    jitted ``decode_paged`` step as one pytree."""
    B = tables.shape[0]
    col = pos // page_size
    if cfg.sliding_window:
        # ring modulus contract: the ring IS the table width the engine
        # passes (>= window_pages; the pool may add slack pages, e.g. for
        # speculative verify rollback) — write targets and every attend
        # core's recovered-position mask use the same modulus
        col = col % tables.shape[1]
    xp = jnp if isinstance(tables, jax.Array) else np
    # live paged rows always have col < table width; the clamp covers rows
    # whose table is a null placeholder (state-slot families, idle slots)
    col = xp.minimum(col, tables.shape[1] - 1)
    return {"tables": tables, "pos": pos,
            "write_page": tables[xp.arange(B), col],
            "write_off": pos % page_size}


# ---------------------------------------------------- flat prefill metadata

def prefill_meta(cfg: ArchConfig, page_size: int, tables, slots, start,
                 n_tail, T: int):
    """Flat per-step prefill metadata, the prefill twin of ``decode_meta``:
    page-table rows, state-slot rows, each row's chunk offset (``start``,
    absolute position of the chunk's first token) and live token count, and
    the precomputed physical (page, offset) write target of every chunk
    position — shared by all layers instead of re-derived per block.

    ``T`` is the chunk's *text* width (the prefill bucket); the write-target
    arrays cover the hidden width ``cfg.n_image_tokens + T`` (vlm prepends
    its image prefix).  Padding rows/positions and, for sliding-window
    families, positions that age out of the ring before the chunk ends are
    routed to the reserved null page.  Works on numpy (engine host path) and
    jnp arrays alike; the jitted ``prefill_paged`` step consumes it as one
    pytree, so step shapes are keyed by (bucket, padded rows) — with
    chunking, by the chunk budget — never by individual prompt lengths."""
    xp = jnp if isinstance(tables, jax.Array) else np
    B = tables.shape[0]
    Th = cfg.n_image_tokens + T
    positions = start[:, None] + xp.arange(Th)[None, :]           # [B, Th]
    n_live = n_tail + cfg.n_image_tokens
    live = xp.arange(Th)[None, :] < n_live[:, None]
    col = positions // page_size
    if cfg.sliding_window:
        # ring modulus = table width (see decode_meta ring contract)
        R = tables.shape[1]
        live = live & (positions >= (start + n_live)[:, None]
                       - R * page_size)
        col = col % R
    col = xp.minimum(col, tables.shape[1] - 1)
    page = tables[xp.arange(B)[:, None], col]
    return {"tables": tables, "slots": slots, "start": start,
            "n_tail": n_tail, "n_live": n_live,
            "write_page": xp.where(live, page, 0),
            "write_off": positions % page_size}


# ---------------------------------------------------- flat verify metadata

def verify_meta(cfg: ArchConfig, page_size: int, tables, pos, n_q, Q: int):
    """Flat metadata for a small-q speculative *verify* step.

    Row ``b`` carries ``n_q[b]`` live queries (the last emitted token plus
    its draft) at absolute positions ``pos[b] .. pos[b] + n_q[b] - 1``; the
    step is padded to the fixed width ``Q = speculate_tokens + 1``.  Write
    targets follow the decode ring contract (modulus = table width); dead
    query rows (``j >= n_q[b]``) are routed to the reserved null page so
    their garbage K/V never lands in an owned page.  Works on numpy (engine
    host path) and jnp arrays alike."""
    xp = jnp if isinstance(tables, jax.Array) else np
    B = tables.shape[0]
    positions = pos[:, None] + xp.arange(Q)[None, :]              # [B, Q]
    live = xp.arange(Q)[None, :] < n_q[:, None]
    col = positions // page_size
    if cfg.sliding_window:
        col = col % tables.shape[1]
    col = xp.minimum(col, tables.shape[1] - 1)
    page = tables[xp.arange(B)[:, None], col]
    return {"tables": tables, "pos": pos, "n_q": n_q,
            "write_page": xp.where(live, page, 0),
            "write_off": positions % page_size}


# ----------------------------------------------------------- backend classes

class AttentionBackend:
    """Family routing (shared) + attend cores (the extension point)."""

    name = "abstract"

    # -------- public entry points: the only paged-attention call sites

    def paged_prefill(self, cfg: ArchConfig, p, x, cache, meta, freqs, *,
                      q_block: int = 512, unroll: bool = False):
        """Multi-token chunk prefill at an offset into the paged pool.
        ``meta`` is the flat per-step metadata from ``prefill_meta``.  Routes
        by cache family (MLA latent / sliding-window ring / vanilla KV);
        returns (out [B, T, d], new_cache)."""
        if cfg.use_mla:
            return mla.mla_paged_prefill_block(
                cfg, p, x, cache, meta, freqs, backend=self,
                q_block=q_block, unroll=unroll)
        return attention.paged_prefill_attention_block(
            cfg, p, x, cache, meta, freqs, backend=self,
            q_block=q_block, unroll=unroll)

    def paged_decode(self, cfg: ArchConfig, p, x, cache, meta, freqs):
        """One-token decode against the paged pool.  ``meta`` is the flat
        per-step metadata from ``decode_meta``; returns (out [B, d],
        new_cache)."""
        if cfg.use_mla:
            return mla.mla_paged_decode_block(cfg, p, x, cache, meta, freqs,
                                              backend=self)
        return attention.paged_decode_attention_block(cfg, p, x, cache, meta,
                                                      freqs, backend=self)

    def paged_verify(self, cfg: ArchConfig, p, x, cache, meta, freqs):
        """Small-q speculative verify against the paged pool: ``x`` is
        [B, Q, d] (last emitted token + draft, padded to Q), ``meta`` is the
        flat metadata from ``verify_meta``.  All Q tokens' K/V scatter into
        their pages first, then every query attends the post-write pool
        under the per-query causal mask — rejected drafts stay invisible to
        surviving queries and are overwritten by the next step's writes.
        Returns (out [B, Q, d], new_cache)."""
        if cfg.use_mla:
            return mla.mla_paged_verify_block(cfg, p, x, cache, meta, freqs,
                                              backend=self)
        return attention.paged_verify_attention_block(cfg, p, x, cache, meta,
                                                      freqs, backend=self)

    # -------- attend cores (override to fuse)
    #
    # Every core takes optional scale pools (``k_scale``/``v_scale``
    # [P, ps, K] bf16, or ``ckv_scale``/``krope_scale`` [P, ps] for MLA).
    # ``None`` (the default) means the payload pools hold bf16 values and
    # the core behaves exactly as before; non-None means the payloads are
    # int8 and must be dequantized ``f32(q) * f32(s)`` before use.

    def decode_attend(self, q, k_pages, v_pages, tables, pos, *, scale: float,
                      softcap: float = 0.0, window: int = 0,
                      k_scale=None, v_scale=None):
        """q: [B, H, D]; pools [P, ps, K, D]; tables [B, n] (ring when
        ``window > 0``); pos [B].  Returns [B, H, D]."""
        raise NotImplementedError

    def mla_decode_attend(self, q_eff, q_rope, ckv_pages, krope_pages, tables,
                          pos, *, scale: float, ckv_scale=None,
                          krope_scale=None):
        """Absorbed-latent scores + latent context: q_eff [B, H, L] /
        q_rope [B, H, R] against [P, ps, L] / [P, ps, R] pages.  Returns the
        latent context [B, H, L]."""
        raise NotImplementedError

    def verify_attend(self, q, k_pages, v_pages, tables, pos, n_q, *,
                      scale: float, softcap: float = 0.0, window: int = 0,
                      k_scale=None, v_scale=None):
        """Small-q verify attend: q [B, Q, H, D] (query j of row b sits at
        absolute position ``pos[b] + j``) against the *post-write* pool.
        Mask: token position <= pos + j (ring-recovered when ``window > 0``)
        and j < n_q[b]; dead query rows return exact zeros on every backend.
        Returns [B, Q, H, D]."""
        raise NotImplementedError

    def mla_verify_attend(self, q_eff, q_rope, ckv_pages, krope_pages,
                          tables, pos, n_q, *, scale: float, ckv_scale=None,
                          krope_scale=None):
        """Small-q absorbed-latent verify attend: q_eff [B, Q, H, L] /
        q_rope [B, Q, H, R] against the post-write latent pages, masked as
        ``verify_attend``.  Returns the latent context [B, Q, H, L]."""
        raise NotImplementedError

    def prefill_attend(self, q, k, v, k_pages, v_pages, tables, start, n_live,
                       *, window: int = 0, softcap: float = 0.0,
                       q_block: int = 512, unroll: bool = False,
                       k_scale=None, v_scale=None):
        """Ragged multi-token prefill attend against the paged pool.

        q: [B, T, H, D] roped chunk queries at per-row offsets ``start``;
        n_live: [B] real chunk tokens.  ``window == 0``: the chunk's K/V are
        already resident — ``k_pages``/``v_pages`` are the *post-write* pool
        and ``k``/``v`` are unused.  ``window > 0``: ``k_pages``/``v_pages``
        are the *pre-write* page ring (``tables`` truncated to the ring
        horizon) and ``k``/``v`` [B, T, K, D] carry the chunk's fresh roped
        K/V (always unquantized — only resident pages are int8).  Returns
        [B, T, H, D_v]."""
        raise NotImplementedError

    def mla_prefill_attend(self, q, ckv_pages, krope_pages, wkv_b, tables,
                           start, n_live, *, nope: int, q_block: int = 512,
                           unroll: bool = False, ckv_scale=None,
                           krope_scale=None):
        """Ragged MLA prefill attend: materialized-K semantics against the
        post-write latent pages (see ``mla.mla_materialized_prefill_attend``,
        the reference formulation).  q: [B, T, H, nope+rope]; returns
        [B, T, H, v_head_dim]."""
        raise NotImplementedError


def _gather_dequant(pages, scale_pages, tables):
    """Materialize the logical fp32 view of an int8 pool: gather payload and
    scale pages through the same table, dequant ``f32(q) * f32(s)``."""
    g = attention.gather_pages(pages, tables)
    return attention.dequant_int8(g, attention.gather_pages(scale_pages,
                                                            tables))


@register_backend
class ReferenceBackend(AttentionBackend):
    """Gather+attend via XLA — the parity oracle.

    int8 pools are dequantized to fp32 right after the gather, then run
    through the unchanged fp32 attend pipeline; the only added rounding
    point vs bf16 is the quantize/dequant round-trip itself, and the output
    is cast back to the query dtype — the same single output rounding the
    fused kernels keep."""

    name = "reference"

    def decode_attend(self, q, k_pages, v_pages, tables, pos, *, scale: float,
                      softcap: float = 0.0, window: int = 0,
                      k_scale=None, v_scale=None):
        if k_scale is not None:
            kg = _gather_dequant(k_pages, k_scale, tables)
            vg = _gather_dequant(v_pages, v_scale, tables)
        else:
            kg = attention.gather_pages(k_pages, tables)
            vg = attention.gather_pages(v_pages, tables)
        valid = attention.decode_valid_mask(pos, kg.shape[1], window=window)
        o = attention.masked_token_attend(q, kg, vg, valid, scale=scale,
                                          softcap=softcap)
        return o.astype(q.dtype)

    def mla_decode_attend(self, q_eff, q_rope, ckv_pages, krope_pages, tables,
                          pos, *, scale: float, ckv_scale=None,
                          krope_scale=None):
        if ckv_scale is not None:
            ccg = _gather_dequant(ckv_pages, ckv_scale, tables)
            crg = _gather_dequant(krope_pages, krope_scale, tables)
        else:
            ccg = attention.gather_pages(ckv_pages, tables)
            crg = attention.gather_pages(krope_pages, tables)
        valid = attention.decode_valid_mask(pos, ccg.shape[1])
        ctx = mla.mla_latent_attend(q_eff, q_rope, ccg, crg, valid,
                                    scale=scale)
        return ctx.astype(q_eff.dtype)

    def verify_attend(self, q, k_pages, v_pages, tables, pos, n_q, *,
                      scale: float, softcap: float = 0.0, window: int = 0,
                      k_scale=None, v_scale=None):
        if k_scale is not None:
            kg = _gather_dequant(k_pages, k_scale, tables)
            vg = _gather_dequant(v_pages, v_scale, tables)
        else:
            kg = attention.gather_pages(k_pages, tables)
            vg = attention.gather_pages(v_pages, tables)
        valid = attention.verify_valid_mask(pos, n_q, q.shape[1],
                                            kg.shape[1], window=window)
        o = attention.masked_multi_token_attend(q, kg, vg, valid,
                                                scale=scale, softcap=softcap)
        return o.astype(q.dtype)

    def mla_verify_attend(self, q_eff, q_rope, ckv_pages, krope_pages,
                          tables, pos, n_q, *, scale: float, ckv_scale=None,
                          krope_scale=None):
        if ckv_scale is not None:
            ccg = _gather_dequant(ckv_pages, ckv_scale, tables)
            crg = _gather_dequant(krope_pages, krope_scale, tables)
        else:
            ccg = attention.gather_pages(ckv_pages, tables)
            crg = attention.gather_pages(krope_pages, tables)
        valid = attention.verify_valid_mask(pos, n_q, q_eff.shape[1],
                                            ccg.shape[1])
        ctx = mla.mla_latent_verify_attend(q_eff, q_rope, ccg, crg, valid,
                                           scale=scale)
        return ctx.astype(q_eff.dtype)

    def prefill_attend(self, q, k, v, k_pages, v_pages, tables, start, n_live,
                       *, window: int = 0, softcap: float = 0.0,
                       q_block: int = 512, unroll: bool = False,
                       k_scale=None, v_scale=None):
        if window == 0:
            if k_scale is not None:
                kg = _gather_dequant(k_pages, k_scale, tables)
                vg = _gather_dequant(v_pages, v_scale, tables)
            else:
                kg = attention.gather_pages(k_pages, tables)
                vg = attention.gather_pages(v_pages, tables)
            o = attention.chunked_attention(
                q, kg, vg, causal=True, q_block=q_block, softcap=softcap,
                q_offset=start, unroll=unroll)
            return o.astype(q.dtype)
        if k_scale is not None:
            kr = _gather_dequant(k_pages, k_scale, tables)
            vr = _gather_dequant(v_pages, v_scale, tables)
            # the fresh chunk K/V stay unquantized; promote to fp32 so the
            # ring concat and the probability cast are fp32 end to end
            k, v = k.astype(jnp.float32), v.astype(jnp.float32)
        else:
            kr = attention.gather_pages(k_pages, tables)
            vr = attention.gather_pages(v_pages, tables)
        o = attention.ring_chunk_attention(
            q, k, v, kr, vr, start, n_live,
            window=window, softcap=softcap, q_block=q_block, unroll=unroll)
        return o.astype(q.dtype)

    def mla_prefill_attend(self, q, ckv_pages, krope_pages, wkv_b, tables,
                           start, n_live, *, nope: int, q_block: int = 512,
                           unroll: bool = False, ckv_scale=None,
                           krope_scale=None):
        o = mla.mla_materialized_prefill_attend(
            q, ckv_pages, krope_pages, wkv_b, tables, start, n_live,
            nope=nope, q_block=q_block, unroll=unroll,
            ckv_scale=ckv_scale, krope_scale=krope_scale)
        return o.astype(q.dtype)


@register_backend
class PallasBackend(ReferenceBackend):
    """Fused paged attention (``repro.kernels.paged_attention`` decode +
    ``repro.kernels.ragged_prefill`` chunk prefill); interpret mode on CPU,
    Mosaic on TPU."""

    name = "pallas"

    def decode_attend(self, q, k_pages, v_pages, tables, pos, *, scale: float,
                      softcap: float = 0.0, window: int = 0,
                      k_scale=None, v_scale=None):
        return paged_attention_decode(q, k_pages, v_pages, tables, pos,
                                      scale=scale, softcap=softcap,
                                      window=window, k_scale=k_scale,
                                      v_scale=v_scale)

    def mla_decode_attend(self, q_eff, q_rope, ckv_pages, krope_pages, tables,
                          pos, *, scale: float, ckv_scale=None,
                          krope_scale=None):
        return mla_paged_attention_decode(q_eff, q_rope, ckv_pages,
                                          krope_pages, tables, pos,
                                          scale=scale, ckv_scale=ckv_scale,
                                          krope_scale=krope_scale)

    def verify_attend(self, q, k_pages, v_pages, tables, pos, n_q, *,
                      scale: float, softcap: float = 0.0, window: int = 0,
                      k_scale=None, v_scale=None):
        return paged_attention_verify(q, k_pages, v_pages, tables, pos, n_q,
                                      scale=scale, softcap=softcap,
                                      window=window, k_scale=k_scale,
                                      v_scale=v_scale)

    def mla_verify_attend(self, q_eff, q_rope, ckv_pages, krope_pages,
                          tables, pos, n_q, *, scale: float, ckv_scale=None,
                          krope_scale=None):
        return mla_paged_attention_verify(q_eff, q_rope, ckv_pages,
                                          krope_pages, tables, pos, n_q,
                                          scale=scale, ckv_scale=ckv_scale,
                                          krope_scale=krope_scale)

    def prefill_attend(self, q, k, v, k_pages, v_pages, tables, start, n_live,
                       *, window: int = 0, softcap: float = 0.0,
                       q_block: int = 512, unroll: bool = False,
                       k_scale=None, v_scale=None):
        return ragged_prefill_attend(q, k, v, k_pages, v_pages, tables,
                                     start, n_live, window=window,
                                     softcap=softcap, k_scale=k_scale,
                                     v_scale=v_scale)

    def mla_prefill_attend(self, q, ckv_pages, krope_pages, wkv_b, tables,
                           start, n_live, *, nope: int, q_block: int = 512,
                           unroll: bool = False, ckv_scale=None,
                           krope_scale=None):
        return mla_ragged_prefill_attend(q, ckv_pages, krope_pages, wkv_b,
                                         tables, start, n_live, nope=nope,
                                         ckv_scale=ckv_scale,
                                         krope_scale=krope_scale)
