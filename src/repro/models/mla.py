"""Multi-head Latent Attention (DeepSeek-V2).

Train/prefill materialize per-head K/V from the rank-``kv_lora`` joint compression;
decode uses the *absorbed* formulation so the per-token cache is only
``kv_lora + rope_head_dim`` floats (512 + 64 for the 236B config) — this is what makes
the decode_32k cell fit, and is the TPU-native analogue of the paper-era concern of
shipping the full weight matrix to every mapper (here: shipping the full KV to every
chip) being the bottleneck.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (NEG_INF, chunked_attention, dequant_int8,
                        gather_pages, quantize_int8)
from .layers import apply_rope, rmsnorm
from .params import ParamDef


def mla_defs(cfg: ArchConfig):
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.nope_head_dim + cfg.rope_head_dim
    defs = {
        "wkv_a": ParamDef((d, cfg.kv_lora_rank + cfg.rope_head_dim), ("embed", "lora")),
        "kv_norm": ParamDef((cfg.kv_lora_rank,), ("lora",), init="ones"),
        "wkv_b": ParamDef((cfg.kv_lora_rank, h, cfg.nope_head_dim + cfg.v_head_dim),
                          ("lora", "heads", "head_dim")),
        "wo": ParamDef((h, cfg.v_head_dim, d), ("heads", "head_dim", "embed")),
    }
    if cfg.q_lora_rank:
        defs["wq_a"] = ParamDef((d, cfg.q_lora_rank), ("embed", "lora"))
        defs["q_norm"] = ParamDef((cfg.q_lora_rank,), ("lora",), init="ones")
        defs["wq_b"] = ParamDef((cfg.q_lora_rank, h, qk), ("lora", "heads", "head_dim"))
    else:
        defs["wq"] = ParamDef((d, h, qk), ("embed", "heads", "head_dim"))
    return defs


def _queries(cfg: ArchConfig, p, x):
    if cfg.q_lora_rank:
        cq = rmsnorm(x @ p["wq_a"], p["q_norm"])
        q = jnp.einsum("bsl,lhe->bshe", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    return q  # [B, S, H, nope+rope]


def mla_full_block(cfg: ArchConfig, p, x, freqs, *, positions=None, q_block=512, unroll=False):
    """Training / prefill MLA self-attention (materialized K/V)."""
    B, S, _ = x.shape
    nope, rope_d, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = _queries(cfg, p, x)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, freqs)

    ckv_full = x @ p["wkv_a"]
    ckv = rmsnorm(ckv_full[..., :cfg.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., cfg.kv_lora_rank:][:, :, None, :]       # [B,S,1,rope]
    k_rope = apply_rope(k_rope, positions, freqs)

    kv = jnp.einsum("bsl,lhe->bshe", ckv, p["wkv_b"])
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (rope_d,))], -1)
    qq = jnp.concatenate([q_nope, q_rope], -1)
    o = chunked_attention(qq, k, v, causal=True, q_block=q_block, unroll=unroll)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


def mla_cache_defs(cfg: ArchConfig, batch: int, max_len: int):
    return {
        "ckv": ParamDef((batch, max_len, cfg.kv_lora_rank), ("batch", "seq", "lora"), init="zeros"),
        "krope": ParamDef((batch, max_len, cfg.rope_head_dim), ("batch", "seq", None), init="zeros"),
    }


def mla_paged_cache_defs(cfg: ArchConfig, num_pages: int, page_size: int,
                         kv_dtype: str = "bf16"):
    """One layer's share of the paged latent pool: the absorbed cache payload
    (rank-``kv_lora`` latent + roped rope-head key) per token slot.

    ``kv_dtype == "int8"`` quantizes both payloads per token slot (the
    latent has one shared "kv head", so the scale leaves are [P, page_size]
    bf16), sharing the page axis exactly as the vanilla KV defs do."""
    payload_dt = jnp.int8 if kv_dtype == "int8" else jnp.bfloat16
    defs = {
        "ckv": ParamDef((num_pages, page_size, cfg.kv_lora_rank),
                        (None, "seq", "lora"), dtype=payload_dt,
                        init="zeros"),
        "krope": ParamDef((num_pages, page_size, cfg.rope_head_dim),
                          (None, "seq", None), dtype=payload_dt,
                          init="zeros"),
    }
    if kv_dtype == "int8":
        defs["ckv_scale"] = ParamDef((num_pages, page_size), (None, "seq"),
                                     dtype=jnp.bfloat16, init="zeros")
        defs["krope_scale"] = ParamDef((num_pages, page_size), (None, "seq"),
                                       dtype=jnp.bfloat16, init="zeros")
    return defs


def mla_paged_prefill_block(cfg: ArchConfig, p, x, cache, meta, freqs,
                            backend, *, q_block=512, unroll=False):
    """Multi-token MLA chunk prefill, straight into the latent pages.

    Mirrors ``paged_prefill_attention_block``: the chunk's latent is written
    token-granularly through the page table (``meta`` carries the
    precomputed write targets; padding rows go to the null page), then the
    attend against the *whole* logical sequence — cached/earlier-chunk
    prefix pages plus the fresh chunk — is delegated to
    ``backend.mla_prefill_attend``, whose contract is the materialized-K
    formulation of ``mla_full_block`` (per-head K/V rebuilt from the
    post-write latent pages with ``wkv_b``)."""
    B, T, _ = x.shape
    nope = cfg.nope_head_dim
    tables, start, n_live = meta["tables"], meta["start"], meta["n_live"]
    positions = start[:, None] + jnp.arange(T)[None, :]              # [B, T]
    q = _queries(cfg, p, x)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, freqs)

    ckv_full = x @ p["wkv_a"]
    ckv = rmsnorm(ckv_full[..., :cfg.kv_lora_rank], p["kv_norm"])
    krope = apply_rope(ckv_full[..., cfg.kv_lora_rank:][:, :, None, :],
                       positions, freqs)[:, :, 0, :]

    wp, wo_ = meta["write_page"], meta["write_off"]
    scales = {}
    if "ckv_scale" in cache:
        ckv, cs = quantize_int8(ckv)
        krope, rs = quantize_int8(krope)
        scales = {"ckv_scale": cache["ckv_scale"].at[wp, wo_].set(cs),
                  "krope_scale": cache["krope_scale"].at[wp, wo_].set(rs)}
    cc = cache["ckv"].at[wp, wo_].set(ckv.astype(cache["ckv"].dtype))
    cr = cache["krope"].at[wp, wo_].set(krope.astype(cache["krope"].dtype))

    qq = jnp.concatenate([q_nope, q_rope], -1)
    o = backend.mla_prefill_attend(qq, cc, cr, p["wkv_b"], tables, start,
                                   n_live, nope=nope, q_block=q_block,
                                   unroll=unroll, **scales)
    new_cache = {"ckv": cc, "krope": cr}
    new_cache.update(scales)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), new_cache


def mla_materialized_prefill_attend(q, ckv_pages, krope_pages, wkv_b, tables,
                                    start, n_live, *, nope: int,
                                    q_block: int = 512, unroll: bool = False,
                                    ckv_scale=None, krope_scale=None):
    """The reference MLA prefill attend: gather the (post-write) latent
    pages, materialize per-head K/V from them with ``wkv_b`` exactly as
    ``mla_full_block`` does — so a cached prefix or an earlier chunk is read
    as if this call had prefilled it itself — and run the chunked XLA
    attend.  q: [B, T, H, nope+rope] (rope part already roped).  int8 pages
    arrive with their per-token-slot scale pools (``ckv_scale`` /
    ``krope_scale``) and are dequantized to fp32 after the gather.  Returns
    the attended values [B, T, H, v_head_dim]."""
    rope_d = q.shape[-1] - nope
    ccg = gather_pages(ckv_pages, tables)
    crg = gather_pages(krope_pages, tables)
    if ckv_scale is not None:
        ccg = dequant_int8(ccg, gather_pages(ckv_scale, tables))
        crg = dequant_int8(crg, gather_pages(krope_scale, tables))
    kv = jnp.einsum("bsl,lhe->bshe", ccg, wkv_b)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(crg[:, :, None, :],
                                  k_nope.shape[:-1] + (rope_d,))], -1)
    return chunked_attention(q, k, v, causal=True, q_block=q_block,
                             q_offset=start, unroll=unroll)


def mla_paged_decode_block(cfg: ArchConfig, p, x, cache, meta, freqs,
                           backend):
    """Absorbed one-token decode against the latent pages (the paged twin of
    ``mla_decode_block``).  ``meta`` is the flat per-step metadata from
    ``attn_backend.decode_meta``; the latent-space attend (scores against
    ckv/krope pages, context in rank-``kv_lora`` space) is delegated to
    ``backend.mla_decode_attend``."""
    B = x.shape[0]
    nope, rope_d = cfg.nope_head_dim, cfg.rope_head_dim
    pos = meta["pos"]
    scale = 1.0 / math.sqrt(nope + rope_d)

    q = _queries(cfg, p, x[:, None, :])[:, 0]                      # [B,H,·]
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope[:, None], pos[:, None], freqs)[:, 0]

    ckv_full = x @ p["wkv_a"]
    ckv_new = rmsnorm(ckv_full[..., :cfg.kv_lora_rank], p["kv_norm"])
    kr_new = apply_rope(ckv_full[..., None, cfg.kv_lora_rank:][:, None],
                        pos[:, None], freqs)[:, 0, 0]

    wp, wo_ = meta["write_page"], meta["write_off"]
    scales = {}
    if "ckv_scale" in cache:
        ckv_new, cs = quantize_int8(ckv_new)
        kr_new, rs = quantize_int8(kr_new)
        scales = {"ckv_scale": cache["ckv_scale"].at[wp, wo_].set(cs),
                  "krope_scale": cache["krope_scale"].at[wp, wo_].set(rs)}
    cc = cache["ckv"].at[wp, wo_].set(ckv_new.astype(cache["ckv"].dtype))
    cr = cache["krope"].at[wp, wo_].set(kr_new.astype(cache["krope"].dtype))

    w_uk = p["wkv_b"][..., :nope]                                  # [L,H,nope]
    q_eff = jnp.einsum("bhn,lhn->bhl", q_nope, w_uk)
    ctx = backend.mla_decode_attend(q_eff, q_rope, cc, cr, meta["tables"],
                                    pos, scale=scale, **scales)
    w_uv = p["wkv_b"][..., nope:]                                  # [L, H, v]
    o = jnp.einsum("bhl,lhv->bhv", ctx, w_uv)
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"])
    new_cache = {"ckv": cc, "krope": cr}
    new_cache.update(scales)
    return out, new_cache


def mla_latent_attend(q_eff, q_rope, cc, cr, valid, *, scale: float):
    """The absorbed-latent attend every reference MLA decode path shares.

    q_eff: [B, H, L] (``w_uk``-absorbed); q_rope: [B, H, R]; cc: [B, S, L];
    cr: [B, S, R] (contiguous logical views); valid: [B, S] bool.  fp32
    scores and fp32 probability-weighted context, rounded to cache dtype
    only at the output — the same rounding point as the fused kernel.
    Returns the latent context [B, H, L]."""
    s = jnp.einsum("bhl,bsl->bhs", q_eff, cc,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope, cr,
                       preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", a, cc,
                     preferred_element_type=jnp.float32)
    return ctx.astype(cc.dtype)


def mla_latent_verify_attend(q_eff, q_rope, cc, cr, valid, *, scale: float):
    """``mla_latent_attend`` with a small query axis (speculative verify).

    q_eff: [B, Q, H, L]; q_rope: [B, Q, H, R]; valid: [B, Q, S] per-query
    masks (``attention.verify_valid_mask``).  Per query row the ops are the
    exact per-row ops of the one-token attend, so ``Q == 1`` reproduces it
    bit-for-bit; all-False rows (dead / padded queries) return exact zeros,
    matching the fused verify kernel's zero-init accumulator.  Returns the
    latent context [B, Q, H, L]."""
    s = jnp.einsum("bqhl,bsl->bqhs", q_eff, cc,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bqhr,bsr->bqhs", q_rope, cr,
                       preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(valid[:, :, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    any_valid = jnp.any(valid, axis=-1)                            # [B, Q]
    a = jnp.where(any_valid[:, :, None, None], a, 0.0)
    ctx = jnp.einsum("bqhs,bsl->bqhl", a, cc,
                     preferred_element_type=jnp.float32)
    return ctx.astype(cc.dtype)


def mla_paged_verify_block(cfg: ArchConfig, p, x, cache, meta, freqs,
                           backend):
    """Small-q speculative verify against the latent pages (the verify twin
    of ``mla_paged_decode_block``).  x: [B, Q, d] — last emitted token plus
    draft, padded to Q; ``meta`` from ``attn_backend.verify_meta``.
    Write-all-then-attend: every query's latent scatters first (dead rows to
    the null page), then the absorbed attend masks per query — see
    ``attention.paged_verify_attention_block`` for the rollback contract.
    Returns (out [B, Q, d], new_cache)."""
    Q = x.shape[1]
    nope, rope_d = cfg.nope_head_dim, cfg.rope_head_dim
    pos = meta["pos"]
    scale = 1.0 / math.sqrt(nope + rope_d)
    positions = pos[:, None] + jnp.arange(Q)[None, :]              # [B, Q]

    q = _queries(cfg, p, x)                                        # [B,Q,H,·]
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, freqs)

    ckv_full = x @ p["wkv_a"]
    ckv_new = rmsnorm(ckv_full[..., :cfg.kv_lora_rank], p["kv_norm"])
    kr_new = apply_rope(ckv_full[..., cfg.kv_lora_rank:][:, :, None, :],
                        positions, freqs)[:, :, 0, :]

    wp, wo_ = meta["write_page"], meta["write_off"]
    scales = {}
    if "ckv_scale" in cache:
        ckv_new, cs = quantize_int8(ckv_new)
        kr_new, rs = quantize_int8(kr_new)
        scales = {"ckv_scale": cache["ckv_scale"].at[wp, wo_].set(cs),
                  "krope_scale": cache["krope_scale"].at[wp, wo_].set(rs)}
    cc = cache["ckv"].at[wp, wo_].set(ckv_new.astype(cache["ckv"].dtype))
    cr = cache["krope"].at[wp, wo_].set(kr_new.astype(cache["krope"].dtype))

    w_uk = p["wkv_b"][..., :nope]                                  # [L,H,nope]
    q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)
    ctx = backend.mla_verify_attend(q_eff, q_rope, cc, cr, meta["tables"],
                                    pos, meta["n_q"], scale=scale, **scales)
    w_uv = p["wkv_b"][..., nope:]                                  # [L, H, v]
    o = jnp.einsum("bqhl,lhv->bqhv", ctx, w_uv)
    out = jnp.einsum("bqhv,hvd->bqd", o, p["wo"])
    new_cache = {"ckv": cc, "krope": cr}
    new_cache.update(scales)
    return out, new_cache


def mla_decode_block(cfg: ArchConfig, p, x, cache, pos, freqs):
    """Absorbed one-token decode.  x: [B, d]."""
    B = x.shape[0]
    nope, rope_d = cfg.nope_head_dim, cfg.rope_head_dim
    scale = 1.0 / math.sqrt(nope + rope_d)

    q = _queries(cfg, p, x[:, None, :])[:, 0]                      # [B,H,nope+rope]
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope[:, None], pos[:, None], freqs)[:, 0]

    ckv_full = x @ p["wkv_a"]
    ckv_new = rmsnorm(ckv_full[..., :cfg.kv_lora_rank], p["kv_norm"])
    kr_new = apply_rope(ckv_full[..., None, cfg.kv_lora_rank:][:, None], pos[:, None], freqs)[:, 0, 0]

    b = jnp.arange(B)
    cc = cache["ckv"].at[b, pos].set(ckv_new.astype(cache["ckv"].dtype))
    cr = cache["krope"].at[b, pos].set(kr_new.astype(cache["krope"].dtype))

    # absorb W_uk into q:  q_eff[b,h,l] = sum_n q_nope[b,h,n] wkv_b[l,h,n]
    w_uk = p["wkv_b"][..., :nope]                                  # [L, H, nope]
    q_eff = jnp.einsum("bhn,lhn->bhl", q_nope, w_uk)
    valid = jnp.arange(cc.shape[1])[None, :] <= pos[:, None]
    ctx = mla_latent_attend(q_eff, q_rope, cc, cr, valid, scale=scale)
    w_uv = p["wkv_b"][..., nope:]                                  # [L, H, v]
    o = jnp.einsum("bhl,lhv->bhv", ctx, w_uv)
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"])
    return out, {"ckv": cc, "krope": cr}
