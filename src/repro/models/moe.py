"""Mixture-of-Experts with token-choice top-k routing and per-expert capacity.

Dispatch is *gather-based*: for each (group, expert) the top-C tokens by gate
probability are gathered into a dense ``[G, E, C, D]`` buffer (C = capacity), run
through the expert matmuls, weighted by their gate, and scatter-added back.  This
keeps dispatch cost at gather/scatter (≈0 FLOPs) instead of the classic
``[tokens, E, C]`` one-hot einsum, whose FLOPs would dwarf the expert matmuls at
160 experts.  Experts are sharded over the ``model`` mesh axis (EP); the gathered
buffer is sharding-constrained so XLA materializes the EP all-to-all around the
expert matmuls.  Tokens over capacity are dropped (lowest gate first), per the
standard capacity-factor contract.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, round_up
from . import shardings
from .layers import act_fn
from .params import ParamDef


def moe_defs(cfg: ArchConfig):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    defs = {
        "router": ParamDef((d, e), ("embed", None), dtype=jnp.float32),
        "up": ParamDef((e, d, f), ("experts", "embed", "ff")),
        "down": ParamDef((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.mlp_gated:
        defs["gate"] = ParamDef((e, d, f), ("experts", "embed", "ff"))
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        defs["shared_up"] = ParamDef((d, fs), ("embed", "ff"))
        defs["shared_down"] = ParamDef((fs, d), ("ff", "embed"))
        if cfg.mlp_gated:
            defs["shared_gate"] = ParamDef((d, fs), ("embed", "ff"))
    return defs


def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = math.ceil(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return min(tokens_per_group, max(8, round_up(c, 8)))


def moe_apply(cfg: ArchConfig, p, x, *, mesh=None,
              cap: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """x: [G, S, D] (groups route independently).  Returns (out, aux_loss).

    ``cap`` overrides the expert capacity; ``cap == S`` guarantees no token
    is ever dropped, making each token's output independent of its
    co-batched neighbors — the speculative verify step relies on this to
    stay bit-identical to the (never-dropping, small-batch) decode step."""
    G, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S) if cap is None else cap
    act = act_fn(cfg.act)

    logits = (x.astype(jnp.float32) @ p["router"])                 # [G,S,E] fp32
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                         # [G,S,K]
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)              # renormalize top-k

    # dense gate map via scatter-add (no [G,S,K,E] one-hot materialized)
    gg = jnp.arange(G)[:, None, None]
    ss = jnp.arange(S)[None, :, None]
    gates = jnp.zeros((G, S, E), jnp.float32).at[gg, ss, top_i].add(top_p)

    # per-expert top-C tokens by gate (capacity with lowest-gate dropping)
    scores = jnp.swapaxes(gates, 1, 2)                             # [G,E,S]
    vals, idx = jax.lax.top_k(scores, C)                           # [G,E,C]
    keep = (vals > 0.0)

    xe = jax.vmap(lambda xg, ig: xg[ig])(x, idx)                   # [G,E,C,D]
    if mesh is not None:
        xe = shardings.constrain(xe, mesh, ("batch", "experts", None, None))
    if cfg.mlp_gated:
        h = act(jnp.einsum("gecd,edf->gecf", xe, p["gate"])) * jnp.einsum(
            "gecd,edf->gecf", xe, p["up"])
    else:
        h = act(jnp.einsum("gecd,edf->gecf", xe, p["up"]))
    ye = jnp.einsum("gecf,efd->gecd", h, p["down"])
    ye = ye * (vals * keep)[..., None].astype(ye.dtype)
    if mesh is not None:
        ye = shardings.constrain(ye, mesh, ("batch", "experts", None, None))

    def scatter_g(idx_g, ye_g):
        return jnp.zeros((S, D), ye.dtype).at[idx_g.reshape(-1)].add(
            ye_g.reshape(-1, D), mode="drop")
    out = jax.vmap(scatter_g)(idx, ye)                             # [G,S,D]
    if mesh is not None:
        out = shardings.constrain(out, mesh, ("batch", None, None))

    if cfg.n_shared_experts:
        if cfg.mlp_gated:
            hs = act(x @ p["shared_gate"]) * (x @ p["shared_up"])
        else:
            hs = act(x @ p["shared_up"])
        out = out + hs @ p["shared_down"]

    # switch-style load-balance auxiliary loss
    frac = jnp.mean(gates > 0.0, axis=(0, 1)).astype(jnp.float32)  # fraction routed
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_p)
    return out, aux


def moe_decode_apply(cfg: ArchConfig, p, x, *, mesh=None) -> jax.Array:
    """x: [B, D] single-token batch — routed as one group of B tokens."""
    out, _ = moe_apply(cfg, p, x[None], mesh=mesh)
    return out[0]
