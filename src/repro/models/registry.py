"""Model registry: ``build_model(cfg)`` + abstract input specs per workload shape."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from ..configs.base import ArchConfig, ShapeConfig, supports
from . import shardings
from .encdec import EncDecLM
from .params import ParamDef, abstract_tree, init_tree, specs_tree
from .transformer import DecoderLM


def build_model(cfg: ArchConfig, attn_backend: str = "reference"):
    """Model for ``cfg``; ``attn_backend`` picks the paged-attention backend
    (``models.attn_backend`` registry) the serving paths route through."""
    if cfg.enc_dec:
        return EncDecLM(cfg, attn_backend)
    return DecoderLM(cfg, attn_backend)


def input_defs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, ParamDef]:
    """ParamDef tree for every model input of this (arch, shape) cell.

    Training / prefill inputs are token ids (plus stub frontend embeddings for
    audio/vlm archs); decode inputs are one token + the KV cache (declared via
    ``build_model(cfg).cache_defs``)."""
    ok, why = supports(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape.name}: {why}")
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.enc_dec:
            return {
                "frames": ParamDef((B, S, cfg.frontend_dim), ("batch", None, None),
                                   jnp.bfloat16, "zeros"),
                "tokens": ParamDef((B, S), ("batch", None), jnp.int32, "zeros"),
            }
        if cfg.n_image_tokens:
            return {
                "tokens": ParamDef((B, S - cfg.n_image_tokens), ("batch", None),
                                   jnp.int32, "zeros"),
                "image_embeds": ParamDef((B, cfg.n_image_tokens, cfg.frontend_dim),
                                         ("batch", None, None), jnp.bfloat16, "zeros"),
            }
        return {"tokens": ParamDef((B, S), ("batch", None), jnp.int32, "zeros")}
    # decode: one new token against a seq_len cache
    return {"tokens": ParamDef((B,), ("batch",), jnp.int32, "zeros")}


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Optional[Mesh] = None):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    return abstract_tree(input_defs(cfg, shape), mesh)


def abstract_params(cfg: ArchConfig, mesh: Optional[Mesh] = None):
    return abstract_tree(build_model(cfg).param_defs(), mesh)


def abstract_cache(cfg: ArchConfig, shape: ShapeConfig, mesh: Optional[Mesh] = None):
    model = build_model(cfg)
    return abstract_tree(model.cache_defs(shape.global_batch, shape.seq_len), mesh)


def init_params(cfg: ArchConfig, key):
    return init_tree(build_model(cfg).param_defs(), key)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, key=None):
    defs = build_model(cfg).cache_defs(batch, max_len)
    return init_tree(defs, jax.random.PRNGKey(0) if key is None else key)
