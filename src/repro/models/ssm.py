"""Mamba-2 SSD (state-space duality) block — chunked, matmul-dominant formulation.

The chunked algorithm (Dao & Gu, 2024, §6) splits the sequence into chunks of Q
tokens: within-chunk terms are batched matmuls (MXU-friendly on TPU), and the
cross-chunk recurrence is a length-``S/Q`` scan over the tiny ``[H, P, N]`` state.
Decode is the exact O(1) recurrence, which is why mamba2 runs the ``long_500k``
cell that full-attention archs must skip.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import rmsnorm
from .params import ParamDef


# ------------------------------------------------------------------ param defs

def ssm_defs(cfg: ArchConfig):
    d, di = cfg.d_model, cfg.d_inner
    n, g, h, w = cfg.ssm_state, cfg.ssm_n_groups, cfg.ssm_n_heads, cfg.conv_width
    return {
        "wz": ParamDef((d, di), ("embed", "ff")),
        "wx": ParamDef((d, di), ("embed", "ff")),
        "wB": ParamDef((d, g * n), ("embed", None)),
        "wC": ParamDef((d, g * n), ("embed", None)),
        "wdt": ParamDef((d, h), ("embed", "heads")),
        "dt_bias": ParamDef((h,), ("heads",), init="zeros"),
        "A_log": ParamDef((h,), ("heads",), dtype=jnp.float32, init="zeros"),
        "D": ParamDef((h,), ("heads",), dtype=jnp.float32, init="ones"),
        "conv_x": ParamDef((w, di), ("conv", "ff")),
        "conv_B": ParamDef((w, g * n), ("conv", None)),
        "conv_C": ParamDef((w, g * n), ("conv", None)),
        "norm": ParamDef((di,), ("ff",), init="ones"),
        "wo": ParamDef((di, d), ("ff", "embed")),
    }


def _causal_depthwise_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """u: [B, S, C]; w: [W, C] — causal depthwise conv via W shifted adds."""
    W = w.shape[0]
    out = u * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[-1 - i]
    return out


def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., T] -> [..., T, T] lower-triangular segment sums (−inf above diag)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xd, dtA, B, C, chunk: int, init_state=None):
    """SSD scan.

    xd:  [b, s, h, p]   (already dt-scaled inputs)
    dtA: [b, s, h]      (dt * A, negative)
    B,C: [b, s, n]      (single group)
    Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = xd.shape
    n = B.shape[-1]
    Q = min(chunk, s)
    pad = (-s) % Q
    if pad:
        # zero-pad: dtA=0 -> decay 1, xd=0 -> state unchanged through padding
        xd = jnp.pad(xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtA = jnp.pad(dtA, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s_out = s
        s = s + pad
    else:
        s_out = s
    c = s // Q
    xd = xd.reshape(b, c, Q, h, p)
    dtA = dtA.reshape(b, c, Q, h).transpose(0, 3, 1, 2)            # [b,h,c,q]
    Bc = B.reshape(b, c, Q, n)
    Cc = C.reshape(b, c, Q, n)

    A_cs = jnp.cumsum(dtA, -1)                                     # [b,h,c,q]
    L = jnp.exp(_segsum(dtA))                                      # [b,h,c,q,q]
    # within-chunk (diagonal) term
    y_diag = jnp.einsum("bcqn,bckn,bhcqk,bckhp->bcqhp", Cc, Bc, L, xd)

    # per-chunk input states (recurrence is carried in fp32)
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)                  # [b,h,c,q]
    states = jnp.einsum("bckn,bhck,bckhp->bchpn", Bc, decay_states, xd)
    states = states.astype(jnp.float32)

    # cross-chunk recurrence
    chunk_decay = jnp.exp(A_cs[..., -1]).astype(jnp.float32)       # [b,h,c]
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                              # [b,h,p,n], [b,h]
        new = carry * dec[..., None, None] + st
        return new, carry                                          # emit state *before* chunk

    final, prev = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, -1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)                                # [b,c,h,p,n]

    state_decay = jnp.exp(A_cs)                                    # [b,h,c,q]
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", Cc, prev, state_decay)
    y = (y_diag + y_off).astype(xd.dtype).reshape(b, s, h, p)
    return y[:, :s_out], final


def ssm_block(cfg: ArchConfig, p, x, *, init_state=None,
              length_mask=None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence SSD block. x: [B, S, d_model] -> ([B,S,d_model], final_state).

    ``length_mask`` ([B, S] bool, optional) marks real positions; masked
    (padding) positions get ``dt = 0`` so they neither decay nor feed the
    state — the returned ``final_state`` is then exactly the state after the
    last *real* position, which is what serving's bucketed (right-padded)
    prefill needs.  Outputs at masked positions are garbage; real positions
    are bit-identical to the unmasked path."""
    h, pd, n = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = x @ p["wz"]
    xs = _causal_depthwise_conv(x @ p["wx"], p["conv_x"])
    xs = jax.nn.silu(xs)
    B = jax.nn.silu(_causal_depthwise_conv(x @ p["wB"], p["conv_B"]))
    C = jax.nn.silu(_causal_depthwise_conv(x @ p["wC"], p["conv_C"]))
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if length_mask is not None:
        dt = dt * length_mask[..., None]        # pads: decay 1, input 0
    A = -jnp.exp(p["A_log"])                                       # [h], negative
    xh = xs.reshape(*xs.shape[:2], h, pd)
    xd = xh * dt[..., None].astype(xh.dtype)
    y, final = ssd_chunked(xd, dt * A, B, C, cfg.ssm_chunk, init_state)
    y = y + p["D"].astype(y.dtype)[:, None] * xh
    y = y.reshape(*x.shape[:2], cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return y @ p["wo"], final


# --------------------------------------------------------------------- decode

def ssm_cache_defs(cfg: ArchConfig, batch: int):
    di, gn = cfg.d_inner, cfg.ssm_n_groups * cfg.ssm_state
    return {
        "conv_x": ParamDef((batch, cfg.conv_width - 1, di), ("batch", None, "ff"), init="zeros"),
        "conv_B": ParamDef((batch, cfg.conv_width - 1, gn), ("batch", None, None), init="zeros"),
        "conv_C": ParamDef((batch, cfg.conv_width - 1, gn), ("batch", None, None), init="zeros"),
        "state": ParamDef((batch, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state),
                          ("batch", "heads", None, None), dtype=jnp.float32, init="zeros"),
    }


def _conv_step(u, cache, w):
    """u: [B, C]; cache: [B, W-1, C]; w: [W, C] -> (y [B,C], new_cache)."""
    full = jnp.concatenate([cache, u[:, None]], axis=1)            # [B, W, C]
    y = jnp.einsum("bwc,wc->bc", full, w)
    return y, full[:, 1:]


def ssm_decode_block(cfg: ArchConfig, p, x, cache):
    """One-token decode. x: [B, d_model]."""
    h, pd, n = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = x @ p["wz"]
    xs, cx = _conv_step(x @ p["wx"], cache["conv_x"], p["conv_x"])
    xs = jax.nn.silu(xs)
    B, cB = _conv_step(x @ p["wB"], cache["conv_B"], p["conv_B"])
    C, cC = _conv_step(x @ p["wC"], cache["conv_C"], p["conv_C"])
    B, C = jax.nn.silu(B), jax.nn.silu(C)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                           # [B,h]
    xh = xs.reshape(-1, h, pd)
    st = cache["state"]
    st = st * dA[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xh.astype(jnp.float32), B.astype(jnp.float32), dt)
    y = jnp.einsum("bhpn,bn->bhp", st, C.astype(jnp.float32)).astype(x.dtype)
    y = y + p["D"].astype(y.dtype)[:, None] * xh
    y = y.reshape(-1, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    new_cache = {"conv_x": cx, "conv_B": cB, "conv_C": cC, "state": st}
    return y @ p["wo"], new_cache
