"""Train / serve step factories.

Two engines build the same training step (see DESIGN.md §2):
  * ``pjit``      — sharding-constraint formulation; XLA schedules/overlaps the
    gradient collectives.  The dry-run/roofline substrate.
  * ``mapreduce`` — the paper-faithful explicit map/combine/reduce via
    ``shard_map`` with selectable reduce mode (allreduce | hierarchical |
    compressed int8+EF).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from ..core.mapreduce import mapreduce_value_and_grad
from ..optim import OptConfig, apply_updates, init_opt_state, opt_state_defs
from . import shardings
from .params import abstract_tree, init_tree, specs_tree
from .registry import build_model, input_defs


# ------------------------------------------------------------- train steps

def make_train_step(cfg: ArchConfig, mesh: Optional[Mesh], opt_cfg: OptConfig,
                    *, engine: str = "pjit", reduce_mode: str = "allreduce",
                    n_micro: int = 1):
    """Returns ``step(params, opt_state, batch) -> (params, opt_state, metrics)``
    (un-jitted; caller jits with the sharding trees from ``train_shardings``)."""
    model = build_model(cfg)

    def loss_fn(params, batch):
        return model.loss(params, batch, mesh)

    if engine == "pjit":
        def step(params, opt_state, batch):
            if n_micro > 1:
                def to_micro(x):
                    return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
                mb = jax.tree.map(to_micro, batch)

                def acc(carry, m):
                    gsum, lsum = carry
                    (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, m)
                    return (jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g),
                            lsum + l), None
                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / n_micro, gsum)
                loss = lsum / n_micro
                aux = {}
            else:
                (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch)
            params, opt_state, om = apply_updates(params, grads, opt_state, opt_cfg)
            return params, opt_state, {"loss": loss, **om}
        return step

    assert engine == "mapreduce", engine
    # inside shard_map the data axes are Manual: global sharding constraints
    # would reference a mismatched mesh, so the model runs constraint-free and
    # the engine's in_specs/psum carry the distribution
    def loss_fn_manual(params, batch):
        return model.loss(params, batch, None)

    mr = mapreduce_value_and_grad(loss_fn_manual, mesh, reduce_mode=reduce_mode,
                                  n_micro=n_micro)

    def step(params, opt_state, batch):
        err = opt_state.get("comp_err") if isinstance(opt_state, dict) else None
        loss, grads, new_err, aux = mr(params, batch, err)
        inner = {k: v for k, v in opt_state.items() if k != "comp_err"}
        params, inner, om = apply_updates(params, grads, inner, opt_cfg)
        if new_err is not None:
            inner["comp_err"] = new_err
        return params, inner, {"loss": loss, **om}
    return step


def train_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    opt_cfg: OptConfig):
    """(params, opt_state, batch) NamedSharding trees for jit in/out_shardings."""
    model = build_model(cfg)
    pdefs = model.param_defs()
    odefs = opt_state_defs(pdefs, opt_cfg)
    bdefs = input_defs(cfg, shape)
    return (specs_tree(pdefs, mesh), specs_tree(odefs, mesh),
            specs_tree(bdefs, mesh))


def abstract_train_args(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                        opt_cfg: OptConfig):
    """ShapeDtypeStructs (with shardings) for lower() — zero allocation."""
    model = build_model(cfg)
    pdefs = model.param_defs()
    odefs = opt_state_defs(pdefs, opt_cfg)
    bdefs = input_defs(cfg, shape)
    return (abstract_tree(pdefs, mesh), abstract_tree(odefs, mesh),
            abstract_tree(bdefs, mesh))


# ------------------------------------------------------------- serve steps

def make_serve_step(cfg: ArchConfig, mesh: Optional[Mesh], kind: str,
                    attn_backend: str = "reference"):
    """kind='decode': step(params, cache, tokens) -> (next_tokens, cache)
       kind='prefill': step(params, batch) -> (logits, cache)
       kind='prefill_at': step(params, batch, last_idx) -> (logits, cache)
         (logits read at per-row position ``last_idx`` — bucketed prompts)
       kind='decode_paged': step(params, kv, state, meta, tokens)
         -> (next_tokens, ok, new_kv, new_state) — slot-indexed continuous-
         batching decode against the paged pool and/or state-slot pool
         (see repro.serving; {} stands in for an absent pool).  ``meta`` is
         the flat per-step metadata pytree from ``attn_backend.decode_meta``
         (page-table rows, positions, precomputed write targets).  ``ok`` is
         a per-row bool: True iff every logit in that row is finite — the
         engine's NaN/inf quarantine guard, computed in-jit so the argmax
         result never has to leave the device alongside raw logits.
       kind='verify_paged': step(params, kv, state, meta, tokens)
         -> (next_tokens [B, Q], ok [B], new_kv, new_state) — small-q
         speculative verify: ``tokens`` is [B, Q] (last emitted token +
         draft per slot) and ``meta`` comes from ``attn_backend.verify_meta``;
         row j of the output is the greedy next token after position pos + j,
         from which the engine computes the accepted draft prefix.  ``ok``
         reduces finiteness over both the Q and vocab axes.
       kind='prefill_paged': step(params, kv, state, meta, tokens, extras)
         -> (logits, new_kv, new_state) — batched chunk prefill straight
         into the pools.  ``meta`` is the flat per-step metadata pytree from
         ``attn_backend.prefill_meta`` (page tables, slot rows, per-row
         chunk offsets + live counts, precomputed write targets): positions
         < start are read from already-resident pages — radix prefix-cache
         hits and earlier chunks alike — recurrent/cross state is scattered
         into the slot rows, and ``extras`` carries frontend inputs
         (frames / image_embeds).

       ``attn_backend`` selects the paged-attention backend the paged kinds
       route through (``reference`` gather+attend | ``pallas`` fused decode
       kernel)."""
    model = build_model(cfg, attn_backend)
    if kind == "decode":
        def step(params, cache, tokens):
            logits, cache = model.decode(params, cache, tokens, mesh)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, cache
        return step
    if kind == "decode_paged":
        def step(params, kv, state, meta, tokens):
            logits, kv, state = model.decode_paged(params, kv, state, meta,
                                                   tokens, mesh)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            ok = jnp.isfinite(logits).all(axis=-1)
            return nxt, ok, kv, state
        return step
    if kind == "verify_paged":
        def step(params, kv, state, meta, tokens):
            logits, kv, state = model.verify_paged(params, kv, state, meta,
                                                   tokens, mesh)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            ok = jnp.isfinite(logits).all(axis=(-2, -1))
            return nxt, ok, kv, state
        return step
    if kind == "prefill_paged":
        def step(params, kv, state, meta, tokens, extras):
            return model.prefill_paged(params, kv, state, meta, tokens,
                                       extras, mesh)
        return step
    if kind == "prefill_paged_cont":
        # continuation chunks of a long prompt: pure page work — enc-dec
        # skips the encoder and reads its pinned cross K/V from the slots
        def step(params, kv, state, meta, tokens, extras):
            return model.prefill_paged(params, kv, state, meta, tokens,
                                       extras, mesh, continuation=True)
        return step
    if kind == "prefill_at":
        def step(params, batch, last_idx):
            return model.prefill(params, batch, mesh, logits_idx=last_idx)
        return step
    assert kind == "prefill", kind

    def step(params, batch):
        return model.prefill(params, batch, mesh)
    return step


def abstract_serve_args(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    model = build_model(cfg)
    pdefs = model.param_defs()
    if shape.kind == "decode":
        cdefs = model.cache_defs(shape.global_batch, shape.seq_len)
        bdefs = input_defs(cfg, shape)
        return (abstract_tree(pdefs, mesh), abstract_tree(cdefs, mesh),
                abstract_tree(bdefs, mesh)["tokens"])
    bdefs = input_defs(cfg, shape)
    return (abstract_tree(pdefs, mesh), abstract_tree(bdefs, mesh))


def serve_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    model = build_model(cfg)
    pdefs = model.param_defs()
    if shape.kind == "decode":
        cdefs = model.cache_defs(shape.global_batch, shape.seq_len)
        bdefs = input_defs(cfg, shape)
        return (specs_tree(pdefs, mesh), specs_tree(cdefs, mesh),
                specs_tree(bdefs, mesh)["tokens"])
    bdefs = input_defs(cfg, shape)
    return (specs_tree(pdefs, mesh), specs_tree(bdefs, mesh))
