"""Cache-family taxonomy for the serving engine.

Every model reports *what kind of decode cache it keeps* through
``cache_spec()``; the serving stack (``repro.serving``) consumes the spec to
decide how requests are admitted, grown, preempted, and retired.  Five layer
families cover every registered arch:

``paged_kv``
    Token-addressable K/V pages (dense / GQA / MQA / MoE attention).  One
    page per ``page_size`` positions; pages are immutable once written, so
    full prompt pages can be shared through the radix prefix cache.

``paged_mla``
    MLA's absorbed latent cache (``ckv`` + roped ``krope``) in pages.  Same
    addressing and immutability as ``paged_kv`` — only the per-token payload
    differs (rank-``kv_lora`` latent instead of per-head K/V).

``windowed_kv``
    Sliding-window K/V in a *page ring*: a request holds at most
    ``window_pages(window, page_size)`` pages and the table entry for
    logical page ``a`` lives at ring slot ``a % horizon`` — once a position
    ages out of the window its page is overwritten in place (recycled), so
    allocation is O(window) regardless of generated length.  Recycling makes
    the pages mutable, which is why windowed families are not
    prefix-cacheable.

``state_slot``
    Fixed-size recurrent state (SSM conv taps + SSD state, RG-LRU conv +
    hidden state, and the hybrid family's bounded local-attention ring).
    One slot per live request, indexed by the decode row; preemption
    checkpoints the slot to host memory and re-admission restores it
    (alloc -> checkpoint-on-preempt -> restore -> free).

``cross_kv``
    Enc-dec cross-attention K/V: computed once at prefill from the encoder
    output and pinned (read-only) in a per-request state slot for the whole
    decode.  The decoder's *self*-attention KV still grows and is paged.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


def window_pages(window: int, page_size: int) -> int:
    """Ring horizon in pages for a sliding window.

    The ring must keep every position in ``(pos - window, pos]`` live while
    the page holding ``pos`` is being written, so it spans at least
    ``window + 1`` token slots rounded up to whole pages."""
    return window // page_size + (1 if window % page_size == 0 else 2)


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """One layer-group's cache family."""
    kind: str            # paged_kv | paged_mla | windowed_kv | state_slot | cross_kv
    window: int = 0      # windowed_kv: sliding window in tokens


@dataclasses.dataclass(frozen=True)
class CacheFamilySpec:
    """A model's full decode-cache shape, as the serving stack sees it."""
    kinds: Tuple[CacheSpec, ...]
    paged: bool                  # has a token-addressable paged component
    window: int = 0              # >0: paged component is a ring of this window
    state_slots: bool = False    # has per-request fixed-size slot state
    prefix_cacheable: bool = False  # prompt pages immutable -> radix cache ok
    prefix_tokens: int = 0       # non-text positions before the prompt (vlm)
    checkpointable: bool = False  # preempt = checkpoint slot state, not replay

    def describe(self) -> str:
        return "+".join(
            f"{k.kind}(w={k.window})" if k.window else k.kind
            for k in self.kinds)
