from .registry import (abstract_cache, abstract_params, build_model, init_cache,
                       init_params, input_defs, input_specs)  # noqa: F401
from .params import ParamDef, abstract_tree, init_tree, specs_tree, stack_tree  # noqa: F401
