"""Encoder-decoder transformer (SeamlessM4T backbone).

The audio frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings at ``d_model``; the backbone is 24 bidirectional
encoder layers + 24 causal decoder layers with cross-attention.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import shardings
from .attention import (attn_defs, cache_defs, cross_attention_block,
                        decode_attention_block, full_attention_block,
                        paged_cache_defs, qkv)
from .attn_backend import get_backend
from .cache_spec import CacheFamilySpec, CacheSpec
from .layers import (apply_mlp, apply_norm, apply_rope, embed_defs, embed_tokens,
                     lm_logits, mlp_defs, norm_defs, rope_freqs)
from .params import ParamDef, stack_tree
from .transformer import _remat, _scan_blocks, _scan_blocks_emit

ENC_LEN_DECODE = 4096   # encoder length assumed for standalone decode cells


class EncDecLM:
    def __init__(self, cfg: ArchConfig, attn_backend: str = "reference"):
        self.cfg = cfg
        self.attn_backend = get_backend(attn_backend)

    def cache_spec(self) -> CacheFamilySpec:
        """Paged decoder self-attention KV + a pinned per-request cross cache
        (computed once from the encoder output, read-only during decode).
        Prompts are frame-conditioned, so token prefixes are not shareable."""
        return CacheFamilySpec(
            kinds=(CacheSpec("paged_kv"), CacheSpec("cross_kv")),
            paged=True, state_slots=True)

    def supports_paged_decode(self):
        return True, self.cache_spec().describe()

    # ------------------------------------------------------------ param defs

    def _enc_block(self):
        cfg = self.cfg
        return {"ln1": norm_defs(cfg, cfg.d_model), "attn": attn_defs(cfg),
                "ln2": norm_defs(cfg, cfg.d_model),
                "mlp": mlp_defs(cfg, cfg.d_model, cfg.d_ff)}

    def _dec_block(self):
        cfg = self.cfg
        return {"ln1": norm_defs(cfg, cfg.d_model), "self_attn": attn_defs(cfg),
                "ln_x": norm_defs(cfg, cfg.d_model), "cross_attn": attn_defs(cfg),
                "ln2": norm_defs(cfg, cfg.d_model),
                "mlp": mlp_defs(cfg, cfg.d_model, cfg.d_ff)}

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": embed_defs(cfg),
            "enc_blocks": stack_tree(self._enc_block(), cfg.n_enc_layers),
            "dec_blocks": stack_tree(self._dec_block(), cfg.n_dec_layers),
            "enc_norm": norm_defs(cfg, cfg.d_model),
            "final_norm": norm_defs(cfg, cfg.d_model),
        }

    # --------------------------------------------------------------- encoder

    def encode(self, params, frames, mesh=None):
        cfg = self.cfg
        freqs = rope_freqs(cfg, cfg.head_dim_)
        x = frames.astype(jnp.bfloat16)
        if mesh is not None:
            x = shardings.constrain(x, mesh, ("batch", None, None))

        def body(x, p):
            h = apply_norm(cfg, p["ln1"], x)
            x = x + full_attention_block(cfg, p["attn"], h, freqs, causal=False, q_block=cfg.attn_q_block, unroll=cfg.unroll)
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, None

        def f(carry, p):
            return body(carry, p)
        x, _ = jax.lax.scan(_remat(f, cfg.remat), x, params["enc_blocks"], unroll=cfg.unroll)
        return apply_norm(cfg, params["enc_norm"], x)

    # ----------------------------------------------------------- decoder/loss

    def _decoder_hidden(self, params, tokens, enc_out, mesh=None):
        cfg = self.cfg
        freqs = rope_freqs(cfg, cfg.head_dim_)
        x = embed_tokens(params["embed"], tokens)
        if mesh is not None:
            x = shardings.constrain(x, mesh, ("batch", None, None))

        def body(carry, p):
            x = carry
            h = apply_norm(cfg, p["ln1"], x)
            x = x + full_attention_block(cfg, p["self_attn"], h, freqs, causal=True, q_block=cfg.attn_q_block, unroll=cfg.unroll)
            x = x + cross_attention_block(cfg, p["cross_attn"],
                                          apply_norm(cfg, p["ln_x"], x), enc_out,
                                          q_block=cfg.attn_q_block, unroll=cfg.unroll)
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, None

        x, _ = jax.lax.scan(_remat(body, cfg.remat), x, params["dec_blocks"], unroll=cfg.unroll)
        return apply_norm(cfg, params["final_norm"], x)

    def loss(self, params, batch, mesh=None, chunk: int = 0):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], mesh)
        hidden = self._decoder_hidden(params, batch["tokens"], enc_out, mesh)
        tokens = batch["tokens"]
        B, S = tokens.shape
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        lmask = jnp.ones_like(labels, bool).at[:, -1].set(False)
        vocab_mask = (jnp.arange(cfg.vocab_padded) >= cfg.vocab)
        chunk = min(chunk or cfg.loss_chunk, S)
        nc = S // chunk
        hc = jnp.moveaxis(hidden.reshape(B, nc, chunk, -1), 1, 0)
        lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
        mc = jnp.moveaxis(lmask.reshape(B, nc, chunk), 1, 0)

        def ce_chunk(carry, inp):
            h, l, m = inp
            logits = lm_logits(cfg, params["embed"], h).astype(jnp.float32)
            logits = jnp.where(vocab_mask, -1e30, logits)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
            tot, cnt = carry
            return (tot + jnp.sum(jnp.where(m, lse - gold, 0.0)),
                    cnt + jnp.sum(m)), None

        (tot, cnt), _ = jax.lax.scan(
            _remat(ce_chunk, cfg.remat),
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc, mc),
            unroll=cfg.unroll)
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss, {"nll": loss, "tokens": cnt}

    # ----------------------------------------------------------------- cache

    def cache_defs(self, batch: int, max_len: int, enc_len: int = ENC_LEN_DECODE):
        cfg = self.cfg
        per = cache_defs(cfg, batch, max_len)
        hd = cfg.head_dim_
        cross = {
            "k": ParamDef((batch, enc_len, cfg.n_kv_heads, hd),
                          ("batch", "seq", "kv_heads", "head_dim"), init="zeros"),
            "v": ParamDef((batch, enc_len, cfg.n_kv_heads, hd),
                          ("batch", "seq", "kv_heads", "head_dim"), init="zeros"),
        }
        return {"self": stack_tree(per, cfg.n_dec_layers),
                "cross": stack_tree(cross, cfg.n_dec_layers),
                "pos": ParamDef((batch,), ("batch",), jnp.int32, "zeros")}

    # ---------------------------------------------------------------- decode

    def decode(self, params, cache, tokens, mesh=None):
        cfg = self.cfg
        pos = cache["pos"]
        freqs = rope_freqs(cfg, cfg.head_dim_)
        x = embed_tokens(params["embed"], tokens)

        def body(x, pc):
            p, (cself, ccross) = pc
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = decode_attention_block(cfg, p["self_attn"], h, cself, pos, freqs)
            x = x + a
            # cross attention against the cached encoder K/V
            hx = apply_norm(cfg, p["ln_x"], x)
            x = x + self._cross_decode(p, hx, ccross["k"], ccross["v"])
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, (c2, ccross)

        x, (nself, ncross) = _scan_blocks(
            body, x, params["dec_blocks"], (cache["self"], cache["cross"]),
            unroll=cfg.unroll)
        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x)
        return logits, {"self": nself, "cross": ncross, "pos": pos + 1}

    # --------------------------------------------------------------- prefill

    def prefill(self, params, batch, mesh=None, logits_idx=None):
        """Encode frames + run the decoder prompt, emitting self/cross caches."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], mesh)
        freqs = rope_freqs(cfg, cfg.head_dim_)
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.arange(S)[None, :]
        x = embed_tokens(params["embed"], tokens)

        def body(x, p):
            h = apply_norm(cfg, p["ln1"], x)
            q, k, v = qkv(cfg, p["self_attn"], h)
            k = apply_rope(k, positions, freqs)
            x = x + full_attention_block(cfg, p["self_attn"], h, freqs, causal=True, q_block=cfg.attn_q_block, unroll=cfg.unroll)
            hx = apply_norm(cfg, p["ln_x"], x)
            x = x + cross_attention_block(cfg, p["cross_attn"], hx, enc_out, q_block=cfg.attn_q_block, unroll=cfg.unroll)
            ck = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross_attn"]["wk"])
            cv = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross_attn"]["wv"])
            if "bk" in p["cross_attn"]:
                ck, cv = ck + p["cross_attn"]["bk"], cv + p["cross_attn"]["bv"]
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, ({"k": k, "v": v}, {"k": ck, "v": cv})

        x, (cself, ccross) = _scan_blocks_emit(body, x, params["dec_blocks"], unroll=cfg.unroll)
        x = apply_norm(cfg, params["final_norm"], x)
        last = x[:, -1] if logits_idx is None else x[jnp.arange(B), logits_idx]
        logits = lm_logits(cfg, params["embed"], last)
        cache = {"self": cself, "cross": ccross,
                 "pos": jnp.full((B,), S, jnp.int32)}
        return logits, cache

    # ------------------------------------------------------- paged serving

    def paged_cache_defs(self, num_pages: int, page_size: int,
                         kv_dtype: str = "bf16"):
        """Decoder *self*-attention KV pages, stacked over decoder layers."""
        per = paged_cache_defs(self.cfg, num_pages, page_size,
                               kv_dtype=kv_dtype)
        return stack_tree(per, self.cfg.n_dec_layers)

    def state_slot_defs(self, n_slots: int, max_len: int, enc_len: int):
        """Per-request pinned cross-attention cache: one K/V block of
        ``enc_len`` encoder positions per decoder layer, slot axis 1."""
        cfg = self.cfg
        hd = cfg.head_dim_
        cross = {
            "k": ParamDef((n_slots, enc_len, cfg.n_kv_heads, hd),
                          ("batch", "seq", "kv_heads", "head_dim"),
                          init="zeros"),
            "v": ParamDef((n_slots, enc_len, cfg.n_kv_heads, hd),
                          ("batch", "seq", "kv_heads", "head_dim"),
                          init="zeros"),
        }
        return {"cross": stack_tree(cross, cfg.n_dec_layers)}

    def _cross_decode(self, p, hx, ck, cv):
        """One-token cross-attention against a pinned cross cache row."""
        cfg = self.cfg
        import math as _m
        q = jnp.einsum("bd,dhe->bhe", hx, p["cross_attn"]["wq"])
        if "bq" in p["cross_attn"]:
            q = q + p["cross_attn"]["bq"]
        K = cfg.n_kv_heads
        G = cfg.n_heads // K
        qg = q.reshape(q.shape[0], K, G, cfg.head_dim_)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, ck,
                       preferred_element_type=jnp.float32)
        s = s / _m.sqrt(cfg.head_dim_)
        att = jax.nn.softmax(s, axis=-1).astype(cv.dtype)
        o = jnp.einsum("bkgs,bskd->bkgd", att, cv)
        o = o.reshape(o.shape[0], cfg.n_heads, cfg.head_dim_)
        return jnp.einsum("bhe,hed->bd", o, p["cross_attn"]["wo"])

    def decode_paged(self, params, kv, state, meta, tokens, mesh=None):
        """One-token continuous-batching decode: paged self-attention (via
        the attention backend, ``meta`` per ``attn_backend.decode_meta``) +
        the slot-pinned cross cache.  Returns (logits, new_kv, state) — the
        cross cache is read-only here (written once at prefill)."""
        cfg = self.cfg
        freqs = rope_freqs(cfg, cfg.head_dim_)
        x = embed_tokens(params["embed"], tokens)

        def body(x, pc):
            p, (cself, ccross) = pc
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = self.attn_backend.paged_decode(cfg, p["self_attn"], h,
                                                   cself, meta, freqs)
            x = x + a
            hx = apply_norm(cfg, p["ln_x"], x)
            x = x + self._cross_decode(p, hx, ccross["k"], ccross["v"])
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, (c2, ccross)

        x, (nself, ncross) = _scan_blocks(
            body, x, params["dec_blocks"], (kv, state["cross"]),
            unroll=cfg.unroll)
        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x)
        return logits, nself, {"cross": ncross}

    def prefill_paged(self, params, kv, state, meta, tokens, extras=None,
                      mesh=None, continuation: bool = False):
        """Chunk prefill: encode each request's frames, write the decoder
        prompt chunk's self-attention KV through the page tables (``meta``
        per ``attn_backend.prefill_meta``; ``start > 0`` resumes a chunked
        prompt against its already-resident pages), and pin the cross K/V
        into the state slots at rows ``meta["slots"]`` (out-of-range rows —
        batch padding — scatter nothing).

        ``continuation=True`` (chunks after the first of a long prompt)
        skips the encoder entirely: the cross K/V the first chunk pinned are
        *read back from the state slots* for this chunk's cross-attention —
        the pinned values are the same bf16 the fresh projection would
        produce, so the chunk is bitwise-identical at a fraction of the
        step cost (no per-chunk encoder forward, no re-pin)."""
        cfg = self.cfg
        if continuation:
            return self._prefill_paged_continue(params, kv, state, meta,
                                                tokens, mesh)
        enc_out = self.encode(params, extras["frames"], mesh)
        freqs = rope_freqs(cfg, cfg.head_dim_)
        B = tokens.shape[0]
        slots, n_tail = meta["slots"], meta["n_tail"]
        x = embed_tokens(params["embed"], tokens)

        def body(x, pc):
            p, cself = pc
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = self.attn_backend.paged_prefill(
                cfg, p["self_attn"], h, cself, meta, freqs,
                q_block=cfg.attn_q_block, unroll=cfg.unroll)
            x = x + a
            hx = apply_norm(cfg, p["ln_x"], x)
            x = x + cross_attention_block(cfg, p["cross_attn"], hx, enc_out,
                                          q_block=cfg.attn_q_block,
                                          unroll=cfg.unroll)
            ck = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross_attn"]["wk"])
            cv = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross_attn"]["wv"])
            if "bk" in p["cross_attn"]:
                ck, cv = ck + p["cross_attn"]["bk"], cv + p["cross_attn"]["bv"]
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, (c2, {"k": ck, "v": cv})

        def f(carry, pc):
            x = carry
            x, out = body(x, pc)
            return x, out
        x, (nself, ncross) = jax.lax.scan(f, x, (params["dec_blocks"], kv),
                                          unroll=cfg.unroll)
        new_state = jax.tree.map(
            lambda a, nw: a.at[:, slots].set(nw.astype(a.dtype), mode="drop"),
            state, {"cross": ncross})
        x = apply_norm(cfg, params["final_norm"], x)
        last = x[jnp.arange(B), n_tail - 1]
        logits = lm_logits(cfg, params["embed"], last)
        return logits, nself, new_state

    def _prefill_paged_continue(self, params, kv, state, meta, tokens,
                                mesh=None):
        """Continuation-chunk prefill: no encoder, no cross re-pin — each
        layer cross-attends the K/V rows the first chunk pinned into the
        state slots (padding rows clamp to row 0 and attend harmless
        garbage; their logits are never read)."""
        cfg = self.cfg
        freqs = rope_freqs(cfg, cfg.head_dim_)
        B = tokens.shape[0]
        slots, n_tail = meta["slots"], meta["n_tail"]
        rows = jnp.clip(slots, 0, state["cross"]["k"].shape[1] - 1)
        ck = state["cross"]["k"][:, rows]        # [L, B, enc_len, K, D]
        cv = state["cross"]["v"][:, rows]
        x = embed_tokens(params["embed"], tokens)

        def body(x, pc):
            p, cself, ckl, cvl = pc
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = self.attn_backend.paged_prefill(
                cfg, p["self_attn"], h, cself, meta, freqs,
                q_block=cfg.attn_q_block, unroll=cfg.unroll)
            x = x + a
            hx = apply_norm(cfg, p["ln_x"], x)
            q = jnp.einsum("bsd,dhe->bshe", hx, p["cross_attn"]["wq"])
            if "bq" in p["cross_attn"]:
                q = q + p["cross_attn"]["bq"]
            from .attention import chunked_attention
            o = chunked_attention(q, ckl, cvl, causal=False,
                                  q_block=cfg.attn_q_block, unroll=cfg.unroll)
            x = x + jnp.einsum("bshe,hed->bsd", o, p["cross_attn"]["wo"])
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, c2

        def f(carry, pc):
            x = carry
            x, c2 = body(x, pc)
            return x, c2
        x, nself = jax.lax.scan(f, x, (params["dec_blocks"], kv, ck, cv),
                                unroll=cfg.unroll)
        x = apply_norm(cfg, params["final_norm"], x)
        last = x[jnp.arange(B), n_tail - 1]
        logits = lm_logits(cfg, params["embed"], last)
        return logits, nself, state
