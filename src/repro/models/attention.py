"""Attention: GQA / MQA / MHA with RoPE, causal + sliding-window masks, KV cache.

The training/prefill path is a *chunked* (query-blocked) attention: a ``lax.scan``
over query blocks keeps the live score tensor at ``[B, H, q_block, S]`` instead of
``[B, H, S, S]`` — this is what makes the 32k-prefill cells compile with sane
``memory_analysis`` numbers, and it is the XLA analogue of the Pallas flash kernel
(``repro.kernels.flash_attention``) that is the TPU target.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import apply_rope, rope_freqs
from .params import ParamDef

NEG_INF = -1e30


# ------------------------------------------------------------------ param defs

def attn_defs(cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    hd = cfg.head_dim_
    h, k = cfg.n_heads_padded, cfg.n_kv_heads
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((k, hd), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef((k, hd), ("kv_heads", "head_dim"), init="zeros")
    return defs


def qkv(cfg: ArchConfig, p, x):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


# --------------------------------------------------------- chunked core attention

def chunked_attention(
    q: jax.Array,            # [B, Sq, H, D]
    k: jax.Array,            # [B, Sk, K, D]
    v: jax.Array,            # [B, Sk, K, D]
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    softcap: float = 0.0,
    q_offset=0,              # absolute position of q[0] relative to k[0];
                             # int (static) or [B] int32 (per-row, traced)
    unroll: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    q_block = min(q_block, Sq)
    # pad Sq to a multiple of q_block
    pad = (-Sq) % q_block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = q.shape[1] // q_block
    qb = q.reshape(B, nb, q_block, K, G, D)
    qb = jnp.moveaxis(qb, 1, 0)                      # [nb, B, q_block, K, G, D]
    kpos = jnp.arange(k.shape[1])
    qoff = jnp.asarray(q_offset, jnp.int32).reshape(-1)[:, None]    # [B or 1, 1]

    def block(carry, inp):
        qi, bidx = inp
        qpos = qoff + bidx * q_block + jnp.arange(q_block)[None, :]  # [B or 1, q]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qi, k, preferred_element_type=jnp.float32)
        s = s * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((qoff.shape[0], q_block, k.shape[1]), bool)
        if causal:
            mask &= kpos[None, None, :] <= qpos[:, :, None]
        if window:
            mask &= kpos[None, None, :] > qpos[:, :, None] - window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", a, v)
        return carry, o

    # flash-style recompute: without this the q-block scan stacks every block's
    # fp32 softmax residuals for backward ([nb, B, H, q, S] — tens of GB)
    _, out = jax.lax.scan(jax.checkpoint(block), None, (qb, jnp.arange(nb)),
                          unroll=unroll)
    out = jnp.moveaxis(out, 0, 1).reshape(B, nb * q_block, H, v.shape[-1])
    if pad:
        out = out[:, :Sq]
    return out


def ring_chunk_attention(
    q: jax.Array,            # [B, T, H, D] roped chunk queries
    k: jax.Array,            # [B, T, K, D] fresh roped chunk keys
    v: jax.Array,            # [B, T, K, D]
    k_ring: jax.Array,       # [B, n, K, D] gathered page ring BEFORE the
    v_ring: jax.Array,       #   chunk's writes (positions < start)
    start: jax.Array,        # [B] absolute position of q[:, 0]
    n_live: jax.Array,       # [B] real (non-padding) chunk tokens
    *,
    window: int,
    softcap: float = 0.0,
    q_block: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Sliding-window attend for a *chunk* of prefill at offset ``start``.

    A chunk's queries need keys from earlier chunks, which live only in the
    page ring.  The ring is gathered before this chunk's scatter (writing
    first would recycle slots still holding in-window keys of the earliest
    queries), so ring slot ``s`` holds the latest position ``< start``
    congruent to ``s`` mod the ring length; each slot's absolute position is
    recovered from that layout and masked to the window, and the chunk's own
    keys are attended fresh with the causal+window rule.  At ``start == 0``
    the ring part is fully masked and this reduces (token-exactly — masked
    entries are exact softmax zeros) to the fresh-only attend the unchunked
    windowed prefill always used."""
    B, T, H, D = q.shape
    K = k.shape[2]
    n = k_ring.shape[1]                               # ring length in tokens
    scale = 1.0 / math.sqrt(D)
    q_block = min(q_block, T)
    pad = (-T) % q_block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = q.shape[1] // q_block
    qb = q.reshape(B, nb, q_block, K, H // K, D)
    qb = jnp.moveaxis(qb, 1, 0)                      # [nb, B, q_block, K, G, D]
    kc = jnp.concatenate([k_ring, k], axis=1)        # [B, n + T, K, D]
    vc = jnp.concatenate([v_ring, v], axis=1)
    start = jnp.asarray(start, jnp.int32).reshape(-1)
    # ring-slot absolute positions, recovered relative to the last position
    # written before this chunk (start - 1); start == 0 -> all negative
    last = (start - 1)[:, None]                                   # [B, 1]
    idx = jnp.arange(n)[None, :]
    k_abs = last - ((last % n - idx) % n)                         # [B, n]
    fresh_abs = start[:, None] + jnp.arange(T)[None, :]           # [B, T]
    fresh_live = jnp.arange(T)[None, :] < n_live[:, None]         # [B, T]

    def block(carry, inp):
        qi, bidx = inp
        qpos = start[:, None] + bidx * q_block \
            + jnp.arange(q_block)[None, :]                        # [B, q]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kc,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        vr = (k_abs[:, None, :] >= 0) \
            & (k_abs[:, None, :] > qpos[:, :, None] - window)     # [B, q, n]
        vf = (fresh_abs[:, None, :] <= qpos[:, :, None]) \
            & (fresh_abs[:, None, :] > qpos[:, :, None] - window) \
            & fresh_live[:, None, :]                              # [B, q, T]
        mask = jnp.concatenate([vr, vf], axis=2)                  # [B, q, n+T]
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1).astype(vc.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", a, vc)
        return carry, o

    _, out = jax.lax.scan(jax.checkpoint(block), None, (qb, jnp.arange(nb)),
                          unroll=unroll)
    out = jnp.moveaxis(out, 0, 1).reshape(B, nb * q_block, H, v.shape[-1])
    if pad:
        out = out[:, :T]
    return out


def full_attention_block(cfg: ArchConfig, p, x, freqs, *, causal=True, window=0,
                         positions=None, q_block=512, unroll=False):
    """Self-attention over a full sequence (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = qkv(cfg, p, x)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if freqs is not None:
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
    o = chunked_attention(q, k, v, causal=causal, window=window, q_block=q_block,
                          softcap=cfg.attn_logit_softcap, unroll=unroll)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


def cross_attention_block(cfg: ArchConfig, p, x, enc_out, q_block=512, unroll=False):
    """Decoder cross-attention (no rope, no mask)."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    k = jnp.einsum("bsd,dhe->bshe", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", enc_out, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    o = chunked_attention(q, k, v, causal=False, q_block=q_block, unroll=unroll)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


# ------------------------------------------------------------------- KV cache

def cache_defs(cfg: ArchConfig, batch: int, max_len: int, window: int = 0):
    """Abstract defs for one layer's KV cache. Ring buffer when window > 0."""
    hd = cfg.head_dim_
    L = min(window, max_len) if window else max_len
    return {
        "k": ParamDef((batch, L, cfg.n_kv_heads, hd), ("batch", "seq", "kv_heads", "head_dim"), init="zeros"),
        "v": ParamDef((batch, L, cfg.n_kv_heads, hd), ("batch", "seq", "kv_heads", "head_dim"), init="zeros"),
    }


def paged_cache_defs(cfg: ArchConfig, num_pages: int, page_size: int,
                     kv_dtype: str = "bf16"):
    """One layer's share of the paged KV pool: [P, page_size, K, D] per tensor.

    Unlike ``cache_defs`` there is no batch dim — requests own disjoint page
    sets and a per-request page table maps logical pages to physical ones.

    ``kv_dtype == "int8"`` stores absmax-quantized int8 payloads plus
    per-token-slot-per-kv-head bf16 scale leaves (``k_scale``/``v_scale``,
    [P, page_size, K]) that share the payload's page axis — a physical page
    id addresses payload and scales together, so refcounting, radix sharing
    and COW forks need no separate scale accounting."""
    hd = cfg.head_dim_
    payload_dt = jnp.int8 if kv_dtype == "int8" else jnp.bfloat16
    defs = {
        "k": ParamDef((num_pages, page_size, cfg.n_kv_heads, hd),
                      (None, "seq", "kv_heads", "head_dim"),
                      dtype=payload_dt, init="zeros"),
        "v": ParamDef((num_pages, page_size, cfg.n_kv_heads, hd),
                      (None, "seq", "kv_heads", "head_dim"),
                      dtype=payload_dt, init="zeros"),
    }
    if kv_dtype == "int8":
        defs["k_scale"] = ParamDef((num_pages, page_size, cfg.n_kv_heads),
                                   (None, "seq", "kv_heads"),
                                   dtype=jnp.bfloat16, init="zeros")
        defs["v_scale"] = ParamDef((num_pages, page_size, cfg.n_kv_heads),
                                   (None, "seq", "kv_heads"),
                                   dtype=jnp.bfloat16, init="zeros")
    return defs


# ------------------------------------------------- int8 KV quantization
#
# The one quantize/dequant rounding contract every path shares (see
# kernels/README.md): absmax is taken in fp32 over the feature axis per
# (token slot, kv head); the stored scale is ``bf16(absmax / 127)`` (one
# round-to-nearest-even); the payload quantizes against the *stored* scale —
# ``int8(clip(round(x / f32(s)), -127, 127))`` — so the round-trip error is
# bounded by the stored scale regardless of its precision; a zero-absmax
# slice stores (q=0, s=0).  Dequant is ``f32(q) * f32(s)`` everywhere: the
# XLA reference gather, the Pallas kernel bodies, and the tests.

def quantize_int8(x: jax.Array):
    """Absmax-quantize ``x`` over its last axis.  Returns
    (q int8 [..., D], s bfloat16 [...])."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    s = (amax / 127.0).astype(jnp.bfloat16)
    sf = s.astype(jnp.float32)
    # zero-scale slices (all-zero input, or absmax underflowing bf16) store
    # q = 0; the safe denominator keeps the division finite either way
    safe = jnp.where(sf > 0.0, sf, 1.0)[..., None]
    q = jnp.clip(jnp.round(xf / safe), -127.0, 127.0)
    q = jnp.where(sf[..., None] > 0.0, q, 0.0).astype(jnp.int8)
    return q, s


def dequant_int8(q: jax.Array, s: jax.Array) -> jax.Array:
    """Invert ``quantize_int8``: fp32 payload * fp32 scale, broadcast over
    the feature axis.  q: [..., D] int8; s: [...] bf16.  Returns fp32."""
    return q.astype(jnp.float32) * s.astype(jnp.float32)[..., None]


# --------------------------------------------- shared paged-cache helpers
#
# One copy of the page-gather / write-targeting / masking arithmetic that the
# vanilla, sliding-window, and MLA paged blocks used to hand-roll separately.
# The reference attention backend (models.attn_backend) is built from these.

def gather_pages(pages: jax.Array, tables: jax.Array) -> jax.Array:
    """Materialize the logical per-request view of a paged pool.

    pages: [P, ps, ...]; tables: [B, n] int32 physical page ids.  Returns
    [B, n * ps, ...] — request b's pages concatenated in table order."""
    B, n = tables.shape
    return pages[tables].reshape((B, n * pages.shape[1]) + pages.shape[2:])


def decode_valid_mask(pos: jax.Array, n: int, *, window: int = 0) -> jax.Array:
    """[B, n] validity of a gathered view at one-token decode.

    ``window == 0``: plain absolute-causal ``idx <= pos``.  ``window > 0``:
    ``n`` is the ring length — each slot's absolute position is recovered
    from the ring layout and masked to the window, the same rule as the
    contiguous ring buffer of ``decode_attention_block``."""
    idx = jnp.arange(n)
    if not window:
        return idx[None, :] <= pos[:, None]
    k_abs = pos[:, None] - (((pos % n)[:, None] - idx[None, :]) % n)
    return (k_abs >= 0) & (k_abs <= pos[:, None]) \
        & (k_abs > pos[:, None] - window)


def verify_valid_mask(pos: jax.Array, n_q: jax.Array, Q: int, n: int, *,
                      window: int = 0) -> jax.Array:
    """[B, Q, n] validity of a gathered view at a small-q verify step.

    Query j of row b sits at absolute position ``pos[b] + j``; its row of the
    mask is ``decode_valid_mask`` evaluated at that position (absolute-causal,
    or ring-recovered for ``window > 0`` with ring length ``n``).  Dead query
    rows (``j >= n_q[b]``) are all-False."""
    qpos = pos[:, None] + jnp.arange(Q)[None, :]                  # [B, Q]
    live = jnp.arange(Q)[None, :] < n_q[:, None]
    idx = jnp.arange(n)
    if not window:
        valid = idx[None, None, :] <= qpos[:, :, None]
    else:
        k_abs = qpos[:, :, None] \
            - (((qpos % n)[:, :, None] - idx[None, None, :]) % n)
        valid = (k_abs >= 0) & (k_abs <= qpos[:, :, None]) \
            & (k_abs > qpos[:, :, None] - window)
    return valid & live[:, :, None]


def decode_qkv(cfg: ArchConfig, p, x, pos, freqs):
    """Project + rope one decode token.  x: [B, d]; pos: [B].  Returns
    (q [B, H, D], k [B, K, D], v [B, K, D])."""
    x1 = x[:, None, :]
    q = jnp.einsum("bsd,dhe->bshe", x1, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x1, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x1, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if freqs is not None:
        q = apply_rope(q, pos[:, None], freqs)
        k = apply_rope(k, pos[:, None], freqs)
    return q[:, 0], k[:, 0], v[:, 0]


def masked_token_attend(q, kg, vg, valid, *, scale: float,
                        softcap: float = 0.0):
    """The one-token GQA attend every reference decode path shares.

    q: [B, H, D]; kg, vg: [B, S, K, D] (contiguous logical view); valid:
    [B, S] bool.  fp32 scores, masked softmax, and an fp32
    probability-weighted sum — the one rounding point is the cast back to
    cache dtype at the block output, which is exactly where the fused Pallas
    decode kernel rounds its fp32 accumulator, so the two backends agree to
    an output ulp.  Returns [B, H, D]."""
    B, H, D = q.shape
    K = kg.shape[2]
    qg = q.reshape(B, K, H // K, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kg,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", a, vg,
                   preferred_element_type=jnp.float32)
    return o.astype(vg.dtype).reshape(B, H, D)


def masked_multi_token_attend(q, kg, vg, valid, *, scale: float,
                              softcap: float = 0.0):
    """``masked_token_attend`` with a small query axis (speculative verify).

    q: [B, Q, H, D]; kg, vg: [B, S, K, D]; valid: [B, Q, S] per-query masks.
    Each query row runs the exact per-row ops of the one-token attend (fp32
    scores, masked softmax, fp32 PV sum, single output cast), so ``Q == 1``
    reproduces it bit-for-bit.  Rows whose mask is all-False (dead / padded
    queries) return exact zeros — matching the fused kernel's zero-init
    accumulator — so backends agree on every row, live or dead.  Returns
    [B, Q, H, D]."""
    B, Q, H, D = q.shape
    K = kg.shape[2]
    qg = q.reshape(B, Q, K, H // K, D)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kg,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    any_valid = jnp.any(valid, axis=-1)                           # [B, Q]
    a = jnp.where(any_valid[:, :, None, None, None], a, 0.0)
    o = jnp.einsum("bqkgs,bskd->bqkgd", a, vg,
                   preferred_element_type=jnp.float32)
    return o.astype(vg.dtype).reshape(B, Q, H, D)


# --------------------------------------------------- paged attention blocks
#
# Family framing shared by every backend: QKV + RoPE, page-table scatter,
# output projection.  The attend itself is delegated to ``backend`` (see
# models.attn_backend) — reference gather+attend or the fused Pallas kernel.

def paged_prefill_attention_block(cfg: ArchConfig, p, x, cache, meta, freqs,
                                  backend, *, q_block=512, unroll=False):
    """Multi-token (chunk) prefill step against the paged KV pool.

    x: [B, T, d] chunk activations; cache: {"k","v": [P, ps, K, D]} one
    layer's pages; meta: the flat per-step prefill metadata from
    ``attn_backend.prefill_meta`` — page-table rows, per-row chunk offsets
    (``start``: absolute position of x[:, 0]), live counts, and the
    precomputed physical (page, offset) write target of every chunk position
    (padding and ring-aged-out positions routed to the null page), derived
    once by the engine instead of per layer.

    Vanilla layers attend to the gathered (post-write) pages with absolute
    causal masking, so a prefix written by an earlier request (radix cache
    hit) or an earlier chunk of this request is read exactly as if this call
    had prefilled it itself.  Sliding-window layers attend the chunk's fresh
    K/V plus the page *ring* as gathered before the chunk's scatter
    (``ring_chunk_attention``); the attend core is delegated to ``backend``
    (reference gather+attend or the fused ragged-prefill kernel).  Returns
    (out [B, T, d], new_cache)."""
    B, T, _ = x.shape
    quantized = "k_scale" in cache
    tables, start, n_live = meta["tables"], meta["start"], meta["n_live"]
    q, k, v = qkv(cfg, p, x)
    positions = start[:, None] + jnp.arange(T)[None, :]              # [B, T]
    if freqs is not None:
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
    wp, wo = meta["write_page"], meta["write_off"]

    def scatter(kx, vx):
        ck = cache["k"].at[wp, wo].set(kx.astype(cache["k"].dtype))
        cv = cache["v"].at[wp, wo].set(vx.astype(cache["v"].dtype))
        return ck, cv

    if quantized:
        kq, ks = quantize_int8(k)
        vq, vs = quantize_int8(v)
        ck, cv = scatter(kq, vq)
        cks = cache["k_scale"].at[wp, wo].set(ks)
        cvs = cache["v_scale"].at[wp, wo].set(vs)
    window = cfg.sliding_window
    if window:
        # ring modulus contract: the ring is the full table width the engine
        # passes (>= window_pages; may carry slack pages for speculation)
        ring_tables = tables
        # the ring must be read *before* the chunk's writes recycle slots
        # still holding in-window keys of this chunk's earliest queries;
        # quantized mode passes the pre-write scales alongside (fresh chunk
        # K/V ride in unquantized — only resident pages are int8)
        scales = ({"k_scale": cache["k_scale"],
                   "v_scale": cache["v_scale"]} if quantized else {})
        o = backend.prefill_attend(
            q, k, v, cache["k"], cache["v"], ring_tables, start, n_live,
            window=window, softcap=cfg.attn_logit_softcap, q_block=q_block,
            unroll=unroll, **scales)
        if not quantized:
            ck, cv = scatter(k, v)
    else:
        if not quantized:
            ck, cv = scatter(k, v)
        scales = ({"k_scale": cks, "v_scale": cvs} if quantized else {})
        o = backend.prefill_attend(
            q, k, v, ck, cv, tables, start, n_live, window=0,
            softcap=cfg.attn_logit_softcap, q_block=q_block, unroll=unroll,
            **scales)
    new_cache = {"k": ck, "v": cv}
    if quantized:
        new_cache.update(k_scale=cks, v_scale=cvs)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), new_cache


def paged_decode_attention_block(cfg: ArchConfig, p, x, cache, meta, freqs,
                                 backend):
    """One-token decode step against the paged KV pool.

    x: [B, d] slot activations; cache: {"k","v": [P, ps, K, D]} (one layer's
    pages, shared by all slots); meta: the flat per-step metadata from
    ``attn_backend.decode_meta`` — page-table rows, absolute positions, and
    the precomputed physical (page, offset) write target of the new token
    (ring-aware for sliding-window layers, recycling the page that just aged
    out of the window).  The attend reads the pages through ``backend`` with
    positions > pos masked (window layers: masked by absolute position
    recovered from the ring layout), so stale data in partially-filled or
    recycled pages is softmax-zero.  Returns (out [B, d], new_cache)."""
    quantized = "k_scale" in cache
    pos = meta["pos"]
    q, k, v = decode_qkv(cfg, p, x, pos, freqs)
    wp, wo = meta["write_page"], meta["write_off"]
    scales = {}
    if quantized:
        k, ks = quantize_int8(k)
        v, vs = quantize_int8(v)
        cks = cache["k_scale"].at[wp, wo].set(ks)
        cvs = cache["v_scale"].at[wp, wo].set(vs)
        scales = {"k_scale": cks, "v_scale": cvs}
    ck = cache["k"].at[wp, wo].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[wp, wo].set(v.astype(cache["v"].dtype))
    tables = meta["tables"]
    window = cfg.sliding_window
    o = backend.decode_attend(q, ck, cv, tables, pos,
                              scale=1.0 / math.sqrt(cfg.head_dim_),
                              softcap=cfg.attn_logit_softcap, window=window,
                              **scales)
    out = jnp.einsum("bhe,hed->bd", o, p["wo"])
    new_cache = {"k": ck, "v": cv}
    new_cache.update(scales)
    return out, new_cache


def paged_verify_attention_block(cfg: ArchConfig, p, x, cache, meta, freqs,
                                 backend):
    """Small-q speculative verify step against the paged KV pool.

    x: [B, Q, d] — per slot the last emitted token plus its draft, padded to
    the fixed width Q; meta: the flat metadata from
    ``attn_backend.verify_meta``.  Write-all-then-attend: every query token's
    K/V scatters into its page first (dead rows to the null page), then each
    query attends the post-write pool under the per-query causal mask
    ``token_pos <= pos + j`` (ring rule for windowed families) and
    ``j < n_q`` — so a rejected draft's K/V is invisible to every query that
    survives the accept decision and gets overwritten by the next step's
    writes at the same positions.  Per token the projections, rope, scatter
    and attend are the exact per-row ops of the decode block, which is what
    keeps accepted tokens bit-identical to the non-speculative stream.
    Returns (out [B, Q, d], new_cache)."""
    quantized = "k_scale" in cache
    pos, Q = meta["pos"], x.shape[1]
    q, k, v = qkv(cfg, p, x)
    positions = pos[:, None] + jnp.arange(Q)[None, :]             # [B, Q]
    if freqs is not None:
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
    wp, wo = meta["write_page"], meta["write_off"]
    scales = {}
    if quantized:
        k, ks = quantize_int8(k)
        v, vs = quantize_int8(v)
        cks = cache["k_scale"].at[wp, wo].set(ks)
        cvs = cache["v_scale"].at[wp, wo].set(vs)
        scales = {"k_scale": cks, "v_scale": cvs}
    ck = cache["k"].at[wp, wo].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[wp, wo].set(v.astype(cache["v"].dtype))
    o = backend.verify_attend(q, ck, cv, meta["tables"], pos, meta["n_q"],
                              scale=1.0 / math.sqrt(cfg.head_dim_),
                              softcap=cfg.attn_logit_softcap,
                              window=cfg.sliding_window, **scales)
    out = jnp.einsum("bqhe,hed->bqd", o, p["wo"])
    new_cache = {"k": ck, "v": cv}
    new_cache.update(scales)
    return out, new_cache


def decode_attention_block(cfg: ArchConfig, p, x, cache, pos, freqs, *, window=0):
    """One-token decode step.  x: [B, d]; pos: [B] absolute positions; cache ring-
    buffered when window > 0.  Returns (out [B, d], new_cache)."""
    B = x.shape[0]
    q, k, v = decode_qkv(cfg, p, x, pos, freqs)
    L = cache["k"].shape[1]
    slot = (pos % L) if window else pos
    b = jnp.arange(B)
    ck = cache["k"].at[b, slot].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[b, slot].set(v.astype(cache["v"].dtype))
    # the contiguous ring masks to its own length L (entries older than L are
    # overwritten), matching the paged ring rule with ring == window == L
    valid = decode_valid_mask(pos, L, window=L if window else 0)
    o = masked_token_attend(q, ck, cv, valid,
                            scale=1.0 / math.sqrt(cfg.head_dim_),
                            softcap=cfg.attn_logit_softcap)
    out = jnp.einsum("bhe,hed->bd", o, p["wo"])
    return out, {"k": ck, "v": cv}
