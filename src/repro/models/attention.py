"""Attention: GQA / MQA / MHA with RoPE, causal + sliding-window masks, KV cache.

The training/prefill path is a *chunked* (query-blocked) attention: a ``lax.scan``
over query blocks keeps the live score tensor at ``[B, H, q_block, S]`` instead of
``[B, H, S, S]`` — this is what makes the 32k-prefill cells compile with sane
``memory_analysis`` numbers, and it is the XLA analogue of the Pallas flash kernel
(``repro.kernels.flash_attention``) that is the TPU target.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import apply_rope, rope_freqs
from .params import ParamDef

NEG_INF = -1e30


# ------------------------------------------------------------------ param defs

def attn_defs(cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    hd = cfg.head_dim_
    h, k = cfg.n_heads_padded, cfg.n_kv_heads
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((k, hd), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef((k, hd), ("kv_heads", "head_dim"), init="zeros")
    return defs


def qkv(cfg: ArchConfig, p, x):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


# --------------------------------------------------------- chunked core attention

def chunked_attention(
    q: jax.Array,            # [B, Sq, H, D]
    k: jax.Array,            # [B, Sk, K, D]
    v: jax.Array,            # [B, Sk, K, D]
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    softcap: float = 0.0,
    q_offset=0,              # absolute position of q[0] relative to k[0];
                             # int (static) or [B] int32 (per-row, traced)
    unroll: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    q_block = min(q_block, Sq)
    # pad Sq to a multiple of q_block
    pad = (-Sq) % q_block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = q.shape[1] // q_block
    qb = q.reshape(B, nb, q_block, K, G, D)
    qb = jnp.moveaxis(qb, 1, 0)                      # [nb, B, q_block, K, G, D]
    kpos = jnp.arange(k.shape[1])
    qoff = jnp.asarray(q_offset, jnp.int32).reshape(-1)[:, None]    # [B or 1, 1]

    def block(carry, inp):
        qi, bidx = inp
        qpos = qoff + bidx * q_block + jnp.arange(q_block)[None, :]  # [B or 1, q]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qi, k, preferred_element_type=jnp.float32)
        s = s * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((qoff.shape[0], q_block, k.shape[1]), bool)
        if causal:
            mask &= kpos[None, None, :] <= qpos[:, :, None]
        if window:
            mask &= kpos[None, None, :] > qpos[:, :, None] - window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", a, v)
        return carry, o

    # flash-style recompute: without this the q-block scan stacks every block's
    # fp32 softmax residuals for backward ([nb, B, H, q, S] — tens of GB)
    _, out = jax.lax.scan(jax.checkpoint(block), None, (qb, jnp.arange(nb)),
                          unroll=unroll)
    out = jnp.moveaxis(out, 0, 1).reshape(B, nb * q_block, H, v.shape[-1])
    if pad:
        out = out[:, :Sq]
    return out


def full_attention_block(cfg: ArchConfig, p, x, freqs, *, causal=True, window=0,
                         positions=None, q_block=512, unroll=False):
    """Self-attention over a full sequence (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = qkv(cfg, p, x)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if freqs is not None:
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
    o = chunked_attention(q, k, v, causal=causal, window=window, q_block=q_block,
                          softcap=cfg.attn_logit_softcap, unroll=unroll)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


def cross_attention_block(cfg: ArchConfig, p, x, enc_out, q_block=512, unroll=False):
    """Decoder cross-attention (no rope, no mask)."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    k = jnp.einsum("bsd,dhe->bshe", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", enc_out, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    o = chunked_attention(q, k, v, causal=False, q_block=q_block, unroll=unroll)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


# ------------------------------------------------------------------- KV cache

def cache_defs(cfg: ArchConfig, batch: int, max_len: int, window: int = 0):
    """Abstract defs for one layer's KV cache. Ring buffer when window > 0."""
    hd = cfg.head_dim_
    L = min(window, max_len) if window else max_len
    return {
        "k": ParamDef((batch, L, cfg.n_kv_heads, hd), ("batch", "seq", "kv_heads", "head_dim"), init="zeros"),
        "v": ParamDef((batch, L, cfg.n_kv_heads, hd), ("batch", "seq", "kv_heads", "head_dim"), init="zeros"),
    }


def paged_cache_defs(cfg: ArchConfig, num_pages: int, page_size: int):
    """One layer's share of the paged KV pool: [P, page_size, K, D] per tensor.

    Unlike ``cache_defs`` there is no batch dim — requests own disjoint page
    sets and a per-request page table maps logical pages to physical ones."""
    hd = cfg.head_dim_
    return {
        "k": ParamDef((num_pages, page_size, cfg.n_kv_heads, hd),
                      (None, "seq", "kv_heads", "head_dim"), init="zeros"),
        "v": ParamDef((num_pages, page_size, cfg.n_kv_heads, hd),
                      (None, "seq", "kv_heads", "head_dim"), init="zeros"),
    }


def paged_prefill_attention_block(cfg: ArchConfig, p, x, cache, tables, start,
                                  n_live, freqs, *, q_block=512, unroll=False):
    """Multi-token prefill step against the paged KV pool, at an offset.

    x: [B, T, d] tail activations; cache: {"k","v": [P, ps, K, D]} one layer's
    pages; tables: [B, maxp] int32 logical->physical page map; start: [B]
    absolute position of x[:, 0]; n_live: [B] count of real (non-padding)
    tail tokens.  Row i's K/V lands at page ``tables[b, (start+i) // ps]``
    offset ``(start+i) % ps``; padding rows (i >= n_live) are routed to the
    reserved null page (physical page 0, a write sink) so they can never
    clobber live entries.  Queries attend to the gathered pages with absolute
    causal masking, so a cached prefix written by an earlier request is read
    exactly as if this request had prefilled it itself.
    Returns (out [B, T, d], new_cache)."""
    B, T, _ = x.shape
    ps = cache["k"].shape[1]
    q, k, v = qkv(cfg, p, x)
    positions = start[:, None] + jnp.arange(T)[None, :]              # [B, T]
    if freqs is not None:
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
    live = jnp.arange(T)[None, :] < n_live[:, None]                  # [B, T]
    page = tables[jnp.arange(B)[:, None], positions // ps]
    page = jnp.where(live, page, 0)                  # padding -> null page
    off = positions % ps
    ck = cache["k"].at[page, off].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[page, off].set(v.astype(cache["v"].dtype))

    kg = ck[tables].reshape(B, -1, cfg.n_kv_heads, cfg.head_dim_)
    vg = cv[tables].reshape(B, -1, cfg.n_kv_heads, cfg.head_dim_)
    o = chunked_attention(q, kg, vg, causal=True, q_block=q_block,
                          softcap=cfg.attn_logit_softcap, q_offset=start,
                          unroll=unroll)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), {"k": ck, "v": cv}


def paged_windowed_prefill_attention_block(cfg: ArchConfig, p, x, cache,
                                           tables, start, n_live, freqs, *,
                                           q_block=512, unroll=False):
    """Prefill for a sliding-window layer against the page *ring*.

    Attention itself is computed from the fresh K/V (the whole prompt is in
    ``x`` — windowed families are not prefix-cacheable, so ``start`` is
    always 0 in practice and nothing needs to be read back from the pool);
    only the cache writes go through the ring: position ``i`` lands at table
    slot ``(i // ps) % horizon``, and positions that would later be
    overwritten inside this same prefill (more than ``ring`` tokens before
    the prompt end) are routed to the null page so the scatter never writes
    one (page, offset) twice."""
    from .cache_spec import window_pages
    B, T, _ = x.shape
    ps = cache["k"].shape[1]
    ring = min(window_pages(cfg.sliding_window, ps), tables.shape[1]) * ps
    q, k, v = qkv(cfg, p, x)
    positions = start[:, None] + jnp.arange(T)[None, :]              # [B, T]
    if freqs is not None:
        q = apply_rope(q, positions, freqs)
        k = apply_rope(k, positions, freqs)
    n_total = start + n_live                                         # [B]
    live = (jnp.arange(T)[None, :] < n_live[:, None]) \
        & (positions >= n_total[:, None] - ring)
    ring_slot = (positions // ps) % (ring // ps)
    page = tables[jnp.arange(B)[:, None], ring_slot]
    page = jnp.where(live, page, 0)                  # masked -> null page
    off = positions % ps
    ck = cache["k"].at[page, off].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[page, off].set(v.astype(cache["v"].dtype))
    o = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                          q_block=q_block, softcap=cfg.attn_logit_softcap,
                          q_offset=start, unroll=unroll)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"]), {"k": ck, "v": cv}


def paged_windowed_decode_attention_block(cfg: ArchConfig, p, x, cache,
                                          tables, pos, freqs):
    """One-token decode for a sliding-window layer against the page ring.

    The new K/V lands at ring slot ``(pos // ps) % horizon`` (recycling the
    page that just aged out of the window); attention gathers the ring and
    masks by *absolute* position recovered from the ring layout — exactly
    the contiguous ring-buffer rule of ``decode_attention_block``, routed
    through the page table."""
    from .cache_spec import window_pages
    B = x.shape[0]
    ps = cache["k"].shape[1]
    R = min(window_pages(cfg.sliding_window, ps), tables.shape[1])
    ring = R * ps
    x1 = x[:, None, :]
    q = jnp.einsum("bsd,dhe->bshe", x1, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x1, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x1, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if freqs is not None:
        q = apply_rope(q, pos[:, None], freqs)
        k = apply_rope(k, pos[:, None], freqs)
    b = jnp.arange(B)
    page = tables[b, (pos // ps) % R]
    off = pos % ps
    ck = cache["k"].at[page, off].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[page, off].set(v[:, 0].astype(cache["v"].dtype))

    kg = ck[tables[:, :R]].reshape(B, -1, cfg.n_kv_heads, cfg.head_dim_)
    vg = cv[tables[:, :R]].reshape(B, -1, cfg.n_kv_heads, cfg.head_dim_)

    scale = 1.0 / math.sqrt(cfg.head_dim_)
    K = cfg.n_kv_heads
    G = cfg.n_heads_padded // K
    qg = q[:, 0].reshape(B, K, G, cfg.head_dim_)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kg,
                   preferred_element_type=jnp.float32) * scale
    if cfg.attn_logit_softcap:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    idx = jnp.arange(ring)
    slot = pos % ring
    k_abs = pos[:, None] - ((slot[:, None] - idx[None, :]) % ring)
    valid = (k_abs >= 0) & (k_abs <= pos[:, None]) \
        & (k_abs > pos[:, None] - cfg.sliding_window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(vg.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", a, vg).reshape(
        B, cfg.n_heads_padded, cfg.head_dim_)
    out = jnp.einsum("bhe,hed->bd", o, p["wo"])
    return out, {"k": ck, "v": cv}


def paged_decode_attention_block(cfg: ArchConfig, p, x, cache, tables, pos,
                                 freqs):
    """One-token decode step against the paged KV pool.

    x: [B, d] slot activations; cache: {"k","v": [P, ps, K, D]} (one layer's
    pages, shared by all slots); tables: [B, maxp] int32 logical->physical page
    map; pos: [B] absolute positions.  The new K/V lands at page
    ``tables[b, pos // ps]`` offset ``pos % ps``; attention reads the gathered
    pages with positions > pos masked, so stale data in partially-filled or
    recycled pages is softmax-zero.  Returns (out [B, d], new_cache)."""
    B = x.shape[0]
    ps = cache["k"].shape[1]
    x1 = x[:, None, :]
    q = jnp.einsum("bsd,dhe->bshe", x1, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x1, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x1, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if freqs is not None:
        q = apply_rope(q, pos[:, None], freqs)
        k = apply_rope(k, pos[:, None], freqs)
    b = jnp.arange(B)
    page = tables[b, pos // ps]                    # [B] physical pages
    off = pos % ps
    ck = cache["k"].at[page, off].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[page, off].set(v[:, 0].astype(cache["v"].dtype))

    # gather each slot's pages into a contiguous [B, maxp*ps, K, D] view
    kg = ck[tables].reshape(B, -1, cfg.n_kv_heads, cfg.head_dim_)
    vg = cv[tables].reshape(B, -1, cfg.n_kv_heads, cfg.head_dim_)

    scale = 1.0 / math.sqrt(cfg.head_dim_)
    K = cfg.n_kv_heads
    G = cfg.n_heads_padded // K
    qg = q[:, 0].reshape(B, K, G, cfg.head_dim_)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kg,
                   preferred_element_type=jnp.float32) * scale
    if cfg.attn_logit_softcap:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    idx = jnp.arange(kg.shape[1])
    valid = idx[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(vg.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", a, vg).reshape(
        B, cfg.n_heads_padded, cfg.head_dim_)
    out = jnp.einsum("bhe,hed->bd", o, p["wo"])
    return out, {"k": ck, "v": cv}


def decode_attention_block(cfg: ArchConfig, p, x, cache, pos, freqs, *, window=0):
    """One-token decode step.  x: [B, d]; pos: [B] absolute positions; cache ring-
    buffered when window > 0.  Returns (out [B, d], new_cache)."""
    B = x.shape[0]
    x1 = x[:, None, :]
    q = jnp.einsum("bsd,dhe->bshe", x1, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x1, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x1, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if freqs is not None:
        q = apply_rope(q, pos[:, None], freqs)
        k = apply_rope(k, pos[:, None], freqs)
    L = cache["k"].shape[1]
    slot = (pos % L) if window else pos
    b = jnp.arange(B)
    ck = cache["k"].at[b, slot].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[b, slot].set(v[:, 0].astype(cache["v"].dtype))

    scale = 1.0 / math.sqrt(cfg.head_dim_)
    K = cfg.n_kv_heads
    G = cfg.n_heads_padded // K
    qg = q[:, 0].reshape(B, K, G, cfg.head_dim_)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, ck, preferred_element_type=jnp.float32) * scale
    if cfg.attn_logit_softcap:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    idx = jnp.arange(L)
    if window:
        # slot is valid if it has been written and is within the window
        age = jnp.minimum(pos[:, None] + 1, L)
        # ring: entries idx written at absolute position pos - ((slot - idx) mod L)
        k_abs = pos[:, None] - ((slot[:, None] - idx[None, :]) % L)
        valid = (k_abs >= 0) & (k_abs <= pos[:, None]) & (k_abs > pos[:, None] - L)
        del age
    else:
        valid = idx[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(cv.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", a, cv).reshape(
        B, cfg.n_heads_padded, cfg.head_dim_)
    out = jnp.einsum("bhe,hed->bd", o, p["wo"])
    return out, {"k": ck, "v": cv}
