"""Parameter-definition machinery.

Models declare their parameters as a pytree of ``ParamDef`` leaves (shape, dtype,
logical sharding axes, init rule).  The same tree serves three consumers:

* ``init_tree``      -> real arrays (smoke tests, examples)
* ``abstract_tree``  -> ShapeDtypeStructs with shardings (dry-run: zero allocation)
* ``specs_tree``     -> NamedShardings (jit in/out_shardings)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from . import shardings


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "kernel"      # kernel | embed | zeros | ones | const:<v>

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(path, d: ParamDef, key) -> jax.Array:
    leaf_key = jax.random.fold_in(key, hash(jax.tree_util.keystr(path)) % (2**31))
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init.startswith("const:"):
        return jnp.full(d.shape, float(d.init.split(":")[1]), d.dtype)
    if d.init == "embed":
        scale = 0.02
    else:  # kernel: variance scaling on fan-in (all dims but last)
        fan_in = max(1, math.prod(d.shape[:-1]))
        scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(leaf_key, d.shape, jnp.float32) * scale).astype(d.dtype)


def init_tree(defs, key) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, d: _init_leaf(p, d, key), defs, is_leaf=_is_def
    )


def abstract_tree(defs, mesh: Optional[Mesh] = None) -> Any:
    def mk(d: ParamDef):
        if mesh is None:
            return jax.ShapeDtypeStruct(d.shape, d.dtype)
        sh = NamedSharding(mesh, shardings.resolve(d.logical, d.shape, mesh))
        return jax.ShapeDtypeStruct(d.shape, d.dtype, sharding=sh)
    return jax.tree.map(mk, defs, is_leaf=_is_def)


def specs_tree(defs, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda d: NamedSharding(mesh, shardings.resolve(d.logical, d.shape, mesh)),
        defs,
        is_leaf=_is_def,
    )


def stack_defs(d: ParamDef, n: int) -> ParamDef:
    """Stack a per-layer def into a scan-friendly [n, ...] def."""
    return ParamDef((n,) + d.shape, ("layers",) + d.logical, d.dtype, d.init)


def stack_tree(defs, n: int) -> Any:
    return jax.tree.map(lambda d: stack_defs(d, n), defs, is_leaf=_is_def)


def param_bytes(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return sum(math.prod(d.shape) * jnp.dtype(d.dtype).itemsize for d in leaves)


def sharded_bytes(defs, mesh: Mesh) -> int:
    """Per-device bytes of a defs tree under its resolved shardings."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=_is_def):
        spec = shardings.resolve(d.logical, d.shape, mesh)
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shards *= sizes[a]
        total += math.prod(d.shape) * jnp.dtype(d.dtype).itemsize // shards
    return total


def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return sum(math.prod(d.shape) for d in leaves)
