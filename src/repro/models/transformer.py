"""Decoder-only LM assembly for all assigned families.

Layers are stacked ``[L, ...]`` and applied with ``lax.scan`` (+ selectable remat
policy) so the HLO contains each block once — this keeps 60-layer 236B-parameter
dry-run compiles tractable and is also what a production launcher wants (compile
time scales O(1) in depth).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import shardings
from .attention import (attn_defs, cache_defs, decode_attention_block,
                        full_attention_block, paged_cache_defs)
from .attn_backend import get_backend
from .cache_spec import CacheFamilySpec, CacheSpec
from .layers import (apply_mlp, apply_norm, embed_defs, embed_tokens, lm_logits,
                     mlp_defs, norm_defs, rope_freqs)
from .mla import (mla_cache_defs, mla_decode_block, mla_defs, mla_full_block,
                  mla_paged_cache_defs)
from .moe import moe_apply, moe_decode_apply, moe_defs
from .params import ParamDef, stack_tree
from .rglru import (rglru_block, rglru_cache_defs, rglru_decode_block, rglru_defs)
from .ssm import (ssm_block, ssm_cache_defs, ssm_decode_block, ssm_defs)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # full


class DecoderLM:
    """Functional model: all state lives in explicit params/cache pytrees.

    ``attn_backend`` selects how the paged serving paths attend (see
    ``models.attn_backend``): the XLA ``reference`` gather+attend or the
    fused ``pallas`` decode kernel.  Training / static paths are unaffected.
    """

    def __init__(self, cfg: ArchConfig, attn_backend: str = "reference"):
        self.cfg = cfg
        self.attn_backend = get_backend(attn_backend)

    # ------------------------------------------------------------ param defs

    def _attn_defs(self):
        return mla_defs(self.cfg) if self.cfg.use_mla else attn_defs(self.cfg)

    def _dense_block_defs(self, d_ff: Optional[int] = None):
        cfg = self.cfg
        return {
            "ln1": norm_defs(cfg, cfg.d_model),
            "attn": self._attn_defs(),
            "ln2": norm_defs(cfg, cfg.d_model),
            "mlp": mlp_defs(cfg, cfg.d_model, d_ff or cfg.d_ff),
        }

    def _moe_block_defs(self):
        cfg = self.cfg
        return {
            "ln1": norm_defs(cfg, cfg.d_model),
            "attn": self._attn_defs(),
            "ln2": norm_defs(cfg, cfg.d_model),
            "moe": moe_defs(cfg),
        }

    def _rec_block_defs(self):
        cfg = self.cfg
        return {
            "ln1": norm_defs(cfg, cfg.d_model),
            "rec": rglru_defs(cfg),
            "ln2": norm_defs(cfg, cfg.d_model),
            "mlp": mlp_defs(cfg, cfg.d_model, cfg.d_ff),
        }

    def _ssm_block_defs(self):
        cfg = self.cfg
        return {"ln1": norm_defs(cfg, cfg.d_model), "ssm": ssm_defs(cfg)}

    def _hybrid_counts(self) -> Tuple[int, int, int]:
        """(n_groups, n_rec_tail, n_attn). Pattern = (rec, rec, attn)."""
        pat = self.cfg.block_pattern
        L = self.cfg.n_layers
        per = len(pat)
        n_groups = L // per
        tail = L - n_groups * per          # leftover layers are 'rec' by pattern order
        return n_groups, tail, n_groups

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        defs: Dict[str, Any] = {"embed": embed_defs(cfg),
                                "final_norm": norm_defs(cfg, cfg.d_model)}
        if cfg.n_image_tokens:
            defs["vision_proj"] = ParamDef((cfg.frontend_dim, cfg.d_model),
                                           (None, "embed"))
        if cfg.family == "ssm":
            defs["blocks"] = stack_tree(self._ssm_block_defs(), cfg.n_layers)
        elif cfg.family == "hybrid":
            n_groups, tail, n_attn = self._hybrid_counts()
            defs["rec_blocks"] = stack_tree(self._rec_block_defs(), 2 * n_groups)
            defs["attn_blocks"] = stack_tree(self._dense_block_defs(), n_attn)
            if tail:
                defs["tail_blocks"] = stack_tree(self._rec_block_defs(), tail)
        elif cfg.is_moe:
            k = cfg.first_k_dense
            if k:
                defs["dense_blocks"] = stack_tree(
                    self._dense_block_defs(cfg.d_ff_dense or cfg.d_ff), k)
            defs["blocks"] = stack_tree(self._moe_block_defs(), cfg.n_layers - k)
        else:
            defs["blocks"] = stack_tree(self._dense_block_defs(), cfg.n_layers)
        return defs

    # ------------------------------------------------------------- embedding

    def _embed_inputs(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Returns (x [B,S,D], loss_mask [B,S])."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["tokens"])
        mask = jnp.ones(batch["tokens"].shape, bool)
        if cfg.n_image_tokens:
            img = batch["image_embeds"].astype(x.dtype) @ params["vision_proj"]
            x = jnp.concatenate([img, x], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros(img.shape[:2], bool), mask], axis=1)
        if cfg.family == "hybrid":          # gemma-style embedding scale
            x = x * math.sqrt(cfg.d_model)
        return x, mask

    # ------------------------------------------------------- full-seq forward

    def _freqs(self, head_dim=None):
        cfg = self.cfg
        if cfg.family == "ssm":
            return None
        hd = head_dim or (cfg.rope_head_dim if cfg.use_mla else cfg.head_dim_)
        return rope_freqs(cfg, hd)

    def forward_hidden(self, params, x, mesh=None, collect_cache: bool = False):
        """x: [B,S,D] -> (hidden, aux_loss, cache_or_None)."""
        cfg = self.cfg
        freqs = self._freqs()
        aux0 = jnp.zeros((), jnp.float32)

        def _sp(x):
            if cfg.seq_parallel and mesh is not None:
                return shardings.constrain(x, mesh, ("batch", "seq_sp", None))
            return x

        def dense_body(carry, p, d_ff=None, window=0):
            x, aux = carry
            h = apply_norm(cfg, p["ln1"], x)
            if cfg.use_mla:
                a = mla_full_block(cfg, p["attn"], h, freqs, q_block=cfg.attn_q_block, unroll=cfg.unroll)
            else:
                a = full_attention_block(cfg, p["attn"], h, freqs, window=window, q_block=cfg.attn_q_block, unroll=cfg.unroll)
            x = x + a
            x = _sp(x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x)))
            return (x, aux), None

        def moe_body(carry, p):
            x, aux = carry
            h = apply_norm(cfg, p["ln1"], x)
            if cfg.use_mla:
                a = mla_full_block(cfg, p["attn"], h, freqs, q_block=cfg.attn_q_block, unroll=cfg.unroll)
            else:
                a = full_attention_block(cfg, p["attn"], h, freqs, q_block=cfg.attn_q_block, unroll=cfg.unroll)
            x = x + a
            m, a_loss = moe_apply(cfg, p["moe"], apply_norm(cfg, p["ln2"], x), mesh=mesh)
            return (_sp(x + m), aux + a_loss), None

        def rec_body(carry, p):
            x, aux = carry
            r, _ = rglru_block(cfg, p["rec"], apply_norm(cfg, p["ln1"], x))
            x = x + r
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return (x, aux), None

        def ssm_body(carry, p):
            x, aux = carry
            s, _ = ssm_block(cfg, p["ssm"], apply_norm(cfg, p["ln1"], x))
            return (x + s, aux), None

        rp = cfg.remat
        carry = (x, aux0)
        if cfg.family == "ssm":
            carry, _ = jax.lax.scan(_remat(ssm_body, rp), carry, params["blocks"], unroll=cfg.unroll)
        elif cfg.family == "hybrid":
            n_groups, tail, _ = self._hybrid_counts()
            rec2 = jax.tree.map(
                lambda a: a.reshape((n_groups, 2) + a.shape[1:]), params["rec_blocks"])

            def group_body(carry, ps):
                rec_p, attn_p = ps
                carry, _ = rec_body(carry, jax.tree.map(lambda a: a[0], rec_p))
                carry, _ = rec_body(carry, jax.tree.map(lambda a: a[1], rec_p))
                carry, _ = dense_body(carry, attn_p, window=cfg.attn_window)
                return carry, None

            carry, _ = jax.lax.scan(_remat(group_body, rp), carry,
                                    (rec2, params["attn_blocks"]),
                                    unroll=cfg.unroll)
            if tail:
                carry, _ = jax.lax.scan(_remat(rec_body, rp), carry,
                                        params["tail_blocks"], unroll=cfg.unroll)
        elif cfg.is_moe:
            if cfg.first_k_dense:
                dff = cfg.d_ff_dense or cfg.d_ff
                carry, _ = jax.lax.scan(
                    _remat(partial(dense_body, d_ff=dff), rp), carry,
                    params["dense_blocks"], unroll=cfg.unroll)
            carry, _ = jax.lax.scan(_remat(moe_body, rp), carry, params["blocks"], unroll=cfg.unroll)
        else:
            carry, _ = jax.lax.scan(
                _remat(partial(dense_body, window=cfg.sliding_window), rp),
                carry, params["blocks"], unroll=cfg.unroll)
        x, aux = carry
        return apply_norm(cfg, params["final_norm"], x), aux

    # ------------------------------------------------------------------ loss

    def loss(self, params, batch, mesh=None, chunk: int = 0):
        """Next-token CE, computed in sequence chunks so the [*, V] logits are
        never materialized for the full sequence (vocab can be 256k)."""
        cfg = self.cfg
        x, tok_mask = self._embed_inputs(params, batch)
        if mesh is not None:
            x = shardings.constrain(x, mesh, ("batch", None, None))
        hidden, aux = self.forward_hidden(params, x, mesh)

        tokens = batch["tokens"]
        n_img = cfg.n_image_tokens
        B, S = hidden.shape[0], hidden.shape[1]
        # labels: next token; image positions and final position masked out
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        labels = jnp.pad(labels, ((0, 0), (n_img, 0)))            # align to hidden
        lmask = jnp.roll(tok_mask, -1, axis=1).at[:, -1].set(False)

        chunk = min(chunk or cfg.loss_chunk, S)
        pad = (-S) % chunk
        if pad:
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)))
            lmask = jnp.pad(lmask, ((0, 0), (0, pad)))
        nc = hidden.shape[1] // chunk
        hc = jnp.moveaxis(hidden.reshape(B, nc, chunk, -1), 1, 0)
        lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
        mc = jnp.moveaxis(lmask.reshape(B, nc, chunk), 1, 0)
        vocab_mask = (jnp.arange(cfg.vocab_padded) >= cfg.vocab)

        def ce_chunk(carry, inp):
            h, l, m = inp
            logits = lm_logits(cfg, params["embed"], h).astype(jnp.float32)
            logits = jnp.where(vocab_mask, -1e30, logits)
            if mesh is not None:
                logits = shardings.constrain(logits, mesh, ("batch", None, "vocab"))
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
            nll = jnp.where(m, lse - gold, 0.0)
            tot, cnt = carry
            return (tot + jnp.sum(nll), cnt + jnp.sum(m)), None

        (tot, cnt), _ = jax.lax.scan(
            _remat(ce_chunk, cfg.remat),
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc, mc),
            unroll=cfg.unroll)
        loss = tot / jnp.maximum(cnt, 1.0)
        if cfg.is_moe:
            loss = loss + cfg.router_aux_coef * aux / max(1, cfg.n_layers)
        return loss, {"nll": tot / jnp.maximum(cnt, 1.0), "aux": aux, "tokens": cnt}

    # ----------------------------------------------------------------- cache

    def cache_defs(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.family == "ssm":
            return {"blocks": stack_tree(ssm_cache_defs(cfg, batch), cfg.n_layers),
                    "pos": ParamDef((batch,), ("batch",), jnp.int32, "zeros")}
        if cfg.family == "hybrid":
            n_groups, tail, n_attn = self._hybrid_counts()
            out = {
                "rec_blocks": stack_tree(rglru_cache_defs(cfg, batch), 2 * n_groups),
                "attn_blocks": stack_tree(
                    cache_defs(cfg, batch, max_len, window=cfg.attn_window), n_attn),
                "pos": ParamDef((batch,), ("batch",), jnp.int32, "zeros"),
            }
            if tail:
                out["tail_blocks"] = stack_tree(rglru_cache_defs(cfg, batch), tail)
            return out
        per = (mla_cache_defs(cfg, batch, max_len) if cfg.use_mla
               else cache_defs(cfg, batch, max_len, window=cfg.sliding_window))
        n = cfg.n_layers if not cfg.is_moe else cfg.n_layers  # same geometry all layers
        out = {"blocks": stack_tree(per, n),
               "pos": ParamDef((batch,), ("batch",), jnp.int32, "zeros")}
        return out

    # ---------------------------------------------------------------- decode

    def decode(self, params, cache, tokens, mesh=None):
        """One-token step. tokens: [B] int32. Returns (logits [B,V], new_cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = embed_tokens(params["embed"], tokens)
        if cfg.family == "hybrid":
            x = x * math.sqrt(cfg.d_model)
        freqs = self._freqs()

        def dense_step(x, p, c, window=0):
            h = apply_norm(cfg, p["ln1"], x)
            if cfg.use_mla:
                a, c2 = mla_decode_block(cfg, p["attn"], h, c, pos, freqs)
            else:
                a, c2 = decode_attention_block(cfg, p["attn"], h, c, pos, freqs,
                                               window=window)
            x = x + a
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, c2

        def moe_step(x, p, c):
            h = apply_norm(cfg, p["ln1"], x)
            if cfg.use_mla:
                a, c2 = mla_decode_block(cfg, p["attn"], h, c, pos, freqs)
            else:
                a, c2 = decode_attention_block(cfg, p["attn"], h, c, pos, freqs)
            x = x + a
            x = x + moe_decode_apply(cfg, p["moe"], apply_norm(cfg, p["ln2"], x), mesh=mesh)
            return x, c2

        def rec_step(x, p, c):
            r, c2 = rglru_decode_block(cfg, p["rec"], apply_norm(cfg, p["ln1"], x), c)
            x = x + r
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, c2

        def ssm_step(x, p, c):
            s, c2 = ssm_decode_block(cfg, p["ssm"], apply_norm(cfg, p["ln1"], x), c)
            return x + s, c2

        new_cache = dict(cache)
        if cfg.family == "ssm":
            def body(x, pc):
                p, c = pc
                return ssm_step(x, p, c)
            x, new_cache["blocks"] = _scan_blocks(body, x, params["blocks"], cache["blocks"], unroll=cfg.unroll)
        elif cfg.family == "hybrid":
            n_groups, tail, _ = self._hybrid_counts()
            rec2 = jax.tree.map(lambda a: a.reshape((n_groups, 2) + a.shape[1:]),
                                params["rec_blocks"])
            crec2 = jax.tree.map(lambda a: a.reshape((n_groups, 2) + a.shape[1:]),
                                 cache["rec_blocks"])

            def gbody(x, pc):
                (rp, ap), (rc, ac) = pc
                x, c0 = rec_step(x, jax.tree.map(lambda a: a[0], rp),
                                 jax.tree.map(lambda a: a[0], rc))
                x, c1 = rec_step(x, jax.tree.map(lambda a: a[1], rp),
                                 jax.tree.map(lambda a: a[1], rc))
                x, ca = dense_step(x, ap, ac, window=cfg.attn_window)
                rc_new = jax.tree.map(lambda a, b: jnp.stack([a, b]), c0, c1)
                return x, (rc_new, ca)

            x, (nrec, nattn) = _scan_blocks(gbody, x, (rec2, params["attn_blocks"]),
                                            (crec2, cache["attn_blocks"]),
                                            unroll=cfg.unroll)
            new_cache["rec_blocks"] = jax.tree.map(
                lambda a: a.reshape((2 * n_groups,) + a.shape[2:]), nrec)
            new_cache["attn_blocks"] = nattn
            if tail:
                def tbody(x, pc):
                    p, c = pc
                    return rec_step(x, p, c)
                x, new_cache["tail_blocks"] = _scan_blocks(
                    tbody, x, params["tail_blocks"], cache["tail_blocks"], unroll=cfg.unroll)
        elif cfg.is_moe:
            if cfg.first_k_dense:
                # dense lead-in layers share the cache stack head
                k = cfg.first_k_dense
                head = jax.tree.map(lambda a: a[:k], cache["blocks"])
                tail_c = jax.tree.map(lambda a: a[k:], cache["blocks"])

                def dbody(x, pc):
                    p, c = pc
                    return dense_step(x, p, c)
                x, nhead = _scan_blocks(dbody, x, params["dense_blocks"], head, unroll=cfg.unroll)

                def mbody(x, pc):
                    p, c = pc
                    return moe_step(x, p, c)
                x, ntail = _scan_blocks(mbody, x, params["blocks"], tail_c, unroll=cfg.unroll)
                new_cache["blocks"] = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b]), nhead, ntail)
            else:
                def mbody(x, pc):
                    p, c = pc
                    return moe_step(x, p, c)
                x, new_cache["blocks"] = _scan_blocks(mbody, x, params["blocks"],
                                                      cache["blocks"], unroll=cfg.unroll)
        else:
            def dbody(x, pc):
                p, c = pc
                return dense_step(x, p, c, window=cfg.sliding_window)
            x, new_cache["blocks"] = _scan_blocks(dbody, x, params["blocks"],
                                                  cache["blocks"], unroll=cfg.unroll)

        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x)
        new_cache["pos"] = pos + 1
        return logits, new_cache

    # --------------------------------------------------------------- prefill

    def prefill(self, params, batch, mesh=None, logits_idx=None):
        """Forward the full prompt; returns (last-token logits, filled cache).

        ``logits_idx`` ([B] int32, optional) selects which hidden position's
        logits to return instead of the last — serving uses this to prefill
        right-padded bucketed prompts (causal masking makes the padding
        invisible to every real position).

        Implemented as forward + per-layer cache extraction.  For attention
        families the K/V are recomputed from the hidden states layer-by-layer
        during the same scan (cache emitted as scan ys)."""
        cfg = self.cfg
        x, _ = self._embed_inputs(params, batch)
        if mesh is not None:
            x = shardings.constrain(x, mesh, ("batch", None, None))
        B, S = x.shape[0], x.shape[1]
        freqs = self._freqs()
        positions = jnp.arange(S)[None, :]

        cache = None
        if cfg.family == "ssm":
            def body(x, p):
                h = apply_norm(cfg, p["ln1"], x)
                z_in = h @ p["ssm"]["wx"]
                cB_in = h @ p["ssm"]["wB"]
                cC_in = h @ p["ssm"]["wC"]
                s, final = ssm_block(cfg, p["ssm"], h)
                w = cfg.conv_width
                c = {"conv_x": z_in[:, S - w + 1:], "conv_B": cB_in[:, S - w + 1:],
                     "conv_C": cC_in[:, S - w + 1:], "state": final}
                return x + s, c
            x, blocks = _scan_blocks_emit(body, x, params["blocks"], unroll=cfg.unroll)
            cache = {"blocks": blocks, "pos": jnp.full((B,), S, jnp.int32)}
        elif cfg.family == "hybrid":
            x, cache = self._prefill_hybrid(params, x, freqs, S)
        else:
            def body(x, p):
                h = apply_norm(cfg, p["ln1"], x)
                if cfg.use_mla:
                    a = mla_full_block(cfg, p["attn"], h, freqs, q_block=cfg.attn_q_block, unroll=cfg.unroll)
                    ckv_full = h @ p["attn"]["wkv_a"]
                    from .layers import rmsnorm as _rn
                    ckv = _rn(ckv_full[..., :cfg.kv_lora_rank], p["attn"]["kv_norm"])
                    from .layers import apply_rope as _ar
                    krope = _ar(ckv_full[..., cfg.kv_lora_rank:][:, :, None, :],
                                positions, freqs)[:, :, 0, :]
                    c = {"ckv": ckv, "krope": krope}
                else:
                    from .attention import qkv as _qkv
                    from .layers import apply_rope as _ar
                    q, k, v = _qkv(cfg, p["attn"], h)
                    k = _ar(k, positions, freqs)
                    a = full_attention_block(cfg, p["attn"], h, freqs,
                                             window=cfg.sliding_window,
                                             q_block=cfg.attn_q_block,
                                             unroll=cfg.unroll)
                    if cfg.sliding_window:
                        # ring-buffer the last W keys at slots (t % W), the
                        # layout decode's windowed cache reads (cache_defs
                        # allocates min(window, max_len) ring entries)
                        W = min(cfg.sliding_window, S)
                        t = jnp.arange(S - W, S)
                        slots = t % W
                        kw = jnp.zeros((k.shape[0], W) + k.shape[2:],
                                       k.dtype).at[:, slots].set(k[:, S - W:])
                        vw = jnp.zeros((v.shape[0], W) + v.shape[2:],
                                       v.dtype).at[:, slots].set(v[:, S - W:])
                        c = {"k": kw, "v": vw}
                    else:
                        c = {"k": k, "v": v}
                x = x + a
                if "moe" in p:
                    m, _ = moe_apply(cfg, p["moe"], apply_norm(cfg, p["ln2"], x), mesh=mesh)
                    x = x + m
                else:
                    x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
                return x, c

            if cfg.is_moe and cfg.first_k_dense:
                x, head = _scan_blocks_emit(
                    lambda x, p: body(x, p), x, params["dense_blocks"],
                    unroll=cfg.unroll)
                x, tail = _scan_blocks_emit(body, x, params["blocks"], unroll=cfg.unroll)
                blocks = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), head, tail)
            else:
                x, blocks = _scan_blocks_emit(body, x, params["blocks"], unroll=cfg.unroll)
            cache = {"blocks": blocks, "pos": jnp.full((B,), S, jnp.int32)}

        x = apply_norm(cfg, params["final_norm"], x)
        last = x[:, -1] if logits_idx is None else x[jnp.arange(B), logits_idx]
        logits = lm_logits(cfg, params["embed"], last)
        return logits, cache

    def cache_spec(self) -> CacheFamilySpec:
        """The decode-cache taxonomy the serving stack schedules against."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return CacheFamilySpec(kinds=(CacheSpec("state_slot"),),
                                   paged=False, state_slots=True,
                                   checkpointable=True)
        if cfg.family == "hybrid":
            # the bounded local-attention ring lives inside the state slot
            return CacheFamilySpec(
                kinds=(CacheSpec("state_slot"),
                       CacheSpec("state_slot", window=cfg.attn_window)),
                paged=False, state_slots=True, checkpointable=True)
        if cfg.use_mla:
            return CacheFamilySpec(kinds=(CacheSpec("paged_mla"),),
                                   paged=True, prefix_cacheable=True)
        if cfg.sliding_window:
            return CacheFamilySpec(
                kinds=(CacheSpec("windowed_kv", window=cfg.sliding_window),),
                paged=True, window=cfg.sliding_window)
        # vlm prompts are image-conditioned: identical token prefixes do not
        # imply identical KV, so the radix cache must not share them
        return CacheFamilySpec(kinds=(CacheSpec("paged_kv"),), paged=True,
                               prefix_cacheable=not cfg.n_image_tokens,
                               prefix_tokens=cfg.n_image_tokens)

    def supports_paged_decode(self) -> Tuple[bool, str]:
        """Capability report: every decoder-LM family pages now.  Returns
        (True, <cache-family description>) — kept as a tuple for callers that
        still branch on the old gate."""
        return True, self.cache_spec().describe()

    def paged_cache_defs(self, num_pages: int, page_size: int,
                         kv_dtype: str = "bf16"):
        """Abstract defs for the layer-stacked paged pool ({} when the whole
        cache is per-request state slots).  ``kv_dtype == "int8"`` adds the
        per-page scale leaves alongside the int8 payloads."""
        cfg = self.cfg
        if not self.cache_spec().paged:
            return {}
        per = (mla_paged_cache_defs(cfg, num_pages, page_size,
                                    kv_dtype=kv_dtype) if cfg.use_mla
               else paged_cache_defs(cfg, num_pages, page_size,
                                     kv_dtype=kv_dtype))
        return stack_tree(per, cfg.n_layers)

    def state_slot_defs(self, n_slots: int, max_len: int, enc_len: int = 0):
        """Abstract defs for the per-request state-slot pool ({} for pure
        paged families).  Slot axis is axis 1 of every (layer-stacked) leaf;
        layout matches ``cache_defs(n_slots, max_len)`` minus ``pos`` so the
        contiguous decode path can be reused verbatim."""
        if self.cfg.family not in ("ssm", "hybrid"):
            return {}
        defs = self.cache_defs(n_slots, max_len)
        defs.pop("pos")
        return defs

    # ----- paged attention dispatch (everything routes via the backend) -----

    def _paged_attn_decode(self, p, h, c, meta, freqs):
        return self.attn_backend.paged_decode(self.cfg, p["attn"], h, c, meta,
                                              freqs)

    def _paged_attn_verify(self, p, h, c, meta, freqs):
        return self.attn_backend.paged_verify(self.cfg, p["attn"], h, c, meta,
                                              freqs)

    def _paged_attn_prefill(self, p, h, c, meta, freqs):
        cfg = self.cfg
        return self.attn_backend.paged_prefill(
            cfg, p["attn"], h, c, meta, freqs,
            q_block=cfg.attn_q_block, unroll=cfg.unroll)

    def decode_paged(self, params, kv, state, meta, tokens, mesh=None):
        """One-token continuous-batching decode step.

        kv: layer-stacked paged pool ({} for state-slot families); state:
        layer-stacked per-slot recurrent state ({} for paged families),
        slot i == batch row i; meta: flat per-step metadata from
        ``attn_backend.decode_meta`` — per-slot page-table rows, [B] int32
        absolute positions, and the new token's precomputed physical write
        target, derived once by the engine instead of per block; tokens: [B]
        int32.  Returns (logits [B, V], new_kv, new_state).  Idle rows ride
        along masked: their table rows point at the reserved null page and
        their state rows are overwritten at the next admission's prefill."""
        cfg = self.cfg
        pos = meta["pos"]
        if cfg.family in ("ssm", "hybrid"):
            cache = dict(state)
            cache["pos"] = pos
            logits, new_cache = self.decode(params, cache, tokens, mesh)
            new_cache.pop("pos")
            return logits, kv, new_cache
        x = embed_tokens(params["embed"], tokens)
        freqs = self._freqs()

        def dense_step(x, p, c):
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = self._paged_attn_decode(p, h, c, meta, freqs)
            x = x + a
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, c2

        def moe_step(x, p, c):
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = self._paged_attn_decode(p, h, c, meta, freqs)
            x = x + a
            x = x + moe_decode_apply(cfg, p["moe"], apply_norm(cfg, p["ln2"], x),
                                     mesh=mesh)
            return x, c2

        if cfg.is_moe:
            k = cfg.first_k_dense
            if k:
                head = jax.tree.map(lambda a: a[:k], kv)
                tail = jax.tree.map(lambda a: a[k:], kv)

                def dbody(x, pc):
                    p, c = pc
                    return dense_step(x, p, c)
                x, nhead = _scan_blocks(dbody, x, params["dense_blocks"], head,
                                        unroll=cfg.unroll)

                def mbody(x, pc):
                    p, c = pc
                    return moe_step(x, p, c)
                x, ntail = _scan_blocks(mbody, x, params["blocks"], tail,
                                        unroll=cfg.unroll)
                new_kv = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b]), nhead, ntail)
            else:
                def mbody(x, pc):
                    p, c = pc
                    return moe_step(x, p, c)
                x, new_kv = _scan_blocks(mbody, x, params["blocks"], kv,
                                         unroll=cfg.unroll)
        else:
            def dbody(x, pc):
                p, c = pc
                return dense_step(x, p, c)
            x, new_kv = _scan_blocks(dbody, x, params["blocks"], kv,
                                     unroll=cfg.unroll)

        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x)
        return logits, new_kv, state

    def verify_paged(self, params, kv, state, meta, tokens, mesh=None):
        """Small-q speculative verify step — ``decode_paged`` over
        ``Q = 1 + speculate_tokens`` candidate tokens per slot.

        tokens: [B, Q] int32 — per slot the last emitted token followed by
        its draft, zero-padded to Q; meta: flat metadata from
        ``attn_backend.verify_meta`` (per-row base positions and live query
        counts).  Every per-token op (embed, norms, attention framing, MLP /
        MoE, logits) is the exact per-row computation of the decode step, so
        row ``j`` of the returned logits equals the decode step's logits at
        position ``pos + j`` bit-for-bit — which is what lets the engine
        accept drafted tokens without changing the greedy stream.  The MoE
        path routes each slot's Q tokens as one group at full capacity
        (``cap=Q``) so capacity dropping can never couple tokens.  Returns
        (logits [B, Q, V], new_kv, state).  Speculation is gated to paged
        decoder-only families (``serving.speculate.speculation_k``), so the
        state-slot route of ``decode_paged`` has no verify twin."""
        cfg = self.cfg
        assert cfg.family not in ("ssm", "hybrid"), \
            "speculative verify requires a paged cache family"
        x = embed_tokens(params["embed"], tokens)              # [B, Q, d]
        freqs = self._freqs()

        def dense_step(x, p, c):
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = self._paged_attn_verify(p, h, c, meta, freqs)
            x = x + a
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, c2

        def moe_step(x, p, c):
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = self._paged_attn_verify(p, h, c, meta, freqs)
            x = x + a
            m, _ = moe_apply(cfg, p["moe"], apply_norm(cfg, p["ln2"], x),
                             mesh=mesh, cap=x.shape[1])
            return x + m, c2

        if cfg.is_moe:
            k = cfg.first_k_dense
            if k:
                head = jax.tree.map(lambda a: a[:k], kv)
                tail = jax.tree.map(lambda a: a[k:], kv)

                def dbody(x, pc):
                    p, c = pc
                    return dense_step(x, p, c)
                x, nhead = _scan_blocks(dbody, x, params["dense_blocks"],
                                        head, unroll=cfg.unroll)

                def mbody(x, pc):
                    p, c = pc
                    return moe_step(x, p, c)
                x, ntail = _scan_blocks(mbody, x, params["blocks"], tail,
                                        unroll=cfg.unroll)
                new_kv = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b]), nhead, ntail)
            else:
                def mbody(x, pc):
                    p, c = pc
                    return moe_step(x, p, c)
                x, new_kv = _scan_blocks(mbody, x, params["blocks"], kv,
                                         unroll=cfg.unroll)
        else:
            def dbody(x, pc):
                p, c = pc
                return dense_step(x, p, c)
            x, new_kv = _scan_blocks(dbody, x, params["blocks"], kv,
                                     unroll=cfg.unroll)

        x = apply_norm(cfg, params["final_norm"], x)
        logits = lm_logits(cfg, params["embed"], x)
        return logits, new_kv, state

    def prefill_paged(self, params, kv, state, meta, tokens, extras=None,
                      mesh=None, continuation: bool = False):
        """Chunk prefill at an offset, straight into the paged pool and/or
        the state-slot pool.  ``continuation`` is a no-op for decoder-only
        models (chunks after the first are already pure page work); enc-dec
        overrides it to skip the per-chunk encoder forward.

        kv: layer-stacked paged pool ({} for state-slot families); state:
        layer-stacked per-slot state ({} for paged families); meta: the flat
        per-step metadata pytree from ``attn_backend.prefill_meta`` —
        page-table rows, state-slot / decode-row indices (out-of-range rows
        — batch padding — scatter nothing), per-row chunk offsets ``start``
        (absolute position of ``tokens[:, 0]``), live counts ``n_tail``
        (``tokens`` is right-padded to a bucket), and the precomputed
        physical write target of every chunk position, derived once by the
        engine instead of per layer; tokens: [B, T] int32; extras: optional
        frontend inputs ({"image_embeds": [B, n_img, D]} for vlm).

        With ``start == 0`` this is a full (or first-chunk) prompt prefill;
        with ``start > 0`` the first ``start`` positions are read from pages
        already resident in the pool — radix prefix-cache hits and earlier
        chunks of the same prompt alike.  Padding rows/positions write to
        the null page.  Returns (last-real-token logits [B, V], new_kv,
        new_state)."""
        cfg = self.cfg
        slots, n_tail = meta["slots"], meta["n_tail"]
        if cfg.family in ("ssm", "hybrid"):
            return self._prefill_state_slots(params, kv, state, slots, n_tail,
                                             tokens, mesh)
        x = embed_tokens(params["embed"], tokens)
        n_live = n_tail
        if cfg.n_image_tokens:
            # vlm: the hidden sequence is image tokens ++ text tokens; the
            # image prefix is always live and always at positions [0, n_img)
            img = (extras["image_embeds"].astype(x.dtype)
                   @ params["vision_proj"])
            x = jnp.concatenate([img, x], axis=1)
            n_live = n_tail + cfg.n_image_tokens
        freqs = self._freqs()
        B = x.shape[0]

        def dense_step(x, p, c):
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = self._paged_attn_prefill(p, h, c, meta, freqs)
            x = x + a
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            return x, c2

        def moe_step(x, p, c):
            h = apply_norm(cfg, p["ln1"], x)
            a, c2 = self._paged_attn_prefill(p, h, c, meta, freqs)
            x = x + a
            m, _ = moe_apply(cfg, p["moe"], apply_norm(cfg, p["ln2"], x),
                             mesh=mesh)
            return x + m, c2

        if cfg.is_moe:
            k = cfg.first_k_dense
            if k:
                head = jax.tree.map(lambda a: a[:k], kv)
                tail = jax.tree.map(lambda a: a[k:], kv)

                def dbody(x, pc):
                    p, c = pc
                    return dense_step(x, p, c)
                x, nhead = _scan_blocks(dbody, x, params["dense_blocks"], head,
                                        unroll=cfg.unroll)

                def mbody(x, pc):
                    p, c = pc
                    return moe_step(x, p, c)
                x, ntail = _scan_blocks(mbody, x, params["blocks"], tail,
                                        unroll=cfg.unroll)
                new_kv = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b]), nhead, ntail)
            else:
                def mbody(x, pc):
                    p, c = pc
                    return moe_step(x, p, c)
                x, new_kv = _scan_blocks(mbody, x, params["blocks"], kv,
                                         unroll=cfg.unroll)
        else:
            def dbody(x, pc):
                p, c = pc
                return dense_step(x, p, c)
            x, new_kv = _scan_blocks(dbody, x, params["blocks"], kv,
                                     unroll=cfg.unroll)

        x = apply_norm(cfg, params["final_norm"], x)
        last = x[jnp.arange(B), n_live - 1]
        logits = lm_logits(cfg, params["embed"], last)
        return logits, new_kv, state

    # ----------------------------------------------- state-slot prefill path

    def _prefill_state_slots(self, params, kv, state, slots, n_tail, tokens,
                             mesh=None):
        """Full-prompt prefill for recurrent families: run the masked full-
        sequence forward (right-padding is a recurrence no-op under
        ``length_mask``), extract each layer's final state + conv taps at the
        *true* prompt length, and scatter them into the state pool at rows
        ``slots`` (out-of-range rows are dropped)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        if cfg.family == "hybrid":
            x = x * math.sqrt(cfg.d_model)
        mask = jnp.arange(S)[None, :] < n_tail[:, None]              # [B, S]

        def conv_tail(u, width):
            """Last ``width - 1`` rows of ``u`` before each row's true length
            (zeros where the prompt is shorter than the conv receptive
            field, matching the zero-initialized decode conv cache)."""
            idx = n_tail[:, None] - (width - 1) + jnp.arange(width - 1)[None, :]
            valid = idx >= 0
            g = jnp.take_along_axis(u, jnp.maximum(idx, 0)[..., None], axis=1)
            return jnp.where(valid[..., None], g, 0).astype(u.dtype)

        if cfg.family == "ssm":
            def body(x, p):
                h = apply_norm(cfg, p["ln1"], x)
                z_in = h @ p["ssm"]["wx"]
                cB_in = h @ p["ssm"]["wB"]
                cC_in = h @ p["ssm"]["wC"]
                s, final = ssm_block(cfg, p["ssm"], h, length_mask=mask)
                w = cfg.conv_width
                c = {"conv_x": conv_tail(z_in, w), "conv_B": conv_tail(cB_in, w),
                     "conv_C": conv_tail(cC_in, w), "state": final}
                return x + s, c
            x, blocks = _scan_blocks_emit(body, x, params["blocks"],
                                          unroll=cfg.unroll)
            new = {"blocks": blocks}
        else:
            # ring length is whatever the state pool allocated
            x, new = self._hybrid_prefill_body(
                params, x, mask, conv_tail, n_tail,
                L_ring=state["attn_blocks"]["k"].shape[2])
        new_state = jax.tree.map(
            lambda a, nw: a.at[:, slots].set(nw.astype(a.dtype), mode="drop"),
            state, new)
        x = apply_norm(cfg, params["final_norm"], x)
        last = x[jnp.arange(B), n_tail - 1]
        logits = lm_logits(cfg, params["embed"], last)
        return logits, kv, new_state

    def _hybrid_prefill_body(self, params, x, mask, conv_tail, n_tail,
                             L_ring):
        """The one hybrid (RG-LRU + windowed-attention) prefill forward,
        shared by the static path (`_prefill_hybrid`: unmasked, ring length
        ``min(window, S)``) and the state-slot path (`_prefill_state_slots`:
        length-masked, ring length from the state pool).  Emits per-layer
        {conv taps, recurrent state, K/V ring} at each row's true length."""
        cfg = self.cfg
        S = x.shape[1]
        freqs = self._freqs()
        n_groups, tail, _ = self._hybrid_counts()
        positions = jnp.arange(S)[None, :]
        rec2 = jax.tree.map(lambda a: a.reshape((n_groups, 2) + a.shape[1:]),
                            params["rec_blocks"])

        def rec_fwd(x, p):
            h = apply_norm(cfg, p["ln1"], x)
            u_raw = h @ p["rec"]["w_in"]
            r, final = rglru_block(cfg, p["rec"], h, length_mask=mask)
            x = x + r
            x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
            c = {"conv": conv_tail(u_raw, cfg.conv_width), "state": final}
            return x, c

        def gbody(x, ps):
            rp, ap = ps
            x, c0 = rec_fwd(x, jax.tree.map(lambda a: a[0], rp))
            x, c1 = rec_fwd(x, jax.tree.map(lambda a: a[1], rp))
            h = apply_norm(cfg, ap["ln1"], x)
            from .attention import qkv as _qkv
            from .layers import apply_rope as _ar
            q, k, v = _qkv(cfg, ap["attn"], h)
            k = _ar(k, positions, freqs)
            a = full_attention_block(cfg, ap["attn"], h, freqs,
                                     window=cfg.attn_window,
                                     q_block=cfg.attn_q_block,
                                     unroll=cfg.unroll)
            x = x + a
            x = x + apply_mlp(cfg, ap["mlp"], apply_norm(cfg, ap["ln2"], x))
            # ring-buffer the last L_ring *true* keys at slots (t % L_ring),
            # per row: positions past each row's prompt never enter the ring
            b = x.shape[0]
            t = n_tail[:, None] - L_ring + jnp.arange(L_ring)[None, :]  # [B,R]
            ring = t % L_ring
            valid = t >= 0
            rows = jnp.arange(b)[:, None]
            kg = jnp.take_along_axis(
                k, jnp.maximum(t, 0)[..., None, None], axis=1)
            vg = jnp.take_along_axis(
                v, jnp.maximum(t, 0)[..., None, None], axis=1)
            kg = jnp.where(valid[..., None, None], kg, 0)
            vg = jnp.where(valid[..., None, None], vg, 0)
            kw = jnp.zeros((b, L_ring) + k.shape[2:], k.dtype
                           ).at[rows, ring].set(kg.astype(k.dtype))
            vw = jnp.zeros((b, L_ring) + v.shape[2:], v.dtype
                           ).at[rows, ring].set(vg.astype(v.dtype))
            ca = {"k": kw, "v": vw}
            rc = jax.tree.map(lambda a, bb: jnp.stack([a, bb]), c0, c1)
            return x, (rc, ca)

        x, (nrec, nattn) = _scan_blocks_emit(
            gbody, x, (rec2, params["attn_blocks"]), unroll=cfg.unroll)
        new = {
            "rec_blocks": jax.tree.map(
                lambda a: a.reshape((2 * n_groups,) + a.shape[2:]), nrec),
            "attn_blocks": nattn,
        }
        if tail:
            x, ctail = _scan_blocks_emit(rec_fwd, x, params["tail_blocks"],
                                         unroll=cfg.unroll)
            new["tail_blocks"] = ctail
        return x, new

    def _prefill_hybrid(self, params, x, freqs, S):
        """Static-path hybrid prefill: the shared body, unmasked, with every
        row at full length and the ring sized ``min(window, S)``."""
        cfg = self.cfg
        B = x.shape[0]
        n_tail = jnp.full((B,), S, jnp.int32)

        def conv_tail(u, width):
            return u[:, S - width + 1:]

        x, new = self._hybrid_prefill_body(params, x, mask=None,
                                           conv_tail=conv_tail, n_tail=n_tail,
                                           L_ring=min(cfg.attn_window, S))
        return x, {**new, "pos": jnp.full((B,), S, jnp.int32)}


def _scan_blocks(body, x, stacked_params, stacked_cache, unroll=False):
    """scan over (params, cache) pairs, returning (x, new_cache_stacked)."""
    def f(carry, pc):
        x = carry
        x, c = body(x, pc)
        return x, c
    x, cs = jax.lax.scan(f, x, (stacked_params, stacked_cache), unroll=unroll)
    return x, cs


def _scan_blocks_emit(body, x, stacked_params, unroll=False):
    def f(carry, p):
        x = carry
        x, c = body(x, p)
        return x, c
    x, cs = jax.lax.scan(f, x, stacked_params, unroll=unroll)
    return x, cs
