"""Shared neural layers: norms, activations, MLPs, RoPE, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .params import ParamDef

# ----------------------------------------------------------------------------- norms

def norm_defs(cfg: ArchConfig, d: int):
    if cfg.norm == "layernorm":
        return {"scale": ParamDef((d,), ("embed",), init="ones"),
                "bias": ParamDef((d,), ("embed",), init="zeros")}
    return {"scale": ParamDef((d,), ("embed",), init="ones")}


def apply_norm(cfg: ArchConfig, p, x, eps: float = 1e-5):
    if cfg.norm_fp32:
        xf = x.astype(jnp.float32)
        if cfg.norm == "layernorm":
            mu = jnp.mean(xf, -1, keepdims=True)
            var = jnp.var(xf, -1, keepdims=True)
            y = (xf - mu) * jax.lax.rsqrt(var + eps)
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        else:  # rmsnorm
            ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
            y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
        return y.astype(x.dtype)
    # bf16 elementwise path: only the variance statistics are fp32, so the
    # backward activation tensors (and their TP all-reduces) stay bf16
    if cfg.norm == "layernorm":
        mu = jnp.mean(x.astype(jnp.float32), -1, keepdims=True)
        var = jnp.var(x.astype(jnp.float32), -1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return (x - mu.astype(x.dtype)) * inv * p["scale"] + p["bias"]
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps).astype(x.dtype) * p["scale"]


def rmsnorm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)

# ----------------------------------------------------------------- activations

def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]

# ------------------------------------------------------------------------ MLP

def mlp_defs(cfg: ArchConfig, d: int, ff: int):
    defs = {"down": ParamDef((ff, d), ("ff", "embed"))}
    if cfg.mlp_gated:
        defs["gate"] = ParamDef((d, ff), ("embed", "ff"))
        defs["up"] = ParamDef((d, ff), ("embed", "ff"))
    else:
        defs["up"] = ParamDef((d, ff), ("embed", "ff"))
        if cfg.qkv_bias:  # starcoder2-style biased MLP
            defs["up_b"] = ParamDef((ff,), ("ff",), init="zeros")
            defs["down_b"] = ParamDef((d,), ("embed",), init="zeros")
    return defs


def apply_mlp(cfg: ArchConfig, p, x):
    act = act_fn(cfg.act)
    if cfg.mlp_gated:
        h = act(x @ p["gate"]) * (x @ p["up"])
    else:
        h = x @ p["up"]
        if "up_b" in p:
            h = h + p["up_b"]
        h = act(h)
    y = h @ p["down"]
    if "down_b" in p:
        y = y + p["down_b"]
    return y

# ----------------------------------------------------------------------- RoPE

def rope_freqs(cfg: ArchConfig, head_dim: int) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, freqs: jax.Array) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (broadcastable)."""
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)

# ------------------------------------------------------------------ embeddings

def embed_defs(cfg: ArchConfig):
    v, d = cfg.vocab_padded, cfg.d_model
    defs = {"tok": ParamDef((v, d), ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("embed", "vocab"))
    return defs


def embed_tokens(p, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["lm_head"]
    return x @ w
